"""Decentralized Byzantine-robust training demo: ring vs complete graph.

No master: every node owns its own parameter copy, exchanges SAGA-corrected
gradients only with its graph neighbors, and robustly aggregates its masked
neighborhood (repro.topology, DESIGN.md Sec. 6).  Two sign-flipping
Byzantine nodes attack PER EDGE -- each receiver gets poison crafted from
its own neighborhood statistics.

The run prints, per topology, the spectral-gap report and the loss +
consensus-distance trajectory under geomed vs the non-robust mean:

* on the COMPLETE graph every honest node sees every message, so the
  copies stay in perfect consensus and geomed recovers the master result;
* on the RING information diffuses hop by hop: consensus distance stays
  positive, robust aggregation still learns, while the mean rule lets the
  per-edge attack poison every neighborhood.

A second section compares the two GOSSIP MODES on a time-varying graph
(DESIGN.md Sec. 7): gradient gossip (aggregate neighbor gradients, then
step) vs parameter gossip (step locally, then robust-aggregate neighbor
MODELS, arXiv:2308.05292's setting), both over a per-round resampled
erdos_renyi schedule whose single rounds may be disconnected -- only the
window union connects.

    PYTHONPATH=src python examples/decentralized_gossip_demo.py \\
        --log-dir runs/gossip-demo --diagnostics

With ``--log-dir`` every run section streams its per-step metrics (and,
with ``--diagnostics``, the in-graph aggregation diagnostics) to
``<dir>/<section>/metrics.jsonl`` through ``repro.telemetry.RunLogger``.
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import RobustConfig, make_federated_step
from repro.core.robust_step import resolve_schedule
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer
from repro.topology import get_topology

HONEST, BYZ, STEPS = 10, 2, 300


def mean_honest_loss(loss_fn, params, wd, wh):
    return float(np.mean([
        loss_fn({"w": params["w"][i]},
                {"a": wd["a"][i], "b": wd["b"][i]})
        for i in range(wh)]))


def run_dir(base: str, name: str):
    return os.path.join(base, name) if base else None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--log-dir", default="", help="write metrics.jsonl per "
                    "run section under <dir>/<section>/ (repro.telemetry)")
    ap.add_argument("--diagnostics", action="store_true",
                    help="log in-graph aggregation diagnostics per step")
    ap.add_argument("--steps", type=int, default=STEPS)
    args = ap.parse_args()
    steps = args.steps
    data = ijcnn1_like(jax.random.PRNGKey(0), n=2000)
    wd = partition({"a": data.x, "b": data.y}, HONEST, seed=1)
    loss_fn = logreg_loss(0.01)
    opt = get_optimizer("sgd", 0.02)

    for topo_name in ("ring", "complete"):
        topo = get_topology(topo_name, HONEST + BYZ)
        print(f"\n=== {topo_name} === {topo.describe()}")
        for agg in ("geomed", "mean"):
            cfg = RobustConfig(aggregator=agg, vr="saga", attack="sign_flip",
                               num_byzantine=BYZ, weiszfeld_iters=32,
                               diagnostics=args.diagnostics)
            init_fn, step_fn = make_federated_step(
                loss_fn, wd, cfg, opt, topology=topo)
            state = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                            jax.random.PRNGKey(1))
            step = jax.jit(step_fn)
            with telemetry.RunLogger(
                    run_dir(args.log_dir, f"{topo_name}_{agg}"),
                    flush_every=64) as logger:
                logger.write_meta(
                    section="topology", topology=topo_name, aggregator=agg,
                    honest=HONEST, byzantine=BYZ, steps=steps,
                    jax_version=jax.__version__)
                for i in range(steps):
                    state, metrics = step(state)
                    logger.log_step(i, metrics)
                    if i % (steps // 3) == 0 or i == steps - 1:
                        ml = mean_honest_loss(loss_fn, state.params, wd,
                                              HONEST)
                        print(f"  {agg:7s} step {i:3d}: honest-loss={ml:.4f} "
                              f"consensus="
                              f"{float(metrics['consensus_dist']):.5f}")

    print("\n=== gossip modes on a time-varying erdos_renyi schedule ===")
    for gossip in ("gradient", "params"):
        cfg = RobustConfig(aggregator="geomed", vr="saga",
                           attack="sign_flip", num_byzantine=BYZ,
                           weiszfeld_iters=32, gossip=gossip,
                           schedule="erdos_renyi", schedule_period=4,
                           topology_p=0.4, diagnostics=args.diagnostics)
        sched = resolve_schedule(cfg, HONEST + BYZ)
        if gossip == "gradient":
            d = sched.describe()
            print(f"  schedule: period={d['period']} "
                  f"window_connected={d['window_connected']} "
                  f"joint_spectral_gap={d['joint_spectral_gap']:.3f} "
                  f"(per-round gaps: "
                  f"{[round(r['spectral_gap'], 3) for r in d['rounds']]})")
        init_fn, step_fn = make_federated_step(
            loss_fn, wd, cfg, opt, schedule=sched)
        state = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                        jax.random.PRNGKey(1))
        step = jax.jit(step_fn)
        with telemetry.RunLogger(
                run_dir(args.log_dir, f"schedule_{gossip}"),
                flush_every=64) as logger:
            logger.write_meta(
                section="gossip_modes", gossip=gossip, honest=HONEST,
                byzantine=BYZ, steps=steps, jax_version=jax.__version__)
            for i in range(steps):
                state, metrics = step(state)
                logger.log_step(i, metrics)
                if i % (steps // 3) == 0 or i == steps - 1:
                    ml = mean_honest_loss(loss_fn, state.params, wd, HONEST)
                    print(f"  {gossip:8s} step {i:3d}: honest-loss={ml:.4f} "
                          f"consensus={float(metrics['consensus_dist']):.5f}")


if __name__ == "__main__":
    main()
