"""Multi-device federation demo: the REAL distributed code path (shard_map /
pjit) on 8 forced host devices — one mesh index per worker, model-parallel
inner axis, geometric-median aggregation over the data axis, one Byzantine
worker mounting a sign-flip attack.

    PYTHONPATH=src python examples/federated_mesh_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.base import TrainConfig  # noqa: E402
from repro.core.robust_step import RobustConfig  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.train import make_batch  # noqa: E402
from repro.models.api import build_model  # noqa: E402
from repro.optim import get_optimizer  # noqa: E402


def main() -> None:
    mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({len(jax.devices())} devices) — 4 workers, 2-way model parallel")
    cfg = get_config("qwen2-7b").reduced()
    model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)

    for comm in ("gather", "sharded"):
        robust = RobustConfig(aggregator="geomed", vr="sgd", attack="sign_flip",
                              num_byzantine=1, comm=comm, weiszfeld_iters=16)
        step_fn, _, _ = steps_lib.make_train_step(
            model, robust, TrainConfig(optimizer="adamw", lr=1e-3), mesh)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            opt = get_optimizer("adamw", 1e-3)
            state = {"params": params, "opt": opt.init(params),
                     "step": jnp.zeros((), jnp.int32)}
            jstep = jax.jit(step_fn)
            key = jax.random.PRNGKey(1)
            print(f"\ncomm={comm} (paper-faithful gather vs sharded Weiszfeld):")
            for i in range(10):
                batch = make_batch(jax.random.fold_in(key, i), cfg, 4, 2, 32)
                state, m = jstep(state, batch, jax.random.fold_in(key, 50 + i))
                if i % 3 == 0 or i == 9:
                    print(f"  step {i}: honest-loss={float(m['loss']):.4f} "
                          f"agg_norm={float(m['agg_norm']):.4f}")


if __name__ == "__main__":
    main()
