"""End-to-end serving driver: the FULL mamba2-130m config (24L, d=768,
130M params — the real assigned architecture, small enough for CPU) serving
a batch of requests: prefill the prompts, then decode autoregressively with
the O(1) SSM state cache.

    PYTHONPATH=src python examples/serve_batched.py --batch 4 --prompt-len 64 \\
        --decode-tokens 48
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.api import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=48)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized model instead of the full 130M")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m")
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=False, q_chunk=64, kv_chunk=64)
    print(f"initializing {cfg.name} ({'reduced' if args.reduced else 'full'})...")
    t0 = time.time()
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"  {n/1e6:.1f}M params in {time.time()-t0:.1f}s")

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in {dt:.2f}s "
          f"({args.batch*args.prompt_len/dt:.0f} tok/s, incl. compile)")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    # SSM decode: position argument is unused by mamba (stateless in pos),
    # cache is O(1) per request regardless of context length.
    logits, cache = decode(params, cache, tok, jnp.asarray(args.prompt_len, jnp.int32))
    jax.block_until_ready(logits)  # compile
    t0 = time.time()
    for i in range(args.decode_tokens - 1):
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(args.prompt_len + 1 + i, jnp.int32))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    total = args.batch * (args.decode_tokens - 1)
    print(f"decode: {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s, "
          f"{dt/(args.decode_tokens-1)*1e3:.0f} ms/step for batch {args.batch})")
    toks = jnp.concatenate(generated, axis=1)
    for b in range(min(args.batch, 2)):
        print(f"  request {b}: {toks[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
