"""End-to-end Byzantine-robust LM training: a ~100M-parameter decoder-only
transformer trained for a few hundred steps across W simulated workers with
SAGA-corrected gradients + geometric-median aggregation, while B workers
mount a sign-flip attack.

    # full ~100M model (slow on CPU; use --preset small for a quick run)
    PYTHONPATH=src python examples/train_robust_lm.py --preset 100m --steps 300

    # CPU-quick variant (~8M params, ~2 min)
    PYTHONPATH=src python examples/train_robust_lm.py --preset small --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import RobustConfig
from repro.core.attacks import apply_attack_stacked
from repro.core.aggregators import get_aggregator
from repro.core.saga import saga_correct_scatter, saga_init_zeros
from repro.models.api import build_model
from repro.optim import apply_updates, get_optimizer

PRESETS = {
    # ~103M params: 12L, d=768, untied 32k vocab.
    "100m": ModelConfig(name="robust-lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=12, d_ff=2048,
                        vocab_size=32000, param_dtype="float32",
                        tie_embeddings=True),
    # ~8M params for CPU-quick runs.
    "small": ModelConfig(name="robust-lm-small", family="dense", num_layers=4,
                         d_model=256, num_heads=4, num_kv_heads=4, d_ff=1024,
                         vocab_size=8000, param_dtype="float32",
                         tie_embeddings=True),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--byzantine", type=int, default=2)
    ap.add_argument("--attack", default="sign_flip")
    ap.add_argument("--aggregator", default="geomed")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--saga-samples", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg, remat=False, q_chunk=args.seq, kv_chunk=args.seq,
                        loss_chunk=128)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params | {args.workers} workers "
          f"({args.byzantine} Byzantine, {args.attack}) | agg={args.aggregator} "
          f"| SAGA J={args.saga_samples}")

    robust = RobustConfig(aggregator=args.aggregator, vr="saga",
                          attack=args.attack, num_byzantine=args.byzantine,
                          weiszfeld_iters=16)
    aggregate = robust.aggregator_fn()
    attack_cfg = robust.attack_config()
    opt = get_optimizer("adamw", args.lr)

    # Fixed per-worker corpora (the finite-sum setting: J batches per worker).
    key = jax.random.PRNGKey(1)
    corpus = jax.random.randint(
        key, (args.workers, args.saga_samples, args.per_worker_batch,
              args.seq + 1), 0, cfg.vocab_size, jnp.int32)

    def worker_loss(p, toks):
        return model.loss(p, {"tokens": toks[..., :-1], "labels": toks[..., 1:]})

    saga = saga_init_zeros(params, args.workers, args.saga_samples)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, saga, key, i):
        k1, k2 = jax.random.split(key)
        idx = jax.random.randint(k1, (args.workers,), 0, args.saga_samples)
        batches = jnp.take_along_axis(
            corpus, idx[:, None, None, None], axis=1)[:, 0]
        losses, grads = jax.vmap(jax.value_and_grad(worker_loss),
                                 in_axes=(None, 0))(params, batches)
        msgs, saga = saga_correct_scatter(saga, grads, idx)
        msgs = apply_attack_stacked(attack_cfg, msgs, k2)
        agg = aggregate(msgs)
        updates, opt_state = opt.update(agg, opt_state, params, i)
        params = apply_updates(params, updates)
        return params, opt_state, saga, jnp.mean(losses)

    t0 = time.time()
    for i in range(args.steps):
        params, opt_state, saga, loss = step(
            params, opt_state, saga, jax.random.fold_in(key, 100 + i), i)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d}  honest-loss={float(loss):.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print("done — loss should be dropping despite the Byzantine workers.")


if __name__ == "__main__":
    main()
