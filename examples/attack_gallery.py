"""Attack x aggregator gallery: the robustness landscape in one table.

Runs the federated logreg problem under every (attack x aggregator) pair
(including the two beyond-paper attacks ALIE and IPM) and prints the final
optimality gap.  Geomed/median/Krum should survive everything with B < W/2;
mean should fail under every attack.

    PYTHONPATH=src python examples/attack_gallery.py
"""
import jax
import jax.numpy as jnp

from repro.core import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_full_loss_and_opt, logreg_loss, partition
from repro.optim import get_optimizer

ATTACKS = ["none", "gaussian", "sign_flip", "zero_gradient", "alie", "ipm"]
AGGS = ["mean", "geomed", "median", "trimmed_mean", "krum", "centered_clip"]
WH, B, STEPS = 15, 6, 500


def main() -> None:
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=1500)
    loss = logreg_loss(0.01)
    _, f_star = logreg_full_loss_and_opt(data)
    batch = {"a": data.x, "b": data.y}
    wd = partition(batch, WH, seed=1)

    print(f"Byrd-SAGA optimality gaps, {WH} honest + {B} Byzantine, {STEPS} steps")
    header = f"{'attack':>14s} | " + " | ".join(f"{a:>13s}" for a in AGGS)
    print(header)
    print("-" * len(header))
    for attack in ATTACKS:
        row = []
        for agg in AGGS:
            cfg = RobustConfig(aggregator=agg, vr="saga", attack=attack,
                               num_byzantine=0 if attack == "none" else B,
                               num_groups=3, trim=min(B, WH // 2))
            opt = get_optimizer("sgd", 0.02)
            init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
            st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(2))
            jstep = jax.jit(step_fn)
            for _ in range(STEPS):
                st, _ = jstep(st)
            row.append(float(loss(st.params, batch)) - f_star)
        print(f"{attack:>14s} | " + " | ".join(f"{g:>13.5f}" for g in row))


if __name__ == "__main__":
    main()
