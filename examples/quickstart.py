"""Quickstart: Byrd-SAGA on l2-regularized logistic regression under a
sign-flipping Byzantine attack (the paper's core experiment, Sec. V-A).

    PYTHONPATH=src python examples/quickstart.py

Expected: mean aggregation collapses under attack; Byrd-SAGA (geomed)
converges to a small optimality gap; robust SGD converges to a larger one
(Thm 1 vs Thm 2).
"""
import jax
import jax.numpy as jnp

from repro.core import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_full_loss_and_opt, logreg_loss, partition
from repro.optim import get_optimizer


def main() -> None:
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=2000)
    loss = logreg_loss(0.01)
    _, f_star = logreg_full_loss_and_opt(data)
    batch = {"a": data.x, "b": data.y}
    honest, byzantine = 25, 10
    worker_data = partition(batch, honest, seed=1)
    print(f"{honest} honest + {byzantine} Byzantine workers, "
          f"J={worker_data['a'].shape[1]} samples each, sign-flip attack\n")

    runs = [
        ("Byrd-SAGA   (SAGA + geomed)", "saga", "geomed", 0.02),
        ("robust SGD  (SGD + geomed)", "sgd", "geomed", 0.02),
        ("plain SAGA  (SAGA + mean)", "saga", "mean", 0.02),
    ]
    for label, vr, agg, lr in runs:
        cfg = RobustConfig(aggregator=agg, vr=vr, attack="sign_flip",
                           num_byzantine=byzantine)
        opt = get_optimizer("sgd", lr)
        init_fn, step_fn = make_federated_step(loss, worker_data, cfg, opt)
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(1))
        jstep = jax.jit(step_fn)
        for k in range(1200):
            st, metrics = jstep(st)
            if (k + 1) % 400 == 0:
                gap = float(loss(st.params, batch)) - f_star
                print(f"  {label}  step {k+1:4d}  gap={gap:.5f}  "
                      f"honest-var={float(metrics['honest_variance']):.2e}")
        print()


if __name__ == "__main__":
    main()
