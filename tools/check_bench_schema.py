#!/usr/bin/env python
"""Bench-artifact schema checker: every ``BENCH_*.json`` matches its schema.

The benchmark scripts under benchmarks/ stamp a ``schema`` version string
(e.g. ``BENCH_step/v3``) into every artifact they write.  This tool pins
those stamps to an explicit registry of required top-level and per-row keys,
so a bench script that silently drops a field (or bumps its output shape
without bumping the version) fails CI instead of producing artifacts that
downstream tooling half-understands.

    python tools/check_bench_schema.py [files...]   # default: BENCH_*.json
                                                    # in the repo root

Unknown schema stamps fail too: adding a new bench artifact means adding
its registry entry here in the same change.  Run by
.github/workflows/ci.yml next to tools/check_doc_links.py.
"""
from __future__ import annotations

import glob
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Per schema version: required top-level keys, required per-row keys, and
# (optionally) required keys of nested top-level objects.  Extra keys are
# allowed everywhere -- the registry pins a floor, not an exact shape.
SCHEMAS = {
    "BENCH_step/v4": {
        "top": {"schema", "jax_version", "platform", "device_count",
                "sim_workers", "gate", "rows"},
        # v4: gate cells are keyed by message_dtype too (keyed_by pins the
        # key fields), and every row names its wire format.
        "nested": {"gate": {"speedup_cells", "speedup_floor",
                            "noise_margin", "keyed_by"}},
        "row": {"path", "aggregator", "packed", "num_workers",
                "num_byzantine", "vr", "attack", "message_dtype",
                "vr_state_bytes", "leaves", "coords", "steps", "reps",
                "wall_us_mean", "wall_us_min"},
        # Only the sim/grid paths carry per-client VR accounting; the
        # distributed-lowering rows legitimately omit these.  Grid rows
        # (the v4 attack x wire-format robustness characterization)
        # additionally score the run by its final honest-data loss.
        "row_when": {("path", "sim"): {"num_samples", "num_clients"},
                     ("path", "grid"): {"num_samples", "num_clients",
                                        "final_honest_loss"}},
    },
    "BENCH_step/v5": {
        "top": {"schema", "jax_version", "platform", "device_count",
                "sim_workers", "gate", "rows"},
        "nested": {"gate": {"speedup_cells", "speedup_floor",
                            "noise_margin", "keyed_by"}},
        "row": {"path", "aggregator", "packed", "num_workers",
                "num_byzantine", "vr", "attack", "message_dtype",
                "vr_state_bytes", "leaves", "coords", "steps", "reps",
                "wall_us_mean", "wall_us_min"},
        # v5 adds the fault-containment grid (path="fault"): guards on/off
        # cells that record whether the honest loss stayed finite, and the
        # loss value only when it did (a NaN would be unrepresentable in
        # JSON and fail the numeric check).
        "row_when": {("path", "sim"): {"num_samples", "num_clients"},
                     ("path", "grid"): {"num_samples", "num_clients",
                                        "final_honest_loss"},
                     ("path", "fault"): {"num_samples", "num_clients",
                                         "guards", "loss_finite"}},
    },
    "BENCH_comm_modes/v1": {
        "top": {"schema", "jax_version", "platform", "device_count",
                "coords_requested", "weiszfeld_iters", "rows"},
        "row": {"mesh", "axes", "worker_axes", "num_workers", "aggregator",
                "comm", "coords", "reps", "model_bytes_per_device",
                "wall_us_mean", "wall_us_min"},
    },
    "BENCH_topologies/v2": {
        "top": {"schema", "jax_version", "platform", "num_honest",
                "num_byzantine", "steps", "rows"},
        "row": {"topology", "aggregator", "attack", "gossip", "schedule",
                "schedule_period", "num_nodes", "num_byzantine", "steps",
                "reps", "spectral_gap", "wall_us_mean", "wall_us_min",
                "final_honest_loss", "consensus_dist"},
    },
}

# Keys whose values must be finite numbers in every row that has them.
NUMERIC_ROW_KEYS = ("wall_us_mean", "wall_us_min", "final_honest_loss",
                    "consensus_dist", "model_bytes_per_device")


def check_file(path: str) -> list[str]:
    rel = os.path.relpath(path, REPO)
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{rel}: unreadable ({e})"]
    errs = []
    schema = doc.get("schema")
    if schema not in SCHEMAS:
        return [f"{rel}: unknown schema {schema!r} "
                f"(registered: {sorted(SCHEMAS)})"]
    spec = SCHEMAS[schema]
    missing = spec["top"] - set(doc)
    if missing:
        errs.append(f"{rel}: missing top-level keys {sorted(missing)}")
    for key, req in spec.get("nested", {}).items():
        sub = doc.get(key)
        if not isinstance(sub, dict):
            errs.append(f"{rel}: {key!r} must be an object")
        elif req - set(sub):
            errs.append(f"{rel}: {key!r} missing {sorted(req - set(sub))}")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        errs.append(f"{rel}: 'rows' must be a non-empty list")
        return errs
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errs.append(f"{rel}: rows[{i}] is not an object")
            continue
        required = set(spec["row"])
        for (key, val), extra in spec.get("row_when", {}).items():
            if row.get(key) == val:
                required |= extra
        missing = required - set(row)
        if missing:
            errs.append(f"{rel}: rows[{i}] missing {sorted(missing)}")
        for k in NUMERIC_ROW_KEYS:
            v = row.get(k)
            if k in row and (not isinstance(v, (int, float))
                             or isinstance(v, bool) or v != v):
                errs.append(f"{rel}: rows[{i}][{k!r}] not a finite "
                            f"number: {v!r}")
    return errs


def main(argv: list[str]) -> int:
    files = argv or sorted(glob.glob(os.path.join(REPO, "BENCH_*.json")))
    if not files:
        print("check_bench_schema: no BENCH_*.json artifacts found")
        return 0
    errs = []
    for path in files:
        errs.extend(check_file(path))
    for e in errs:
        print(e)
    if not errs:
        print(f"check_bench_schema: {len(files)} artifact(s) OK "
              f"({', '.join(os.path.basename(p) for p in files)})")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
