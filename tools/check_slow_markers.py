"""Audit that every minutes-scale test carries the ``slow`` marker.

Tier-1 (the default ``pytest -x -q`` run) must stay fast enough to gate
every PR; anything that takes longer than ``BUDGET_S`` belongs in tier 2
behind ``@pytest.mark.slow`` (pytest.ini) so local runs can deselect it
with ``-m "not slow"``.  This script closes the loop: it parses the
``--durations=25`` report that CI tees into ``TEST_DURATIONS.txt`` and
fails if any over-budget test is NOT slow-marked in its source file.

    python tools/check_slow_markers.py [TEST_DURATIONS.txt]

Duration lines look like::

    123.45s call     tests/test_convergence.py::test_c2_saga_beats_sgd_under_attack

Only ``call`` phases count (setup/teardown of a module-scope fixture is
amortized across every test that shares it, so charging it to the first
test would misfire).  Parametrized ids are stripped to the function name
before the source grep.
"""
from __future__ import annotations

import pathlib
import re
import sys

BUDGET_S = 60.0
ROOT = pathlib.Path(__file__).resolve().parent.parent

_LINE = re.compile(
    r"^\s*(?P<secs>\d+(?:\.\d+)?)s\s+call\s+"
    r"(?P<file>\S+?)::(?P<test>\S+)\s*$")


def over_budget(report_text: str):
    """(seconds, file, test-function) for every over-budget call line."""
    out = []
    for line in report_text.splitlines():
        m = _LINE.match(line)
        if not m:
            continue
        secs = float(m.group("secs"))
        if secs <= BUDGET_S:
            continue
        test = m.group("test").split("[")[0]      # strip parametrized id
        out.append((secs, m.group("file"), test))
    return out


def is_slow_marked(path: pathlib.Path, test: str) -> bool:
    """True if ``test``'s def in ``path`` sits under a pytest.mark.slow
    decorator (scanning the decorator block directly above the def)."""
    try:
        lines = path.read_text().splitlines()
    except OSError:
        return False
    for i, line in enumerate(lines):
        if re.match(rf"\s*def {re.escape(test)}\s*\(", line):
            j = i - 1
            while j >= 0 and (lines[j].lstrip().startswith("@")
                              or lines[j].strip() == ""
                              or lines[j].lstrip().startswith("#")):
                if "pytest.mark.slow" in lines[j]:
                    return True
                j -= 1
    return False


def main() -> int:
    report = pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                          else "TEST_DURATIONS.txt")
    if not report.exists():
        print(f"{report}: not found (run pytest with --durations=25 "
              "| tee TEST_DURATIONS.txt first)")
        return 1
    failures = []
    for secs, fname, test in over_budget(report.read_text()):
        if not is_slow_marked(ROOT / fname, test):
            failures.append(f"{fname}::{test} took {secs:.0f}s "
                            f"(> {BUDGET_S:.0f}s) without @pytest.mark.slow")
    if failures:
        print("SLOW-MARKER AUDIT FAILED:")
        for f in failures:
            print(" ", f)
        return 1
    print("slow-marker audit OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
