#!/usr/bin/env python
"""Doc-link checker: no dangling file references in the repo's documentation.

Scans the markdown docs for references that look like repo paths -- markdown
link targets and backticked tokens ending in a known file extension (or a
trailing slash for directories) -- and fails if the referenced path exists
neither relative to the repo root nor to src/repro/ (docstrings habitually
cite module paths like ``core/robust_step.py``).  Generated artifacts
(``BENCH_*.json``, anything under ``experiments/``) are exempt from the
path check, but every ``BENCH_*.json`` schema named in
``benchmarks/README.md`` must have a PRODUCING SCRIPT under benchmarks/
(a .py file that mentions the artifact by name), so documented bench
schemas can't outlive their producers.

    python tools/check_doc_links.py [files...]     # default: the doc set

Run by .github/workflows/ci.yml on every push/PR.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEFAULT_DOCS = ["README.md", "DESIGN.md", "ROADMAP.md", "benchmarks/README.md"]

# Tokens that count as path references when they appear in `backticks` or as
# [markdown](targets): end in a checked extension, or in "/" (a directory).
EXTS = (".py", ".md", ".yml", ".yaml", ".json", ".txt", ".toml", ".cfg")

BACKTICK = re.compile(r"`([A-Za-z0-9_.:/\-]+)`")
MD_LINK = re.compile(r"\]\(([^)#\s]+)\)")

# Generated at runtime, not committed.
GENERATED = re.compile(r"(^|/)BENCH_[\w.-]*\.json$|^experiments/")


def path_refs(text: str):
    for m in BACKTICK.finditer(text):
        tok = m.group(1).split("::")[0]  # strip pytest node ids
        if tok.endswith(EXTS) or (tok.endswith("/") and "/" in tok.rstrip("/")):
            yield tok
    for m in MD_LINK.finditer(text):
        tok = m.group(1)
        if "://" not in tok and not tok.startswith("mailto:"):
            yield tok


def resolves(tok: str, doc_dir: str) -> bool:
    tok = tok.rstrip("/") or tok
    bases = (doc_dir, REPO, os.path.join(REPO, "src", "repro"),
             os.path.join(REPO, "src"))
    return any(os.path.exists(os.path.join(b, tok)) for b in bases)


BENCH_ARTIFACT = re.compile(r"\bBENCH_[\w.-]+?\.json\b")


def bench_producer_gaps(doc: str, text: str) -> list:
    """Every BENCH_*.json artifact named in the benchmarks README must be
    produced by a script in benchmarks/ -- i.e. some .py file there
    mentions the artifact name (default --out value or schema writer)."""
    bench_dir = os.path.join(REPO, "benchmarks")
    scripts = {}
    for fname in sorted(os.listdir(bench_dir)):
        if fname.endswith(".py"):
            with open(os.path.join(bench_dir, fname)) as f:
                scripts[fname] = f.read()
    gaps = []
    for artifact in sorted(set(BENCH_ARTIFACT.findall(text))):
        producers = [s for s, body in scripts.items() if artifact in body]
        if not producers:
            gaps.append(f"{doc}: bench artifact {artifact!r} has no "
                        "producing script under benchmarks/")
    return gaps


def main(argv) -> int:
    docs = argv[1:] or DEFAULT_DOCS
    missing = []
    for doc in docs:
        path = os.path.join(REPO, doc)
        if not os.path.exists(path):
            missing.append(f"{doc}: (document itself is missing)")
            continue
        with open(path) as f:
            text = f.read()
        for tok in path_refs(text):
            if GENERATED.search(tok):
                continue
            if tok.startswith("/"):
                # Absolute paths name the growth environment (e.g. the
                # /root/related/ retrieval set), not repo files -- they are
                # not expected to exist on CI runners.
                continue
            if not resolves(tok, os.path.dirname(path)):
                missing.append(f"{doc}: dangling reference {tok!r}")
        if os.path.normpath(doc) == os.path.join("benchmarks", "README.md"):
            missing.extend(bench_producer_gaps(doc, text))
    if missing:
        print("doc-link check FAILED:")
        for m in missing:
            print(" ", m)
        return 1
    print(f"doc-link check OK ({', '.join(docs)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
