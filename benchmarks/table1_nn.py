"""Paper Table I: 1-hidden-layer (50 neurons, tanh) NN classification
accuracy under attacks, mean vs geomed aggregation (non-convex case).

MNIST is replaced by the synthetic 784-dim 10-class blob set (offline
container); derived metric = test accuracy in [0, 1].
"""
import jax
import jax.numpy as jnp

from repro.core import RobustConfig, make_federated_step
from repro.data import mnist_like, partition
from repro.optim import get_optimizer

from benchmarks import common

WH, B = 10, 4
HIDDEN = 50


def init_params(key, p=784, h=HIDDEN, classes=10):
    k1, k2 = jax.random.split(key)
    return {"w1": 0.05 * jax.random.normal(k1, (p, h)),
            "b1": jnp.zeros((h,)),
            "w2": 0.05 * jax.random.normal(k2, (h, classes)),
            "b2": jnp.zeros((classes,))}


def nn_loss(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32), 1)[:, 0]
    return jnp.mean(lse - tgt)


def accuracy(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    return float(jnp.mean(jnp.argmax(logits, -1) == batch["y"]))


def main(steps: int = 500) -> None:
    key = jax.random.PRNGKey(0)
    train = mnist_like(key, n=1500)
    test = mnist_like(jax.random.fold_in(key, 1), n=500)
    wd = partition({"x": train.x, "y": train.y}, WH, seed=2)
    test_batch = {"x": test.x, "y": test.y}
    for attack in common.ATTACKS:
        b = 0 if attack == "none" else B
        for label, vr, lr in [("SGD", "sgd", 0.1), ("BSGD", "minibatch", 0.5),
                              ("SAGA", "saga", 0.1)]:
            for agg in ("mean", "geomed"):
                cfg = RobustConfig(aggregator=agg, vr=vr, attack=attack,
                                   num_byzantine=b, minibatch_size=20)
                opt = get_optimizer("sgd", lr)
                init_fn, step_fn = make_federated_step(nn_loss, wd, cfg, opt)
                st = init_fn(init_params(jax.random.fold_in(key, 7)),
                             jax.random.PRNGKey(5))
                jstep = jax.jit(step_fn)
                import time
                t0 = time.perf_counter()
                for _ in range(steps):
                    st, _ = jstep(st)
                us = (time.perf_counter() - t0) / steps * 1e6
                common.emit(f"table1/{attack}/{label}-{agg}", us,
                            accuracy(st.params, test_batch))


if __name__ == "__main__":
    main()
