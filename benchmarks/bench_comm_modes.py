"""Gather-vs-sharded aggregation wall-clock comparison (DESIGN.md Sec. 2).

For every (mesh, aggregator, comm mode) combination this times the jitted
shard_map'd aggregation step on synthetic worker gradients and emits
``BENCH_comm_modes.json`` plus a markdown table on stdout.

    PYTHONPATH=src python benchmarks/bench_comm_modes.py [--quick] \\
        [--coords N] [--reps R] [--out BENCH_comm_modes.json]

On this CPU container the 8 forced host devices share one machine, so the
numbers characterize compute + memory-movement volume, not TPU interconnect
latency: ``gather`` runs the full-vector rule redundantly on every device
(O(W * p) work and O(W * p_shard) bytes per device) while ``sharded`` runs
it on a 1/W coordinate slice (O(p) work, O(2 * p_shard) bytes) -- the
ordering between the modes is the scale-independent claim being validated.
Per-device collective-byte estimates from that model are included in the
JSON next to the measured wall-clock (schema: benchmarks/README.md).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# The two lines above MUST run before jax is imported (jax locks the host
# device count at first initialization); if XLA_FLAGS is already set it is
# left alone, so CI / mesh_harness environments keep their own value.

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import AGGREGATOR_NAMES, RobustConfig
from repro.core.robust_step import distributed_aggregate, sharded_aggregate

SCHEMA = "BENCH_comm_modes/v1"

# (label, mesh shape, mesh axes, worker axes) -- both worker-axis layouts
# the federation supports (launch/mesh.py), shrunk to 8 host devices.
MESHES = [
    ("4x2", (4, 2), ("data", "model"), ("data",)),
    ("2x2x2", (2, 2, 2), ("pod", "data", "model"), ("pod", "data")),
]

QUICK_AGGREGATORS = ("geomed", "krum", "geomed_blockwise")


def make_payload(key, num_workers: int, coords: int):
    """Synthetic per-worker gradients: a 3-leaf pytree (two model-sharded
    matrices + a replicated bias) totalling ~``coords`` coordinates."""
    c1 = max(coords // 2 // 8, 8)
    c2 = max(coords // 4 // 8, 8)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wq": jax.random.normal(k1, (num_workers, c1, 8)),
        "wk": jax.random.normal(k2, (num_workers, c2, 8)),
        "bias": jax.random.normal(k3, (num_workers, 128)),
    }


def payload_specs(worker_axes):
    wa = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    return {
        "wq": P(wa, None, "model"),
        "wk": P(wa, None, "model"),
        "bias": P(wa),
    }, {
        "wq": P(None, "model"),
        "wk": P(None, "model"),
        "bias": P(),
    }


def model_bytes_per_device(comm: str, num_workers: int, coords: int,
                           model: int) -> int:
    """Analytic per-device collective volume (f32): the gather master moves
    O(W * p_shard), the sharded master O(2 * p_shard) (all_to_all out +
    all_gather in), ignoring the small per-iteration norm psums."""
    p_shard = coords // model
    if comm == "gather":
        return 4 * num_workers * p_shard
    return 4 * 2 * p_shard


def time_call(fn, args, reps: int) -> dict:
    jax.block_until_ready(fn(*args))  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return {
        "wall_us_mean": sum(times) / len(times) * 1e6,
        "wall_us_min": min(times) * 1e6,
    }


def bench_one(mesh, mesh_axes, worker_axes, name: str, comm: str,
              payload, reps: int) -> dict:
    w = 1
    sizes = dict(zip(mesh_axes, mesh.devices.shape))
    for a in worker_axes:
        w *= sizes[a]
    cfg = RobustConfig(aggregator=name, weiszfeld_iters=32,
                       weiszfeld_tol=1e-9, num_byzantine=1, comm=comm)
    in_specs, out_specs = payload_specs(worker_axes)

    def agg_fn(msgs):
        local = jax.tree_util.tree_map(lambda z: z[0], msgs)
        if comm == "sharded":
            return sharded_aggregate(local, cfg, worker_axes=worker_axes,
                                     model_axes=("model",), num_workers=w)
        return distributed_aggregate(local, cfg, worker_axes=worker_axes,
                                     model_axes=("model",))

    fn = jax.jit(compat.shard_map(
        agg_fn, mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
        check_vma=False))
    return time_call(fn, (payload,), reps)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help=f"only {QUICK_AGGREGATORS} (the CI artifact setting)")
    ap.add_argument("--coords", type=int, default=1 << 16,
                    help="approximate parameter count of the payload")
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--out", default="BENCH_comm_modes.json")
    args = ap.parse_args()

    names = QUICK_AGGREGATORS if args.quick else AGGREGATOR_NAMES
    rows = []
    for label, shape, axes, wa in MESHES:
        mesh = compat.make_mesh(shape, axes)
        sizes = dict(zip(axes, shape))
        w = functools.reduce(lambda a, b: a * b, (sizes[a] for a in wa), 1)
        payload = make_payload(jax.random.PRNGKey(0), w, args.coords)
        coords = sum(int(l[0].size) for l in jax.tree_util.tree_leaves(payload))
        for name in names:
            for comm in ("gather", "sharded"):
                t = bench_one(mesh, axes, wa, name, comm, payload, args.reps)
                rows.append({
                    "mesh": label, "axes": list(axes),
                    "worker_axes": list(wa), "num_workers": w,
                    "aggregator": name, "comm": comm, "coords": coords,
                    "reps": args.reps,
                    "model_bytes_per_device": model_bytes_per_device(
                        comm, w, coords, sizes["model"]),
                    **t,
                })
                print(f"  {label:6s} {name:18s} {comm:8s} "
                      f"{t['wall_us_mean']:10.0f} us/step")

    report = {
        "schema": SCHEMA,
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "coords_requested": args.coords,
        "weiszfeld_iters": 32,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"\nwrote {args.out} ({len(rows)} rows)\n")

    # Markdown summary: gather vs sharded side by side.
    print("| mesh | aggregator | gather us | sharded us | sharded/gather |")
    print("|------|------------|-----------|------------|----------------|")
    by_key = {(r["mesh"], r["aggregator"], r["comm"]): r for r in rows}
    for label, _, _, _ in MESHES:
        for name in names:
            g = by_key[(label, name, "gather")]["wall_us_mean"]
            s = by_key[(label, name, "sharded")]["wall_us_mean"]
            print(f"| {label} | {name} | {g:.0f} | {s:.0f} | {s / g:.2f} |")


if __name__ == "__main__":
    main()
