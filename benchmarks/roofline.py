"""Roofline report: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits per-(arch x shape x mesh) the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and memory per device.

Derived metric in the run.py CSV = dominant-term seconds.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(pattern: str = "*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r) -> str:
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | - | SKIP: {r['skipped']} "
                "| | | | | |")
    ro = r["roofline"]
    mf = r.get("model_flops_total")
    hw = r.get("flops_per_device", 0) * r.get("chips", 1)
    ratio = (mf / hw) if (mf and hw) else 0.0
    mem = r.get("memory", {}).get("total_per_device_gb", float("nan"))
    return ("| {arch} | {shape} | {mesh} | {c:.3f} | {m:.3f} | {k:.3f} | "
            "{dom} | {mem:.1f} | {ratio:.2f} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        c=ro["compute_s"] * 1e3, m=ro["memory_s"] * 1e3,
        k=ro["collective_s"] * 1e3, dom=ro["dominant"].replace("_s", ""),
        mem=mem, ratio=ratio)


def table(recs) -> str:
    head = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
            "| dominant | GB/dev | useful-FLOP ratio |\n"
            "|---|---|---|---|---|---|---|---|---|")
    return "\n".join([head] + [fmt_row(r) for r in recs])


def main() -> None:
    recs = load_records()
    if not recs:
        print("roofline/no-artifacts,0.0,0.0")
        print("# run: PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes")
        return
    for r in recs:
        if "skipped" in r:
            continue
        dom = r["roofline"][r["roofline"]["dominant"]]
        tag = "mp" if r.get("multi_pod") else "sp"
        print(f"roofline/{r['arch']}/{r['shape']}/{tag},{r.get('compile_s',0)*1e6:.0f},{dom:.6f}")


if __name__ == "__main__":
    main()
