"""Shared harness for the paper-figure benchmarks.

Every benchmark is scaled down from the paper (W-B=50+20 workers, 50-580k
samples, 3000+ iterations) to CPU-friendly sizes (25+10 workers, 2k samples,
600 iterations); the claims being validated are orderings between
algorithms, which are scale-independent.  Each run reports
``(us_per_step, final_optimality_gap, honest_variance)``.
"""
from __future__ import annotations

import time
from typing import Iterable

import jax
import jax.numpy as jnp

from repro.core import RobustConfig, make_federated_step
from repro.data import (covtype_like, ijcnn1_like, logreg_full_loss_and_opt,
                        logreg_loss, partition)
from repro.optim import get_optimizer

WH, B = 25, 10          # honest / byzantine (paper: 50 / 20)
STEPS = 600


def build_problem(dataset: str, n: int = 2000, *, replicated: bool = False):
    key = jax.random.PRNGKey(0)
    if replicated:
        # Fig. 5 setting: every worker holds the WHOLE dataset (delta^2 = 0);
        # keep n modest so SAGA's table-refresh period (~J steps) stays
        # within the benchmark budget.
        n = min(n, 400)
    data = ijcnn1_like(key, n) if dataset == "ijcnn1" else covtype_like(key, n)
    loss = logreg_loss(0.01)
    _, f_star = logreg_full_loss_and_opt(data, iters=4000, lr=0.5)
    batch = {"a": data.x, "b": data.y}
    mode = "replicated" if replicated else "iid"
    wd = partition(batch, WH, mode=mode, seed=1)
    return loss, batch, f_star, wd


def run_algorithm(loss, wd, cfg: RobustConfig, lr: float, steps: int = STEPS):
    opt = get_optimizer("sgd", lr)
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    p = jax.tree_util.tree_leaves(wd)[0].shape[-1]
    st = init_fn({"w": jnp.zeros((p,), jnp.float32)}, jax.random.PRNGKey(11))
    jstep = jax.jit(step_fn)
    st, metrics = jstep(st)  # compile
    # perf_counter, not time.time: monotonic and ns-resolution, so µs-scale
    # steps are not swamped by clock quantization or NTP steps.
    t0 = time.perf_counter()
    for _ in range(steps - 1):
        st, metrics = jstep(st)
    jax.block_until_ready(st.params["w"])
    us = (time.perf_counter() - t0) / (steps - 1) * 1e6
    return st, metrics, us


# (algorithm label, vr mode, lr key) -- the paper's three solvers.
ALGOS = [("SGD", "sgd", 0.02), ("BSGD", "minibatch", 0.01), ("SAGA", "saga", 0.02)]
ATTACKS = ["none", "gaussian", "sign_flip", "zero_gradient"]


def emit(name: str, us: float, derived: float) -> None:
    print(f"{name},{us:.1f},{derived:.6f}")
