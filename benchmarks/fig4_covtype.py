"""Paper Fig. 4: same grid as Fig. 3 on COVTYPE-like data (p=54)."""
from benchmarks import fig3_ijcnn1


def main() -> None:
    fig3_ijcnn1.main(dataset="covtype", tag="fig4")


if __name__ == "__main__":
    main()
