"""Paper Fig. 6: distributed SAGA with mean / geomed / median / Krum
(+ our geomed_groups and trimmed_mean) under the 4 attacks."""
from repro.core import RobustConfig

from benchmarks import common

AGGS = ["mean", "geomed", "median", "krum", "trimmed_mean", "geomed_groups"]


def main() -> None:
    loss, batch, f_star, wd = common.build_problem("ijcnn1")
    for attack in common.ATTACKS:
        b = 0 if attack == "none" else common.B
        for agg in AGGS:
            cfg = RobustConfig(aggregator=agg, vr="saga", attack=attack,
                               num_byzantine=b, num_groups=5,
                               trim=min(b, (common.WH + b) // 2 - 1) or 1)
            st, metrics, us = common.run_algorithm(loss, wd, cfg, 0.02)
            gap = float(loss(st.params, batch)) - f_star
            common.emit(f"fig6/{attack}/SAGA-{agg}", us, gap)


if __name__ == "__main__":
    main()
