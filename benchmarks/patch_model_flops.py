"""Re-derive model_flops / params / useful-FLOP ratio for existing dry-run
JSONs (fixes an int32-overflow in early sweeps without recompiling).

    PYTHONPATH=src python -m benchmarks.patch_model_flops
"""
import glob
import json
import math
import os

import jax

from repro.configs import SHAPES, get_config
from repro.models.api import build_model

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def model_flops(arch: str, shape_name: str):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.param_structs()
    n_total = sum(math.prod(p.shape) for p in jax.tree_util.tree_leaves(params))
    n_active = n_total
    if cfg.num_experts:
        pat, periods = cfg.resolve_pattern()
        moe_blocks = sum(1 for b in pat if b.moe) * periods
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        n_active = n_total - moe_blocks * (cfg.num_experts - cfg.top_k) * per_expert
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * tokens, n_total, n_active


def main() -> None:
    cache = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "skipped" in r:
            continue
        key = (r["arch"], r["shape"])
        if key not in cache:
            cache[key] = model_flops(*key)
        mf, n_tot, n_act = cache[key]
        r["model_flops_total"] = mf
        r["params_total"] = n_tot
        r["params_active"] = n_act
        hw = r.get("flops_per_device", 0.0) * r.get("chips", 1)
        r["useful_flops_ratio"] = mf / hw if hw else None
        with open(path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"patched {os.path.basename(path)}: N={n_tot/1e9:.2f}B "
              f"N_act={n_act/1e9:.2f}B ratio={r['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()
