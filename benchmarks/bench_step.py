"""End-to-end train-step wall-clock: flat-packed vs per-leaf hot path.

The DESIGN.md Sec. 8 claim made measurable: for every (aggregator, path)
cell this times the FULL Byzantine-robust training step -- per-worker
grads, variance-reduction correction, attack injection, robust
aggregation, optimizer -- with the flat-packed pipeline
(``RobustConfig.packed=True``, the default) against the pre-refactor
per-leaf pipeline (``packed=False``), and emits ``BENCH_step.json`` plus a
markdown ratio table.  Since schema v2 the sim rows also carry the
resident variance-reduction state bytes, and a saga-vs-lsvrg trade-off
pair at fixed (W, J, D) quantifies the O((J+1)D)-table vs O(2D)-snapshot
memory/step story (DESIGN.md Sec. 9).  Schema v3 adds cohort-size scaling
cells: client-scale virtualization (DESIGN.md Sec. 10) at
``num_clients`` in {64, 256} with the same 16-slot cohort, measuring what
the per-round cohort gather/scatter and staleness weighting cost on top
of the fixed-width aggregation (packed path only -- the per-leaf baseline
has no weighted rules, so the gate ignores these cells).  Schema v4 adds
the robustness characterization grid (DESIGN.md Sec. 12): attack x wire
format x robust rule (``path="grid"`` rows) on the Sec. V-A logreg
federation, each cell reporting the final honest-data loss of a short
Byrd-SAGA run -- the quantized wire formats (int8 per-block scales,
sign1 + error feedback) must keep every rule's error floor, not just
survive attack-free.  Gate keys carry ``message_dtype`` since v4.
Schema v5 adds the fault-containment grid (DESIGN.md Sec. 13):
fault attack (nan / inf_overflow / bitflip) x robust rule x guards
on/off (``path="fault"`` rows), each cell reporting ``loss_finite``
plus the final honest loss when it IS finite -- the in-graph guards
must keep every rule's run finite under faults that destroy the
unguarded step, at the usual wall-clock readout.

    PYTHONPATH=src python benchmarks/bench_step.py [--quick] [--gate] \\
        [--steps N] [--reps R] [--out BENCH_step.json]

Paths:

* ``sim``     -- the single-host simulated federation
  (``make_federated_step``) on a deep-MLP workload with MANY small
  parameter blocks -- the regime the packing targets (per-leaf dispatch
  multiplies kernel launches by num_leaves).
* ``gather`` / ``sharded`` -- the distributed ``make_train_step`` on the
  4x2 host mesh (8 forced devices), reduced mamba2 model.  The sharded
  comm path re-shards by coordinate inside shard_map either way, so its
  packed/per-leaf cells differ only in the attack/packing stage.

``--gate`` turns the run into the STEP-LEVEL PERF GATE (wired into CI with
``--quick``): it fails the job if any cell's packed path is slower than
per-leaf beyond a noise margin (on ``wall_us_min``, the noise-robust
statistic), or if the sim geomed/krum cells -- the aggregation-dominated
ones -- fall short of the 1.3x speedup floor.

Process layout: the sim cells run IN-PROCESS on the natural device count
(one CPU device -- forcing 8 host devices splits the XLA threadpool and
drowns the sim numbers in scheduler noise on small containers), while the
gather/sharded cells run in a SUBPROCESS with 8 forced host devices
(``--distributed-only``), whose rows are merged into the report.
"""
import argparse
import json
import math
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import AGGREGATOR_NAMES, RobustConfig, make_federated_step
from repro.data import ijcnn1_like, partition
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.optim import get_optimizer

SCHEMA = "BENCH_step/v5"

QUICK_AGGREGATORS = ("geomed", "krum", "mean")
# Robustness characterization grid (schema v4, DESIGN.md Sec. 12): every
# (attack, wire format) pair for the three headline robust rules, scored
# by the honest-data loss a short Byrd-SAGA run reaches.
GRID_ATTACKS = ("none", "gaussian", "sign_flip", "straggler")
GRID_DTYPES = ("float32", "bfloat16", "int8", "sign1")
GRID_AGGREGATORS = ("geomed", "krum", "trimmed_mean")
GRID_STEPS = 150
# Fault-containment grid (schema v5, DESIGN.md Sec. 13): the fault
# injections that produce garbage rows rather than adversarial ones, run
# with the in-graph guards off and on.  bitflip_prob is raised well past
# the registry default so the D=22 logreg rows actually take hits.
FAULT_ATTACKS = ("nan", "inf_overflow", "bitflip")
FAULT_BITFLIP_PROB = 0.5
# Cohort-size scaling cells (schema v3): the packed sim geomed/saga step
# with num_clients virtual clients feeding the same 16-slot cohort --
# gather/scatter + staleness weighting cost as C grows past W.
COHORT_CLIENTS = (64, 256)
# The memory/step trade-off cells (schema v2): saga vs lsvrg at the SAME
# (W, J, D) on the sim geomed workload, reporting resident VR-state bytes
# next to wall-clock (the O((J+1)D) table vs O(2D) snapshot story).
VR_TRADEOFF_VRS = ("saga", "lsvrg")
# The gate's speedup floor applies to the aggregation-dominated sim cells
# (vr=saga -- the lsvrg cells are a trade-off readout, not a packing claim).
GATE_SPEEDUP_CELLS = ("geomed", "krum")
GATE_SPEEDUP_FLOOR = 1.3
# "No slower" allows this much wall-clock noise on ~1.0x cells.
GATE_NOISE_MARGIN = 1.15
# The gather/sharded cells time 8 forced XLA host devices time-slicing
# the runner's real cores, so their wall-clock is scheduler-dominated:
# repeated runs of the SAME binary spread the per-cell min statistic by
# ~20% (e.g. gather/geomed per-leaf min 560-662ms across five runs on a
# 1-core container).  They get a correspondingly wider "no slower"
# margin; the tight margin + speedup floor above remain the claims on
# the single-device sim cells, where the measurement is clean.
GATE_DIST_NOISE_MARGIN = 1.35

# Simulated-federation workload: a deep MLP with MANY small parameter
# blocks (34 leaves) -- per-leaf dispatch cost scales with the block count,
# packed cost does not.
MLP_LAYERS, MLP_HIDDEN = 16, 16
SIM_HONEST, SIM_BYZANTINE = 16, 4


def mlp_params(key, din=22, h=MLP_HIDDEN):
    p = {}
    ks = jax.random.split(key, MLP_LAYERS + 1)
    for i in range(MLP_LAYERS):
        p[f"w{i}"] = 0.3 * jax.random.normal(ks[i], (din if i == 0 else h, h))
        p[f"b{i}"] = jnp.zeros((h,))
    p["wout"] = 0.3 * jax.random.normal(ks[-1], (h,))
    p["bout"] = jnp.zeros(())
    return p


def mlp_loss(params, batch):
    x, y = batch["a"], batch["b"]
    for i in range(MLP_LAYERS):
        x = jnp.tanh(x @ params[f"w{i}"] + params[f"b{i}"])
    logit = x @ params["wout"] + params["bout"]
    return jnp.mean(jnp.logaddexp(0.0, -y * logit))


def sim_cfg(name: str, packed: bool, vr: str = "saga",
            num_clients: int = 0) -> RobustConfig:
    return RobustConfig(aggregator=name, vr=vr, attack="sign_flip",
                        num_byzantine=SIM_BYZANTINE, weiszfeld_iters=32,
                        num_groups=4, packed=packed, lsvrg_p=0.05,
                        num_clients=num_clients,
                        cohort_size=SIM_HONEST if num_clients else 0)


def time_steps(jstep, state, step_args, steps: int, reps: int) -> dict:
    """Per-step wall-clock: ``reps`` measurements of ``steps`` steps each
    (state threaded through, so donation works like the real loop)."""
    state = jstep(state, *step_args)[0]  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(steps):
            state, _ = jstep(state, *step_args)
        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        times.append((time.perf_counter() - t0) / steps)
    return {"wall_us_mean": sum(times) / len(times) * 1e6,
            "wall_us_min": min(times) * 1e6}


def bench_sim(name: str, packed: bool, steps: int, reps: int, wd,
              vr: str = "saga", num_clients: int = 0) -> dict:
    cfg = sim_cfg(name, packed, vr, num_clients)
    init_fn, step_fn = make_federated_step(mlp_loss, wd, cfg,
                                           get_optimizer("sgd", 0.05))
    state = init_fn(mlp_params(jax.random.PRNGKey(1)), jax.random.PRNGKey(3))
    # Resident VR-state bytes (the schema-v2 memory column of the saga vs
    # lsvrg trade-off), cross-checked against the reducer's own accounting.
    # Under client-scale virtualization the tables are per CLIENT, so the
    # effective row count is num_clients, not the cohort width.
    vr_leaves = jax.tree_util.tree_leaves(state.vr)
    vr_bytes = sum(int(l.size) * l.dtype.itemsize for l in vr_leaves)
    p = mlp_params(jax.random.PRNGKey(1))
    coords = sum(int(x.size) for x in jax.tree_util.tree_leaves(p))
    j = jax.tree_util.tree_leaves(wd)[0].shape[1]
    expect = cfg.reducer().memory_elems(num_clients or SIM_HONEST, j, coords)
    got = sum(int(l.size) for l in vr_leaves)
    assert got == expect, f"memory_elems drift for {vr}: {got} != {expect}"
    jstep = steps_lib.compile_train_step(step_fn)
    t = time_steps(jstep, state, (), steps, reps)
    return {
        "path": "sim", "aggregator": name, "packed": packed,
        "num_workers": SIM_HONEST + SIM_BYZANTINE,
        "num_byzantine": SIM_BYZANTINE, "vr": cfg.vr, "attack": cfg.attack,
        "num_samples": j, "vr_state_bytes": vr_bytes,
        "num_clients": num_clients, "message_dtype": cfg.message_dtype,
        "leaves": len(jax.tree_util.tree_leaves(p)),
        "coords": coords,
        "steps": steps, "reps": reps, **t,
    }


def bench_distributed(name: str, comm: str, packed: bool, steps: int,
                      reps: int, dist) -> dict:
    from repro.configs.base import TrainConfig
    from repro.launch.train import make_batch
    model, mesh, cfg_model = dist
    robust = RobustConfig(aggregator=name, vr="sgd", attack="sign_flip",
                          num_byzantine=1, comm=comm, weiszfeld_iters=16,
                          num_groups=2, packed=packed)
    step_fn, _, _ = steps_lib.make_train_step(
        model, robust, TrainConfig(optimizer="sgd", lr=0.05), mesh)
    with compat.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": (),
                 "step": jnp.zeros((), jnp.int32)}
        batch = make_batch(jax.random.PRNGKey(5), cfg_model, 4, 1, 32)
        jstep = steps_lib.compile_train_step(step_fn)
        t = time_steps(jstep, state, (batch, jax.random.PRNGKey(9)),
                       steps, reps)
    leaves = jax.tree_util.tree_leaves(model.param_structs())
    return {
        "path": comm, "aggregator": name, "packed": packed,
        "num_workers": 4, "num_byzantine": 1, "vr": "sgd",
        "vr_state_bytes": 0, "message_dtype": robust.message_dtype,
        "attack": "sign_flip", "leaves": len(leaves),
        "coords": sum(math.prod(s.shape) for s in leaves),
        "steps": steps, "reps": reps, **t,
    }


def bench_grid(wd, batch, steps: int = GRID_STEPS) -> list:
    """The schema-v4 robustness grid: attack x wire format x rule cells on
    the Sec. V-A logreg federation (SIM_HONEST honest + SIM_BYZANTINE
    Byzantine when the attack is live), each reporting the honest-data
    loss after ``steps`` Byrd-SAGA steps plus the usual wall-clock."""
    from repro.data import logreg_loss
    loss = logreg_loss(0.01)
    j = jax.tree_util.tree_leaves(wd)[0].shape[1]
    rows = []
    for name in GRID_AGGREGATORS:
        for attack in GRID_ATTACKS:
            nb = 0 if attack == "none" else SIM_BYZANTINE
            for dtype in GRID_DTYPES:
                cfg = RobustConfig(aggregator=name, vr="saga", attack=attack,
                                   num_byzantine=nb, weiszfeld_iters=32,
                                   trim=SIM_BYZANTINE, straggler_k=4,
                                   message_dtype=dtype)
                init_fn, step_fn = make_federated_step(
                    loss, wd, cfg, get_optimizer("sgd", 0.05))
                # Fresh params per cell: the compiled step DONATES its
                # state, so a shared init tree would be a dead buffer by
                # the second cell.
                state = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                                jax.random.PRNGKey(3))
                jstep = steps_lib.compile_train_step(step_fn)
                state = jstep(state)[0]          # compile + warm
                t0 = time.perf_counter()
                for _ in range(steps):
                    state, _ = jstep(state)
                jax.block_until_ready(state.params["w"])
                wall_us = (time.perf_counter() - t0) / steps * 1e6
                final = float(loss(state.params, batch))
                rows.append({
                    "path": "grid", "aggregator": name, "packed": True,
                    "num_workers": SIM_HONEST + nb, "num_byzantine": nb,
                    "vr": cfg.vr, "attack": attack, "message_dtype": dtype,
                    "vr_state_bytes": sum(
                        int(l.size) * l.dtype.itemsize
                        for l in jax.tree_util.tree_leaves(state.vr)),
                    "num_samples": j, "num_clients": 0,
                    "leaves": 1, "coords": 22, "steps": steps, "reps": 1,
                    "wall_us_mean": wall_us, "wall_us_min": wall_us,
                    "final_honest_loss": final,
                })
                print(f"  grid    {name:14s} {attack:10s} {dtype:9s} "
                      f"loss={final:.4f}")
    return rows


def bench_fault(wd, batch, steps: int = GRID_STEPS) -> list:
    """The schema-v5 fault-containment grid: fault x rule x guards cells on
    the same logreg federation as :func:`bench_grid`.  Guards-on runs must
    stay finite (the poisoned rows get aggregation weight exactly 0);
    guards-off nan runs go non-finite, which the row records as
    ``loss_finite`` instead of a NaN loss value the schema checker (and
    JSON) cannot represent."""
    import math as _math

    from repro.data import logreg_loss
    loss = logreg_loss(0.01)
    j = jax.tree_util.tree_leaves(wd)[0].shape[1]
    rows = []
    for name in GRID_AGGREGATORS:
        for attack in FAULT_ATTACKS:
            for guards in (False, True):
                cfg = RobustConfig(aggregator=name, vr="saga", attack=attack,
                                   num_byzantine=SIM_BYZANTINE,
                                   weiszfeld_iters=32, trim=SIM_BYZANTINE,
                                   bitflip_prob=FAULT_BITFLIP_PROB,
                                   guards=guards)
                init_fn, step_fn = make_federated_step(
                    loss, wd, cfg, get_optimizer("sgd", 0.05))
                state = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                                jax.random.PRNGKey(3))
                jstep = steps_lib.compile_train_step(step_fn)
                state = jstep(state)[0]          # compile + warm
                t0 = time.perf_counter()
                for _ in range(steps):
                    state, _ = jstep(state)
                jax.block_until_ready(state.params["w"])
                wall_us = (time.perf_counter() - t0) / steps * 1e6
                final = float(loss(state.params, batch))
                finite = _math.isfinite(final)
                row = {
                    "path": "fault", "aggregator": name, "packed": True,
                    "num_workers": SIM_HONEST + SIM_BYZANTINE,
                    "num_byzantine": SIM_BYZANTINE, "vr": cfg.vr,
                    "attack": attack, "message_dtype": cfg.message_dtype,
                    "guards": guards, "loss_finite": finite,
                    "vr_state_bytes": sum(
                        int(l.size) * l.dtype.itemsize
                        for l in jax.tree_util.tree_leaves(state.vr)),
                    "num_samples": j, "num_clients": 0,
                    "leaves": 1, "coords": 22, "steps": steps, "reps": 1,
                    "wall_us_mean": wall_us, "wall_us_min": wall_us,
                }
                if finite:
                    row["final_honest_loss"] = final
                rows.append(row)
                print(f"  fault   {name:14s} {attack:12s} "
                      f"guards={guards!s:5s} loss="
                      f"{final if finite else float('nan'):.4f}")
    return rows


def run_gate(rows) -> list:
    """The step-level perf gate: packed must never lose beyond noise, and
    must beat the floor on the aggregation-dominated sim cells.  Gates on
    ``wall_us_min`` -- the minimum over reps is the standard noise-robust
    microbenchmark statistic (scheduler interference only ever ADDS
    time).  Cells are keyed by (path, aggregator, vr, num_clients,
    message_dtype, packed) since v4 (the lsvrg trade-off, cohort-scaling
    and wire-format cells must not collide with the saga sweep); the
    speedup floor stays a vr=saga f32 full-participation claim, and the
    packed-only cohort/grid cells have no per-leaf pair so the gate skips
    them."""
    by_key = {(r["path"], r["aggregator"], r["vr"], r.get("num_clients", 0),
               r.get("message_dtype", "float32"), r["packed"]):
              r["wall_us_min"] for r in rows}
    failures = []
    for (path, name, vr, nc, dtype, packed), us in sorted(by_key.items()):
        if packed:
            continue
        packed_us = by_key.get((path, name, vr, nc, dtype, True))
        if packed_us is None:
            continue
        ratio = us / packed_us
        margin = GATE_NOISE_MARGIN if path == "sim" else GATE_DIST_NOISE_MARGIN
        if packed_us > us * margin:
            failures.append(
                f"{path}/{name}/{vr}: packed {packed_us:.0f}us is slower "
                f"than per-leaf {us:.0f}us beyond the "
                f"{margin}x margin")
        if path == "sim" and vr == "saga" and nc == 0 \
                and dtype == "float32" \
                and name in GATE_SPEEDUP_CELLS \
                and ratio < GATE_SPEEDUP_FLOOR:
            failures.append(
                f"sim/{name}/{vr}: packed speedup {ratio:.2f}x is below "
                f"the {GATE_SPEEDUP_FLOOR}x floor")
    return failures


def distributed_rows(names, steps: int, reps: int) -> list:
    from repro.configs import get_config
    from repro.models.api import build_model
    cfg_model = get_config("mamba2-130m").reduced()
    mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
    model = build_model(cfg_model, remat=False, q_chunk=32, kv_chunk=32,
                        loss_chunk=32)
    dist = (model, mesh, cfg_model)
    rows = []
    for name in names:
        for comm in ("gather", "sharded"):
            for packed in (False, True):
                r = bench_distributed(name, comm, packed,
                                      max(steps // 5, 2), reps, dist)
                rows.append(r)
                print(f"  {comm:7s} {name:18s} packed={packed!s:5s} "
                      f"{r['wall_us_mean']:10.0f} us/step")
    return rows


def spawn_distributed(args) -> list:
    """Run the gather/sharded cells in a child process with 8 forced host
    devices (the parent keeps its natural single device for the sim
    cells), and merge its rows."""
    out = tempfile.NamedTemporaryFile(suffix=".json", delete=False)
    out.close()
    cmd = [sys.executable, os.path.abspath(__file__), "--distributed-only",
           "--steps", str(args.steps), "--reps", str(args.reps),
           "--out", out.name]
    if args.quick:
        cmd.append("--quick")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    subprocess.run(cmd, check=True, env=env)
    with open(out.name) as f:
        rows = json.load(f)["rows"]
    os.unlink(out.name)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help=f"only {QUICK_AGGREGATORS} (the CI artifact setting)")
    ap.add_argument("--gate", action="store_true",
                    help="fail (exit 1) on packed-path perf regressions")
    ap.add_argument("--steps", type=int, default=30,
                    help="steps per timing rep (sim; distributed uses 1/5)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--skip-distributed", action="store_true",
                    help="simulation cells only (no 8-device mesh)")
    ap.add_argument("--distributed-only", action="store_true",
                    help="(internal) gather/sharded cells; needs >= 8 "
                    "devices (XLA_FLAGS=--xla_force_host_platform_device_"
                    "count=8)")
    ap.add_argument("--out", default="BENCH_step.json")
    args = ap.parse_args()

    names = QUICK_AGGREGATORS if args.quick else AGGREGATOR_NAMES
    rows = []
    if args.distributed_only:
        if jax.device_count() < 8:
            raise SystemExit(
                "--distributed-only needs 8 devices; set XLA_FLAGS="
                "--xla_force_host_platform_device_count=8 before jax init")
        rows += distributed_rows(names, args.steps, args.reps)
    else:
        data = ijcnn1_like(jax.random.PRNGKey(0), n=400)
        wd = partition({"a": data.x, "b": data.y}, SIM_HONEST, seed=1)
        for name in names:
            for packed in (False, True):
                r = bench_sim(name, packed, args.steps, args.reps, wd)
                rows.append(r)
                print(f"  sim     {name:18s} packed={packed!s:5s} "
                      f"{r['wall_us_mean']:10.0f} us/step")
        # Memory/step trade-off cells (v2): lsvrg on the geomed workload at
        # the same (W, J, D) as the saga sweep above -- BENCH_step.json then
        # holds both VRs' resident state bytes and wall-clock side by side.
        for packed in (False, True):
            r = bench_sim("geomed", packed, args.steps, args.reps, wd,
                          vr="lsvrg")
            rows.append(r)
            print(f"  sim     geomed/lsvrg      packed={packed!s:5s} "
                  f"{r['wall_us_mean']:10.0f} us/step "
                  f"(state {r['vr_state_bytes']} B)")
        # Cohort-size scaling cells (v3): client-scale virtualization on
        # the packed geomed/saga workload -- C virtual clients, 16-slot
        # cohort.  Packed only: staleness row_weights route every rule
        # through the flat engines, so there is no per-leaf pair.
        for n_clients in COHORT_CLIENTS:
            cwd = partition({"a": data.x, "b": data.y}, n_clients, seed=1)
            r = bench_sim("geomed", True, args.steps, args.reps, cwd,
                          num_clients=n_clients)
            rows.append(r)
            print(f"  sim     geomed/C={n_clients:<5d}    packed=True  "
                  f"{r['wall_us_mean']:10.0f} us/step "
                  f"(state {r['vr_state_bytes']} B)")
        # Robustness grid cells (v4): attack x wire format x rule.
        rows += bench_grid(wd, {"a": data.x, "b": data.y},
                           steps=GRID_STEPS if not args.quick else 100)
        # Fault-containment cells (v5): fault x rule x guards.
        rows += bench_fault(wd, {"a": data.x, "b": data.y},
                            steps=GRID_STEPS if not args.quick else 100)
        if not args.skip_distributed:
            rows += spawn_distributed(args)

    report = {
        "schema": SCHEMA,
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "sim_workers": [SIM_HONEST, SIM_BYZANTINE],
        "gate": {"speedup_cells": list(GATE_SPEEDUP_CELLS),
                 "speedup_floor": GATE_SPEEDUP_FLOOR,
                 "noise_margin": GATE_NOISE_MARGIN,
                 "dist_noise_margin": GATE_DIST_NOISE_MARGIN,
                 "keyed_by": ["path", "aggregator", "vr", "num_clients",
                              "message_dtype", "packed"]},
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"\nwrote {args.out} ({len(rows)} rows)\n")

    print("| path | aggregator | vr | per-leaf us | packed us | speedup | state bytes |")
    print("|------|------------|----|-------------|-----------|---------|-------------|")
    by_key = {(r["path"], r["aggregator"], r["vr"],
               r.get("num_clients", 0), r["packed"]): r
              for r in rows if r["path"] not in ("grid", "fault")}
    for (path, name, vr, nc, packed), r in sorted(by_key.items()):
        if packed:
            continue
        pk = by_key[(path, name, vr, nc, True)]
        print(f"| {path} | {name} | {vr} | {r['wall_us_mean']:.0f} | "
              f"{pk['wall_us_mean']:.0f} | "
              f"{r['wall_us_mean'] / pk['wall_us_mean']:.2f}x | "
              f"{pk.get('vr_state_bytes', 0)} |")
    cohort = sorted((k, r) for k, r in by_key.items() if k[3])
    if cohort:
        print("\n| clients | cohort | packed us | state bytes |")
        print("|---------|--------|-----------|-------------|")
        for (path, name, vr, nc, packed), r in cohort:
            print(f"| {nc} | {SIM_HONEST} | {r['wall_us_mean']:.0f} | "
                  f"{r['vr_state_bytes']} |")
    grid = [r for r in rows if r["path"] == "grid"]
    if grid:
        print("\n| aggregator | attack | " +
              " | ".join(GRID_DTYPES) + " |  (final honest loss)")
        print("|------------|--------|" + "----|" * len(GRID_DTYPES))
        cell = {(r["aggregator"], r["attack"], r["message_dtype"]):
                r["final_honest_loss"] for r in grid}
        for name in GRID_AGGREGATORS:
            for attack in GRID_ATTACKS:
                vals = " | ".join(f"{cell[(name, attack, d)]:.4f}"
                                  for d in GRID_DTYPES)
                print(f"| {name} | {attack} | {vals} |")

    fault = [r for r in rows if r["path"] == "fault"]
    if fault:
        print("\n| aggregator | fault | guards off | guards on |"
              "  (final honest loss; -- = non-finite)")
        print("|------------|-------|------------|-----------|")
        cell = {(r["aggregator"], r["attack"], r["guards"]):
                (f"{r['final_honest_loss']:.4f}" if r["loss_finite"]
                 else "--") for r in fault}
        for name in GRID_AGGREGATORS:
            for attack in FAULT_ATTACKS:
                print(f"| {name} | {attack} | {cell[(name, attack, False)]} "
                      f"| {cell[(name, attack, True)]} |")

    if args.gate:
        failures = run_gate(rows)
        if failures and not args.distributed_only:
            # Up to two retry rounds for failing cells: on a loaded small
            # container a background burst during either side's timing
            # window can fake a regression; fresh measurements settle it
            # (min-across-runs -- scheduler interference only ever ADDS
            # time, so the min converges while a TRUE regression keeps
            # failing every round).  Sim cells re-time just the failing
            # pairs in-process; a distributed failure re-spawns the
            # 8-device subprocess (its cells are the noisiest -- eight
            # forced host devices time-slice the real cores, so a single
            # scheduler burst skews one side of a pair by 20%+).  The
            # retried rows are folded back into the report and the JSON
            # is re-dumped, so the uploaded artifact always matches the
            # gate verdict.
            retried = False

            def fold(fresh_rows):
                nonlocal retried
                fresh_by_key = {
                    (f["path"], f["aggregator"], f["vr"],
                     f.get("num_clients", 0),
                     f.get("message_dtype", "float32"), f["packed"]): f
                    for f in fresh_rows}
                for r in rows:
                    fresh = fresh_by_key.get(
                        (r["path"], r["aggregator"], r["vr"],
                         r.get("num_clients", 0),
                         r.get("message_dtype", "float32"), r["packed"]))
                    if fresh and fresh["wall_us_min"] < r["wall_us_min"]:
                        r.update(wall_us_min=fresh["wall_us_min"],
                                 wall_us_mean=fresh["wall_us_mean"])
                        retried = True

            for _ in range(2):
                failing = {tuple(f.split(":")[0].split("/"))
                           for f in failures}             # (path, name, vr)
                for path, name, vr in sorted(failing):
                    if path != "sim":
                        continue
                    # 3x reps on retry: the sim cells are ms-scale, so
                    # extra samples are nearly free and min-of-more-reps
                    # is the stronger form of the same noise-floor
                    # statistic.
                    fold([bench_sim(name, packed, args.steps,
                                    args.reps * 3, wd, vr=vr)
                          for packed in (False, True)])
                if any(p in ("gather", "sharded") for p, _, _ in failing):
                    fold(spawn_distributed(args))
                failures = run_gate(rows)
                if not failures:
                    break
            if retried:
                with open(args.out, "w") as f:
                    json.dump(report, f, indent=1)
                print(f"rewrote {args.out} with retried cells")
        if failures:
            print("\nSTEP PERF GATE FAILED:")
            for fmsg in failures:
                print(" ", fmsg)
            raise SystemExit(1)
        print("\nstep perf gate OK")


if __name__ == "__main__":
    main()
