"""Regenerate the EXPERIMENTS.md data tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.make_experiments_tables > experiments/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")

ARCH_ORDER = ["mamba2-130m", "qwen2-moe-a2.7b", "qwen2-7b", "nemotron-4-340b",
              "whisper-tiny", "mixtral-8x22b", "jamba-v0.1-52b",
              "mistral-large-123b", "command-r-plus-104b", "paligemma-3b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag_filter=None):
    recs = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        tag = parts[3] if len(parts) > 3 else ""
        if (tag_filter or "") != tag:
            continue
        with open(path) as f:
            r = json.load(f)
        mesh = parts[2] if len(parts) > 2 else ("2x16x16" if r.get("multi_pod") else "16x16")
        recs[(r["arch"], r["shape"], mesh)] = r
    return recs


def roofline_table(recs, mesh="16x16") -> list[str]:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant "
        "| GB/dev | useful-FLOP ratio | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | MISSING | | | |")
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | N/A ({r['skipped'][:40]}…) | | | |")
                continue
            ro = r["roofline"]
            ratio = r.get("useful_flops_ratio") or 0
            mem = r.get("memory", {}).get("total_per_device_gb", float("nan"))
            lines.append(
                "| {a} | {s} | {c:.2f} | {m:.2f} | {k:.2f} | **{d}** | {gb:.1f} | {ra:.2f} | {cs:.0f} |".format(
                    a=arch, s=shape, c=ro["compute_s"] * 1e3,
                    m=ro["memory_s"] * 1e3, k=ro["collective_s"] * 1e3,
                    d=ro["dominant"].replace("_s", ""), gb=mem, ra=ratio,
                    cs=r.get("compile_s", 0)))
    return lines


def multipod_table(recs) -> list[str]:
    lines = ["| arch | shape | lower+compile s | GB/dev | collective GB/dev | status |",
             "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "2x16x16"))
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | MISSING |")
            elif "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | N/A (skip) |")
            else:
                mem = r.get("memory", {}).get("total_per_device_gb", float("nan"))
                cb = r.get("collectives", {}).get("total", 0) / 1e9
                lines.append(
                    f"| {arch} | {shape} | {r.get('lower_s',0)+r.get('compile_s',0):.0f} "
                    f"| {mem:.1f} | {cb:.2f} | compiled |")
    return lines


def main() -> None:
    recs = load()
    print("### Single-pod (16x16, 256 chips) baseline roofline\n")
    print("\n".join(roofline_table(recs)))
    print("\n### Multi-pod (2x16x16, 512 chips) dry-run\n")
    print("\n".join(multipod_table(recs)))


if __name__ == "__main__":
    main()
