"""Paper Fig. 3: SGD / BSGD / SAGA x {mean, geomed} x 4 attacks on
IJCNN1-like data.  Derived metric = final optimality gap f(x)-f(x*)."""
from repro.core import RobustConfig

from benchmarks import common


def main(dataset="ijcnn1", tag="fig3") -> None:
    loss, batch, f_star, wd = common.build_problem(dataset)
    for attack in common.ATTACKS:
        for label, vr, lr in common.ALGOS:
            for agg in ("mean", "geomed"):
                cfg = RobustConfig(
                    aggregator=agg, vr=vr, attack=attack,
                    num_byzantine=0 if attack == "none" else common.B,
                    minibatch_size=50)
                st, metrics, us = common.run_algorithm(loss, wd, cfg, lr)
                gap = float(loss(st.params, batch)) - f_star
                common.emit(f"{tag}/{attack}/{label}-{agg}", us, gap)


if __name__ == "__main__":
    main()
