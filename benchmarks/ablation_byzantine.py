"""Beyond-figure ablations tied to the paper's THEORY:

* ``byz_fraction`` — asymptotic error vs number of Byzantine workers B.
  Thm 1: Delta_2 ~ C_alpha^2 with C_alpha = (2-2a)/(1-2a), a = B/W —
  monotonically increasing in B and exploding as B -> W/2.  We sweep B and
  check the measured optimality gap is (weakly) increasing and finite below
  W/2 while mean aggregation fails already at B=1.

* ``weiszfeld_eps`` — asymptotic error vs Weiszfeld iteration budget
  (Remark 1 / the eps^2/(W-2B)^2 term of Delta_2): crude geomed
  approximations inflate the error floor; a handful of iterations suffice.

Derived metric = final optimality gap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_full_loss_and_opt, logreg_loss, partition
from repro.optim import get_optimizer

from benchmarks import common

WH = 20
STEPS = 500


def _problem():
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=1600)
    loss = logreg_loss(0.01)
    _, f_star = logreg_full_loss_and_opt(data)
    batch = {"a": data.x, "b": data.y}
    wd = partition(batch, WH, seed=1)
    return loss, batch, f_star, wd


def _gap(loss, batch, f_star, wd, cfg, lr=0.02):
    opt = get_optimizer("sgd", lr)
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(4))
    jstep = jax.jit(step_fn)
    for _ in range(STEPS):
        st, _ = jstep(st)
    return float(loss(st.params, batch)) - f_star


def byz_fraction() -> None:
    loss, batch, f_star, wd = _problem()
    for b in (0, 1, 4, 8, 12, 16):   # W = 20 + b; b=16 -> alpha=0.44 < 1/2
        cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                           num_byzantine=b)
        common.emit(f"ablation/byz_fraction/geomed/B{b}", 0.0,
                    _gap(loss, batch, f_star, wd, cfg))
    cfg = RobustConfig(aggregator="mean", vr="saga", attack="sign_flip",
                       num_byzantine=1)
    common.emit("ablation/byz_fraction/mean/B1", 0.0,
                _gap(loss, batch, f_star, wd, cfg))


def weiszfeld_eps() -> None:
    loss, batch, f_star, wd = _problem()
    for iters in (1, 2, 4, 8, 32):
        cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                           num_byzantine=8, weiszfeld_iters=iters,
                           weiszfeld_tol=0.0)
        common.emit(f"ablation/weiszfeld_iters/{iters}", 0.0,
                    _gap(loss, batch, f_star, wd, cfg))


def main() -> None:
    byz_fraction()
    weiszfeld_eps()


if __name__ == "__main__":
    main()
