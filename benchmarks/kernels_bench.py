"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock numbers characterize the *oracle-equivalence harness*, not TPU
performance; the derived metric therefore reports the structural quantity
that matters on TPU -- the arithmetic intensity (FLOPs per HBM byte) of the
fused kernel vs its unfused reference, which determines the roofline
position of the aggregation step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def main() -> None:
    key = jax.random.PRNGKey(0)
    w, p = 32, 65536
    z = jax.random.normal(key, (w, p))
    y = jnp.mean(z, axis=0)

    us = _time(ops.weiszfeld_step, z, y)
    # Fused Weiszfeld pass: reads W*p once per sub-kernel (2 sweeps), writes p.
    flops = 4 * w * p          # sub, mul, add (dist) + weighted sum
    bytes_moved = (2 * w * p + 2 * p) * 4
    print(f"kernel/weiszfeld_step/W{w}xP{p},{us:.1f},{flops/bytes_moved:.4f}")
    us_ref = _time(jax.jit(ref.weiszfeld_step), z, y)
    # Unfused reference: residual matrix materialized (3 extra W*p sweeps).
    bytes_ref = (5 * w * p + 2 * p) * 4
    print(f"kernel/weiszfeld_step_ref/W{w}xP{p},{us_ref:.1f},{flops/bytes_ref:.4f}")

    j = 16
    table = jax.random.normal(key, (j, p))
    grad = jax.random.normal(key, (p,))
    avg = jnp.mean(table, axis=0)
    idx = jnp.asarray(3, jnp.int32)
    us = _time(ops.saga_correct, grad, table, avg, idx)
    flops = 4 * p
    bytes_fused = 6 * p * 4          # read g, row, avg; write msg, avg, row
    print(f"kernel/saga_correct/J{j}xP{p},{us:.1f},{flops/bytes_fused:.4f}")
    us_ref = _time(jax.jit(lambda *a: ref.saga_correct(*a)), grad, table, avg, idx)
    bytes_unfused = (6 * p + 2 * j * p) * 4  # + full-table scatter copy
    print(f"kernel/saga_correct_ref/J{j}xP{p},{us_ref:.1f},{flops/bytes_unfused:.4f}")

    us = _time(ops.coordinate_median, z)
    print(f"kernel/coordinate_median/W{w}xP{p},{us:.1f},{(w*jnp.log2(w)*p)/(w*p*4+p*4):.4f}")


if __name__ == "__main__":
    main()
