"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock numbers characterize the *oracle-equivalence harness*, not TPU
performance; the derived metric therefore reports the structural quantity
that matters on TPU -- the arithmetic intensity (FLOPs per HBM byte) of the
fused kernel vs its unfused reference, which determines the roofline
position of the aggregation step.  The intensity report is parametrized
over the message element size (f32 and bf16 wires -- the
``message_dtype="bfloat16"`` packing mode of DESIGN.md Sec. 8 halves every
byte term while the FLOPs stay f32-accumulated, doubling intensity).

Timing uses ``time.perf_counter`` (monotonic, ns resolution) -- never
``time.time``, whose wall-clock can step under NTP and only guarantees
~µs-scale resolution, the same magnitude as one fused kernel call.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

# Wire element sizes the intensity report is parametrized over: the f32
# baseline and the bf16 packed-message mode (DESIGN.md Sec. 8).
ELEMENT_SIZES = {"f32": 4, "bf16": 2}


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _emit_intensity(name: str, us: float, flops: float,
                    elems_moved: float, fixed_f32_elems: float = 0.0) -> None:
    """One CSV row per wire dtype: ``elems_moved`` scale with the message
    element size; ``fixed_f32_elems`` (accumulators, f32 outputs) do not."""
    for tag, esize in ELEMENT_SIZES.items():
        bytes_moved = elems_moved * esize + fixed_f32_elems * 4
        print(f"kernel/{name}/{tag},{us:.1f},{flops/bytes_moved:.4f}")


def main() -> None:
    key = jax.random.PRNGKey(0)
    w, p = 32, 65536
    z = jax.random.normal(key, (w, p))
    y = jnp.mean(z, axis=0)

    us = _time(ops.weiszfeld_step, z, y)
    # Fused Weiszfeld pass: reads W*p once per sub-kernel (2 sweeps of the
    # message matrix), writes the p-dim f32 iterate.
    flops = 4 * w * p          # sub, mul, add (dist) + weighted sum
    _emit_intensity(f"weiszfeld_step/W{w}xP{p}", us,
                    flops, elems_moved=2 * w * p, fixed_f32_elems=2 * p)
    us_ref = _time(jax.jit(ref.weiszfeld_step), z, y)
    # Unfused reference: residual matrix materialized (3 extra W*p sweeps).
    _emit_intensity(f"weiszfeld_step_ref/W{w}xP{p}", us_ref,
                    flops, elems_moved=5 * w * p, fixed_f32_elems=2 * p)

    j = 16
    table = jax.random.normal(key, (j, p))
    grad = jax.random.normal(key, (p,))
    avg = jnp.mean(table, axis=0)
    idx = jnp.asarray(3, jnp.int32)
    us = _time(ops.saga_correct, grad, table, avg, idx)
    flops = 4 * p
    # read g, row, avg; write msg, avg, row
    _emit_intensity(f"saga_correct/J{j}xP{p}", us, flops, elems_moved=6 * p)
    us_ref = _time(jax.jit(lambda *a: ref.saga_correct(*a)), grad, table, avg, idx)
    # + full-table scatter copy
    _emit_intensity(f"saga_correct_ref/J{j}xP{p}", us_ref, flops,
                    elems_moved=6 * p + 2 * j * p)

    us = _time(ops.coordinate_median, z)
    _emit_intensity(f"coordinate_median/W{w}xP{p}", us,
                    flops=float(w * jnp.log2(w) * p),
                    elems_moved=w * p, fixed_f32_elems=p)


if __name__ == "__main__":
    main()
