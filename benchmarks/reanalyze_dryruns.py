"""Recompute roofline terms for existing dry-run JSONs from the analytic
cost model (and reparse collectives from archived HLO when present) WITHOUT
recompiling.

    PYTHONPATH=src python -m benchmarks.reanalyze_dryruns
"""
import glob
import gzip
import json
import os

from repro.launch import hlo_analysis
from repro.launch.dryrun import attach_roofline

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def main() -> None:
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "skipped" in r:
            continue
        hlo = path[:-5] + ".hlo.gz"
        if os.path.exists(hlo):
            with gzip.open(hlo, "rt") as hf:
                r["collectives"] = hlo_analysis.collective_bytes(hf.read())
        attach_roofline(r)
        with open(path, "w") as f:
            json.dump(r, f, indent=1)
        ro = r["roofline"]
        print(f"{os.path.basename(path)[:-5]:58s} "
              f"c={ro['compute_s']*1e3:9.2f}ms m={ro['memory_s']*1e3:9.2f}ms "
              f"k={ro['collective_s']*1e3:9.2f}ms dom={ro['dominant']}")


if __name__ == "__main__":
    main()
