"""Topology x aggregator x attack x gossip x schedule sweep for
decentralized training.

For every cell this runs the simulated decentralized federation
(``repro.topology.make_decentralized_step``, DESIGN.md Secs. 6-7) on the
paper's logistic-regression workload, times the jitted per-step wall-clock,
and records the final mean honest loss plus the honest consensus distance.
Two grids are swept:

* the PR-3 fixed-graph grid: (topology, aggregator, attack) with gradient
  gossip on a static schedule;
* the gossip grid: (gossip mode x graph schedule) -- gradient vs PARAMETER
  gossip on static / cyclic / per-round-resampled erdos_renyi graphs,
  geomed under sign_flip (the arXiv:2308.05292 setting).

Emits ``BENCH_topologies.json`` and a markdown table on stdout; any cell
that RAISES aborts the script with a non-zero exit, which is exactly how CI
uses it (a registry aggregator or gossip mode that stops working on some
graph/schedule fails the job, not just a test marker).

    PYTHONPATH=src python benchmarks/bench_topologies.py [--quick] \\
        [--steps N] [--reps R] [--out BENCH_topologies.json]

``--quick`` (the CI artifact setting) restricts to the structurally
distinct corners: {ring, complete} x {geomed, krum, mean} x {none,
sign_flip}, plus both gossip modes on {static, erdos_renyi} schedules.
The full sweep covers every registry aggregator on ring / torus2d /
complete / erdos_renyi under none / sign_flip / alie, and both gossip
modes on all three schedules.

Reading the numbers: the star-free claims being validated are orderings --
robust rules keep the final loss near the attack-free value on every
connected graph while ``mean`` degrades, consensus distance shrinks as the
(joint) spectral gap grows, and parameter gossip tracks gradient gossip's
error floor under attack.  Wall-clock on this CPU container characterizes
the dense (N, N, p) exchange + masked-rule compute, not network latency.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AGGREGATOR_NAMES, RobustConfig, make_federated_step
from repro.core.robust_step import resolve_schedule
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer

SCHEMA = "BENCH_topologies/v2"

HONEST, BYZ = 10, 2
TOPOLOGIES = ("ring", "torus2d", "complete", "erdos_renyi")
ATTACKS = ("none", "sign_flip", "alie")

QUICK_TOPOLOGIES = ("ring", "complete")
QUICK_AGGREGATORS = ("geomed", "krum", "mean")
QUICK_ATTACKS = ("none", "sign_flip")

# The gossip grid: (gossip mode x schedule) cells.  "cyclic" rotates the
# named list; "erdos_renyi" resamples per round (period below).
GOSSIP_MODES = ("gradient", "params")
SCHEDULES = ("static", "cyclic", "erdos_renyi")
QUICK_SCHEDULES = ("static", "erdos_renyi")
SCHEDULE_PERIOD = 3
SCHEDULE_TOPOLOGY = {"static": "ring", "cyclic": "ring,complete",
                     "erdos_renyi": "ring"}


def bench_cell(topo_name: str, agg: str, attack: str, *, steps: int,
               reps: int, seed: int, gossip: str = "gradient",
               schedule: str = "static") -> dict:
    data = ijcnn1_like(jax.random.PRNGKey(0), n=1200)
    wd = partition({"a": data.x, "b": data.y}, HONEST, seed=1)
    loss_fn = logreg_loss(0.01)
    b = BYZ if attack != "none" else 0
    cfg = RobustConfig(aggregator=agg, vr="saga", attack=attack,
                       num_byzantine=b, weiszfeld_iters=32,
                       topology=topo_name, topology_seed=seed,
                       gossip=gossip, schedule=schedule,
                       schedule_period=SCHEDULE_PERIOD)
    sched = resolve_schedule(cfg, HONEST + b)
    init_fn, step_fn = make_federated_step(
        loss_fn, wd, cfg, get_optimizer("sgd", 0.02), schedule=sched)
    state = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                    jax.random.PRNGKey(2))
    step = jax.jit(step_fn)
    state, metrics = step(state)        # compile + warm
    jax.block_until_ready(state.params)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        state, metrics = step(state)
        jax.block_until_ready(state.params)
        times.append(time.perf_counter() - t0)
    for _ in range(max(steps - reps - 1, 0)):
        state, metrics = step(state)
    final_loss = float(np.mean([
        loss_fn({"w": state.params["w"][i]},
                {"a": wd["a"][i], "b": wd["b"][i]})
        for i in range(HONEST)]))
    return {
        "topology": topo_name, "aggregator": agg, "attack": attack,
        "gossip": gossip, "schedule": schedule,
        "schedule_period": sched.period,
        "num_nodes": HONEST + b, "num_byzantine": b, "steps": steps,
        "reps": reps, "spectral_gap": sched.joint_spectral_gap(),
        "wall_us_mean": sum(times) / len(times) * 1e6,
        "wall_us_min": min(times) * 1e6,
        "final_honest_loss": final_loss,
        "consensus_dist": float(metrics["consensus_dist"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help=f"only {QUICK_TOPOLOGIES} x {QUICK_AGGREGATORS} x "
                    f"{QUICK_ATTACKS} (the CI artifact setting)")
    ap.add_argument("--steps", type=int, default=120,
                    help="training steps per cell (final-loss horizon)")
    ap.add_argument("--reps", type=int, default=10,
                    help="timed steps per cell")
    ap.add_argument("--seed", type=int, default=0,
                    help="erdos_renyi topology seed")
    ap.add_argument("--out", default="BENCH_topologies.json")
    args = ap.parse_args()

    topologies = QUICK_TOPOLOGIES if args.quick else TOPOLOGIES
    aggregators = QUICK_AGGREGATORS if args.quick else AGGREGATOR_NAMES
    attacks = QUICK_ATTACKS if args.quick else ATTACKS
    schedules = QUICK_SCHEDULES if args.quick else SCHEDULES

    rows = []
    for topo_name in topologies:
        for agg in aggregators:
            for attack in attacks:
                r = bench_cell(topo_name, agg, attack, steps=args.steps,
                               reps=args.reps, seed=args.seed)
                rows.append(r)
                print(f"  {topo_name:12s} {agg:18s} {attack:10s} "
                      f"{r['wall_us_mean']:9.0f} us/step "
                      f"loss={r['final_honest_loss']:.4f} "
                      f"consensus={r['consensus_dist']:.5f}")

    # The gossip-mode x schedule grid (geomed under sign_flip): parameter
    # gossip must hold an error floor comparable to gradient gossip on
    # every schedule, and a raising cell fails CI like any other.
    for gossip in GOSSIP_MODES:
        for schedule in schedules:
            r = bench_cell(SCHEDULE_TOPOLOGY[schedule], "geomed",
                           "sign_flip", steps=args.steps, reps=args.reps,
                           seed=args.seed, gossip=gossip, schedule=schedule)
            rows.append(r)
            print(f"  gossip={gossip:8s} schedule={schedule:12s} "
                  f"{r['wall_us_mean']:9.0f} us/step "
                  f"loss={r['final_honest_loss']:.4f} "
                  f"consensus={r['consensus_dist']:.5f}")

    report = {
        "schema": SCHEMA,
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "num_honest": HONEST,
        "num_byzantine": BYZ,
        "steps": args.steps,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"\nwrote {args.out} ({len(rows)} rows)\n")

    print("| topology | aggregator | attack | gossip | schedule | us/step "
          "| final loss | consensus |")
    print("|----------|------------|--------|--------|----------|---------"
          "|------------|-----------|")
    for r in rows:
        print(f"| {r['topology']} | {r['aggregator']} | {r['attack']} | "
              f"{r['gossip']} | {r['schedule']} | "
              f"{r['wall_us_mean']:.0f} | {r['final_honest_loss']:.4f} | "
              f"{r['consensus_dist']:.5f} |")


if __name__ == "__main__":
    main()
