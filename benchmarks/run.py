"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,table1] [--fast]

Prints ``name,us_per_call,derived`` CSV rows (derived = optimality gap for
the figure benchmarks, accuracy for Table I, dominant roofline seconds for
the roofline report, arithmetic intensity for kernels).
"""
import argparse
import sys
import time

ALL = ["fig3", "fig4", "fig5", "fig6", "table1", "ablation", "kernels", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of {ALL}")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower paper-figure grids (fig4, table1)")
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else list(ALL)
    if args.fast:
        selected = [s for s in selected if s not in ("fig4", "table1")]

    print("name,us_per_call,derived")
    t0 = time.time()
    for name in selected:
        t1 = time.time()
        if name == "fig3":
            from benchmarks import fig3_ijcnn1
            fig3_ijcnn1.main()
        elif name == "fig4":
            from benchmarks import fig4_covtype
            fig4_covtype.main()
        elif name == "fig5":
            from benchmarks import fig5_zero_outer
            fig5_zero_outer.main()
        elif name == "fig6":
            from benchmarks import fig6_aggregators
            fig6_aggregators.main()
        elif name == "table1":
            from benchmarks import table1_nn
            table1_nn.main()
        elif name == "ablation":
            from benchmarks import ablation_byzantine
            ablation_byzantine.main()
        elif name == "kernels":
            from benchmarks import kernels_bench
            kernels_bench.main()
        elif name == "roofline":
            from benchmarks import roofline
            roofline.main()
        else:
            print(f"# unknown benchmark {name}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t1:.1f}s", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
