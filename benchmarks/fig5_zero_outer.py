"""Paper Fig. 5: every honest worker holds the SAME dataset (outer variation
delta^2 = 0).  Thm 1: Byrd-SAGA's asymptotic error vanishes; Thm 2:
robust SGD/BSGD stay inner-variation limited."""
from repro.core import RobustConfig

from benchmarks import common


def main() -> None:
    loss, batch, f_star, wd = common.build_problem("ijcnn1", replicated=True)
    for attack in common.ATTACKS:
        for label, vr, lr in common.ALGOS:
            cfg = RobustConfig(
                aggregator="geomed", vr=vr, attack=attack,
                num_byzantine=0 if attack == "none" else common.B,
                minibatch_size=50)
            st, metrics, us = common.run_algorithm(loss, wd, cfg, lr * 0.5,
                                                   steps=800)
            gap = float(loss(st.params, batch)) - f_star
            common.emit(f"fig5/{attack}/{label}-geomed", us, gap)


if __name__ == "__main__":
    main()
