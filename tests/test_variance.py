"""Seam regression for the VarianceReducer strategy layer (DESIGN.md Sec. 9).

The refactor moved every ``cfg.vr`` string branch into the
``repro.core.variance`` registry.  These tests pin the seam:

* sgd / minibatch / saga through the interface are BIT-EXACT with an
  in-test oracle that re-implements the pre-refactor pipeline (direct
  ``jax.random.randint`` draws + ``saga_correct_scatter`` calls), on both
  the packed and the per-leaf hot paths;
* lsvrg carries O(D) per-client state -- snapshot + anchor, never a
  (W, J, ...) table -- and its first corrected message from a warm init
  equals the worker's FULL local gradient (the SVRG identity);
* the registry is the single source of truth: every ``VR_NAMES`` entry
  trains on the master sim, the decentralized sim, and both distributed
  comm modes without raising; unknown names fail with the derived error.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from mesh_harness import run_py
from repro.core import RobustConfig, make_federated_step
from repro.core import attacks as attack_lib
from repro.core import saga as saga_lib
from repro.core.robust_step import FederatedState
from repro.core.variance import _REDUCERS, VR_NAMES, LsvrgState, get_reducer
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer
from repro.optim import optimizers as optim_lib

WH, B, J = 6, 2, 8


@pytest.fixture(scope="module")
def problem():
    data = ijcnn1_like(jax.random.PRNGKey(0), n=WH * J)
    wd = partition({"a": data.x, "b": data.y}, WH, seed=1)
    return logreg_loss(0.01), wd


def _params0(wd):
    p = jax.tree_util.tree_leaves(wd)[0].shape[-1]
    return {"w": jnp.zeros((p,), jnp.float32)}


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------

def test_registry_is_single_source_of_truth():
    assert VR_NAMES == tuple(_REDUCERS)  # derived, not hand-spliced
    assert set(VR_NAMES) == {"sgd", "minibatch", "saga", "lsvrg"}
    for name in VR_NAMES:
        r = get_reducer(RobustConfig(vr=name))
        assert r.name == name
        assert isinstance(r, _REDUCERS[name])


def test_unknown_name_error_is_derived():
    with pytest.raises(ValueError, match="unknown variance reducer 'svrg2'"):
        RobustConfig(vr="svrg2").reducer()
    with pytest.raises(ValueError, match="lsvrg"):  # lists the registry
        RobustConfig(vr="nope").reducer()


def test_historical_index_draw_shapes_bitwise():
    """The per-step sample draws must reproduce the pre-refactor
    ``jax.random.randint`` calls bit-for-bit -- they feed the trajectory."""
    key = jax.random.PRNGKey(42)
    for name in ("sgd", "saga", "lsvrg"):
        idx = get_reducer(RobustConfig(vr=name)).draw_indices(key, WH, J)
        np.testing.assert_array_equal(
            np.asarray(idx),
            np.asarray(jax.random.randint(key, (WH,), 0, J)))
    mb = get_reducer(RobustConfig(vr="minibatch", minibatch_size=5))
    np.testing.assert_array_equal(
        np.asarray(mb.draw_indices(key, WH, J)),
        np.asarray(jax.random.randint(key, (WH, 5), 0, J)))


# ---------------------------------------------------------------------------
# Bit-exactness vs the pre-refactor pipeline (in-test oracle)
# ---------------------------------------------------------------------------

def _oracle_run(loss, wd, cfg, opt, steps):
    """The PRE-refactor simulation pipeline, re-implemented inline as ONE
    jitted step: string dispatch on cfg.vr, direct randint draws, direct
    saga_lib calls, the same honest-variance metric.  Any change the
    strategy layer makes to RNG consumption, packing order or correction
    math shows up as a mismatch against this."""
    import repro.core.aggregators as agg_lib
    grad_fn = jax.grad(loss)
    j = jax.tree_util.tree_leaves(wd)[0].shape[1]
    attack_cfg = cfg.attack_config()

    def sample(d, i):
        return jax.tree_util.tree_map(lambda z: z[i], d)

    def pack(tree, bn):
        spec = cfg.message_spec(tree, batch_ndim=bn)
        return spec.pack(tree, batch_ndim=bn), spec

    params = _params0(wd)
    opt_state = opt.init(params)
    if cfg.vr == "saga":
        tab = jax.vmap(lambda d: jax.vmap(
            lambda jj: grad_fn(params, sample(d, jj[None])))(jnp.arange(j))
        )(wd)
        if cfg.packed:
            tab, _ = pack(tab, 2)
        vr = saga_lib.saga_init(tab)
    else:
        vr = None
    st = FederatedState(params, opt_state, vr,
                        jnp.zeros((), jnp.int32), jax.random.PRNGKey(7))

    @jax.jit
    def oracle_step(state):
        key, k_idx, k_attack = jax.random.split(state.key, 3)
        params, vr = state.params, state.vr
        if cfg.vr == "minibatch":
            idx = jax.random.randint(k_idx, (WH, cfg.minibatch_size), 0, j)
            honest = jax.vmap(
                lambda d, i: grad_fn(params, sample(d, i)))(wd, idx)
        else:
            idx = jax.random.randint(k_idx, (WH,), 0, j)
            honest = jax.vmap(
                lambda d, i: grad_fn(params, sample(d, i[None])))(wd, idx)
        if cfg.packed:
            honest, spec = pack(honest, 1)
            if cfg.vr == "saga":
                honest, vr = saga_lib.saga_correct_scatter(vr, honest, idx)
            h32 = honest.astype(jnp.float32)
            var = jnp.sum((h32 - jnp.mean(h32, axis=0)[None]) ** 2) / WH
            msgs = attack_lib.apply_attack(attack_cfg, honest, k_attack,
                                           spec=spec)
            agg = spec.unpack(cfg.flat_aggregator_fn(spec)(msgs),
                              batch_ndim=0)
        else:
            if cfg.vr == "saga":
                honest, vr = saga_lib.saga_correct_scatter(vr, honest, idx)
            hm = agg_lib.mean_agg_perleaf(honest)
            var = sum(
                jnp.sum((z.astype(jnp.float32)
                         - m.astype(jnp.float32)[None]) ** 2)
                for z, m in zip(jax.tree_util.tree_leaves(honest),
                                jax.tree_util.tree_leaves(hm))) / WH
            msgs = attack_lib.apply_attack(attack_cfg, honest, k_attack)
            agg = cfg.aggregator_fn(perleaf=True)(msgs)
        updates, opt_state = opt.update(agg, state.opt_state, params,
                                        state.step)
        params = optim_lib.apply_updates(params, updates)
        new_state = FederatedState(params, opt_state, vr, state.step + 1,
                                   key)
        return new_state, {"honest_variance": var}

    for _ in range(steps):
        st, _ = oracle_step(st)
    return st


@pytest.mark.parametrize("vr", ["sgd", "minibatch", "saga"])
@pytest.mark.parametrize("packed", [True, False])
def test_ported_reducers_bit_exact_vs_oracle(problem, vr, packed):
    """5 steps of attacked geomed + momentum through the strategy layer ==
    5 steps of the inlined pre-refactor pipeline, on EVERY state leaf
    (params, momenta, SAGA table/avg, PRNG key)."""
    loss, wd = problem
    cfg = RobustConfig(aggregator="geomed", vr=vr, attack="sign_flip",
                       num_byzantine=B, minibatch_size=3, packed=packed,
                       weiszfeld_iters=16)
    opt = get_optimizer("momentum", 0.05)
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    st = init_fn(_params0(wd), jax.random.PRNGKey(7))
    jstep = jax.jit(step_fn)
    for _ in range(5):
        st, _ = jstep(st)
    ref = _oracle_run(loss, wd, cfg, opt, 5)
    got, want = st._asdict(), ref._asdict()
    for k in want:
        for a, b in zip(jax.tree_util.tree_leaves(got[k]),
                        jax.tree_util.tree_leaves(want[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{vr} packed={packed} {k}")


@pytest.mark.parametrize("vr", VR_NAMES)
def test_packed_and_perleaf_paths_agree(problem, vr):
    """The Sec.-8 packed buffer is a LAYOUT, not math: both hot paths land
    on the same trajectory for every reducer (lsvrg included -- its
    snapshot/anchor live in whichever layout the path uses)."""
    loss, wd = problem
    outs = {}
    for packed in (True, False):
        cfg = RobustConfig(aggregator="geomed", vr=vr, attack="gaussian",
                           num_byzantine=B, minibatch_size=3, lsvrg_p=0.5,
                           packed=packed, weiszfeld_iters=16)
        init_fn, step_fn = make_federated_step(
            loss, wd, cfg, get_optimizer("sgd", 0.05))
        st = init_fn(_params0(wd), jax.random.PRNGKey(7))
        jstep = jax.jit(step_fn)
        for _ in range(4):
            st, m = jstep(st)
        outs[packed] = st.params
        assert np.isfinite(float(m["honest_variance"]))
    np.testing.assert_allclose(np.asarray(outs[True]["w"]),
                               np.asarray(outs[False]["w"]),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# lsvrg: O(D) state + the SVRG correction identity
# ---------------------------------------------------------------------------

def test_lsvrg_state_is_o_of_d(problem):
    """The whole point vs SAGA: per-client state is snapshot + anchor
    (2 * W * D elements), never a (W, J, ...) table -- checked on both
    layouts and cross-checked against ``memory_elems`` (the dryrun/bench
    accounting term)."""
    loss, wd = problem
    d = jax.tree_util.tree_leaves(wd)[0].shape[-1]
    reducer = get_reducer(RobustConfig(vr="lsvrg"))
    for packed in (True, False):
        cfg = RobustConfig(vr="lsvrg", packed=packed)
        init_fn, _ = make_federated_step(loss, wd, cfg,
                                         get_optimizer("sgd", 0.05))
        st = init_fn(_params0(wd), jax.random.PRNGKey(0))
        assert isinstance(st.vr, LsvrgState)
        for leaf in jax.tree_util.tree_leaves(st.vr):
            assert leaf.shape[0] == WH
            assert J not in leaf.shape[1:], f"table-like axis: {leaf.shape}"
        elems = sum(l.size for l in jax.tree_util.tree_leaves(st.vr))
        assert elems == reducer.memory_elems(WH, J, d) == 2 * WH * d
    saga_state = make_federated_step(
        loss, wd, RobustConfig(vr="saga", packed=True),
        get_optimizer("sgd", 0.05))[0](_params0(wd), jax.random.PRNGKey(0)).vr
    assert saga_state.table.shape == (WH, J, d)  # what lsvrg shrinks away


def test_lsvrg_first_message_is_full_gradient(problem):
    """SVRG identity: from the warm init (snapshot = x0, anchor = full
    local grad at x0) the first corrected message is g_i(x0) - g_i(x0) +
    mu = mu exactly, so one mean-aggregated sgd step == one step of exact
    distributed gradient descent."""
    loss, wd = problem
    lr = 0.1
    cfg = RobustConfig(aggregator="mean", vr="lsvrg", attack="none",
                       lsvrg_p=0.0)
    init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                           get_optimizer("sgd", lr))
    st = init_fn(_params0(wd), jax.random.PRNGKey(7))
    st, _ = jax.jit(step_fn)(st)
    full = jax.vmap(jax.grad(loss))(
        jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (WH,) + p.shape),
            _params0(wd)), wd)
    want = _params0(wd)["w"] - lr * jnp.mean(full["w"], axis=0)
    np.testing.assert_allclose(np.asarray(st.params["w"]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_lsvrg_snapshot_refresh_probability(problem):
    """p=1 refreshes every step (snapshot tracks the iterate, rate metric
    1.0); p=0 never does (state frozen at init)."""
    loss, wd = problem
    for p, rate in ((1.0, 1.0), (0.0, 0.0)):
        cfg = RobustConfig(aggregator="geomed", vr="lsvrg", attack="none",
                           lsvrg_p=p, packed=False)
        init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                               get_optimizer("sgd", 0.05))
        st0 = init_fn(_params0(wd), jax.random.PRNGKey(7))
        st, m = jax.jit(step_fn)(st0)
        assert float(m["vr_snapshot_rate"]) == rate
        if p == 0.0:
            np.testing.assert_array_equal(np.asarray(st.vr.snapshot["w"]),
                                          np.asarray(st0.vr.snapshot["w"]))
        else:
            # Refreshed to the PRE-update iterate, broadcast per worker.
            np.testing.assert_allclose(
                np.asarray(st.vr.snapshot["w"]),
                np.broadcast_to(np.asarray(st0.params["w"])[None],
                                (WH, st0.params["w"].shape[0])))


def test_lsvrg_beats_sgd_variance(problem):
    """The Lemma-1 property the robust rule relies on: after the table
    warms up, lsvrg's honest-message variance sits well below plain
    sgd's (like SAGA's)."""
    loss, wd = problem
    var = {}
    for vr in ("sgd", "lsvrg"):
        cfg = RobustConfig(aggregator="geomed", vr=vr, attack="none",
                           lsvrg_p=0.3)
        init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                               get_optimizer("sgd", 0.05))
        st = init_fn(_params0(wd), jax.random.PRNGKey(7))
        jstep = jax.jit(step_fn)
        for _ in range(60):
            st, m = jstep(st)
        var[vr] = float(m["honest_variance"])
    assert var["lsvrg"] < 0.5 * var["sgd"], var


# ---------------------------------------------------------------------------
# Registry coverage: every name x every execution path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vr", VR_NAMES)
def test_every_reducer_trains_master_and_decentralized_sim(problem, vr):
    """Every registry name runs the master sim AND the decentralized sim
    (ring, both gossip modes) without raising, producing finite params."""
    loss, wd = problem
    for topology, gossip in (("star", "gradient"), ("ring", "gradient"),
                             ("ring", "params")):
        cfg = RobustConfig(aggregator="geomed", vr=vr, attack="sign_flip",
                           num_byzantine=B, minibatch_size=3, lsvrg_p=0.5,
                           topology=topology, gossip=gossip,
                           weiszfeld_iters=8)
        init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                               get_optimizer("sgd", 0.05))
        st = init_fn(_params0(wd), jax.random.PRNGKey(7))
        jstep = jax.jit(step_fn)
        for _ in range(2):
            st, _ = jstep(st)
        leaves = jax.tree_util.tree_leaves(st.params)
        assert all(np.isfinite(np.asarray(l)).all() for l in leaves), (
            vr, topology, gossip)


@pytest.mark.slow
def test_every_reducer_trains_distributed_both_comm_modes():
    """Launch-path coverage on the 8-device mesh: every VR_NAMES entry
    compiles and trains under make_train_step in BOTH comm modes, with
    finite loss; the stateful reducers carry their state through the
    donated step (lsvrg with NO sample axis -- O(D) on this path too)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.core.variance import VR_NAMES
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32,
                            loss_chunk=32)
        train = TrainConfig(optimizer="sgd", lr=0.05)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            nparams = sum(l.size for l in jax.tree_util.tree_leaves(params))
            for vr in VR_NAMES:
                for comm in ("gather", "sharded"):
                    robust = RobustConfig(aggregator="geomed", vr=vr,
                                          attack="sign_flip", num_byzantine=1,
                                          comm=comm, weiszfeld_iters=8,
                                          minibatch_size=2, lsvrg_p=0.5)
                    reducer = robust.reducer()
                    jj = 3 if reducer.uses_sample_idx else 0
                    step_fn, _, _ = steps_lib.make_train_step(
                        model, robust, train, mesh, saga_num_samples=jj)
                    # Copy params into the state: the compiled step DONATES
                    # arg 0, so each combo needs its own live buffers.
                    state = {"params": jax.tree_util.tree_map(
                                 lambda x: x + 0, params),
                             "opt": (), "step": jnp.zeros((), jnp.int32)}
                    if reducer.wants_state(jj):
                        state["vr"] = reducer.init_zeros(params, 4, jj)
                    jstep = steps_lib.compile_train_step(step_fn)
                    for i in range(2):
                        batch = make_batch(jax.random.fold_in(
                            jax.random.PRNGKey(5), i), cfg, 4, 2, 32)
                        state, m = jstep(state, batch,
                                         jax.random.fold_in(jax.random.PRNGKey(9), i))
                    assert np.isfinite(float(m["loss"])), (vr, comm)
                    if vr == "lsvrg":
                        elems = sum(l.size for l in
                                    jax.tree_util.tree_leaves(state["vr"]))
                        assert elems == 2 * 4 * nparams, (elems, nparams)
                        assert float(m["vr_snapshot_rate"]) >= 0.0
                    print("VRCOV_OK", vr, comm, float(m["loss"]))
    """, timeout=600)
    for vr in VR_NAMES:
        for comm in ("gather", "sharded"):
            assert f"VRCOV_OK {vr} {comm}" in out, out
