"""Tiny stand-in for ``hypothesis`` used when the real package is absent.

The suite must always COLLECT (a module-scope ImportError aborts the whole
pytest run), and the property tests are still worth running on a handful of
deterministically drawn examples.  This shim implements just the surface the
repo's tests use -- ``given``/``settings``/``assume``, ``st.floats``/
``st.integers`` and ``hypothesis.extra.numpy.arrays`` -- drawing from a
seeded numpy Generator.  Install the real thing (requirements-dev.txt) for
actual shrinking/coverage.
"""
from __future__ import annotations

import types

import numpy as np

_N_EXAMPLES = 5


class _Unsatisfied(Exception):
    """Raised by assume() to discard the current example."""


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _floats(min_value, max_value, width=64):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _arrays(dtype, shape, elements=None):
    shape = (shape,) if isinstance(shape, int) else tuple(shape)

    def draw(rng):
        n = int(np.prod(shape)) if shape else 1
        if elements is None:
            flat = rng.standard_normal(n)
        else:
            flat = np.array([elements.draw(rng) for _ in range(n)])
        return flat.reshape(shape).astype(dtype)

    return _Strategy(draw)


def _given(**strategies):
    def deco(fn):
        # No functools.wraps: it sets __wrapped__, which makes pytest follow
        # the original signature and demand the drawn kwargs as fixtures.
        def wrapper(*args):
            rng = np.random.default_rng(0)
            ran = 0
            for _ in range(_N_EXAMPLES * 10):
                kwargs = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs)
                except _Unsatisfied:
                    continue
                ran += 1
                if ran >= _N_EXAMPLES:
                    break
            if ran == 0:
                # Mirror real hypothesis' Unsatisfiable: a test whose body
                # never ran must not silently pass.
                raise RuntimeError(
                    f"{fn.__name__}: assume() rejected every drawn example")
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def _settings(**_kw):
    return lambda fn: fn


def _assume(condition):
    if not condition:
        raise _Unsatisfied()
    return True


hypothesis = types.SimpleNamespace(given=_given, settings=_settings,
                                   assume=_assume)
st = types.SimpleNamespace(floats=_floats, integers=_integers)
hnp = types.SimpleNamespace(arrays=_arrays)
