"""Multi-device integration tests.

These need >1 host device, so each runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
keeps the default single device, per the brief)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_gather_vs_sharded_aggregation_agree():
    out = run_py("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.core import RobustConfig, distributed_aggregate, sharded_aggregate
        from repro.core.aggregators import geomed_agg
        mesh = jax.make_mesh((4,2),("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        cfg = RobustConfig(aggregator="geomed", weiszfeld_iters=100, weiszfeld_tol=1e-9)
        ref = geomed_agg({"a": g1, "b": g2}, max_iters=100, tol=1e-9)
        sm = partial(jax.shard_map, mesh=mesh,
                     in_specs=(P("data","model"), P("data",None,"model")),
                     out_specs=(P("model"), P(None,"model")), check_vma=False)
        out1 = sm(lambda a, b: tuple(distributed_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",), model_axes=("model",)).values()))(g1, g2)
        out2 = sm(lambda a, b: tuple(sharded_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",), model_axes=("model",), num_workers=4).values()))(g1, g2)
        import numpy as np
        for o in (out1, out2):
            np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]), atol=1e-5)
        print("AGREE")
    """)
    assert "AGREE" in out


def test_train_step_runs_on_mesh_and_attack_is_neutralized():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("qwen2-7b").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        results = {}
        for agg in ("geomed", "mean"):
            robust = RobustConfig(aggregator=agg, vr="sgd", attack="sign_flip",
                                  num_byzantine=1, weiszfeld_iters=16)
            step_fn, _, _ = steps_lib.make_train_step(
                model, robust, TrainConfig(optimizer="adamw", lr=1e-3), mesh)
            with jax.set_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                opt = get_optimizer("adamw", 1e-3)
                state = {"params": params, "opt": opt.init(params),
                         "step": jnp.zeros((), jnp.int32)}
                jstep = jax.jit(step_fn)
                key = jax.random.PRNGKey(1)
                losses = []
                for i in range(8):
                    batch = make_batch(jax.random.fold_in(key, i), cfg, 4, 2, 32)
                    state, m = jstep(state, batch, jax.random.fold_in(key, 100+i))
                    losses.append(float(m["loss"]))
            results[agg] = losses
        # geomed training loss decreases; sign-flip attack under mean pushes
        # the model the wrong way (loss non-decreasing or worse than geomed).
        assert results["geomed"][-1] < results["geomed"][0], results["geomed"]
        assert results["geomed"][-1] < results["mean"][-1] + 1e-6, results
        print("ROBUST", results["geomed"][0], "->", results["geomed"][-1])
    """)
    assert "ROBUST" in out


def test_sharded_comm_equals_gather_comm_training():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        outs = {}
        for comm in ("gather", "sharded"):
            robust = RobustConfig(aggregator="geomed", vr="sgd", attack="sign_flip",
                                  num_byzantine=1, comm=comm,
                                  weiszfeld_iters=32, weiszfeld_tol=1e-9)
            step_fn, _, _ = steps_lib.make_train_step(
                model, robust, TrainConfig(optimizer="sgd", lr=0.1), mesh)
            with jax.set_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                state = {"params": params, "opt": (), "step": jnp.zeros((), jnp.int32)}
                batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
                state, _ = jax.jit(step_fn)(state, batch, jax.random.PRNGKey(9))
                outs[comm] = state["params"]
        for a, b in zip(jax.tree_util.tree_leaves(outs["gather"]),
                        jax.tree_util.tree_leaves(outs["sharded"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=2e-3, atol=2e-4)
        print("EQUAL")
    """)
    assert "EQUAL" in out


def test_saga_distributed_train_step():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.core.saga import saga_init_zeros
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        robust = RobustConfig(aggregator="geomed", vr="saga", attack="gaussian",
                              num_byzantine=1, weiszfeld_iters=8)
        step_fn, _, sstructs = steps_lib.make_train_step(
            model, robust, TrainConfig(optimizer="sgd", lr=0.05), mesh,
            saga_num_samples=4)
        with jax.set_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            state = {"params": params, "opt": (), "step": jnp.zeros((), jnp.int32),
                     "saga": saga_init_zeros(params, 4, 4)}
            jstep = jax.jit(step_fn)
            for i in range(3):
                batch = make_batch(jax.random.fold_in(jax.random.PRNGKey(2), i), cfg, 4, 2, 32)
                state, m = jstep(state, batch, jax.random.fold_in(jax.random.PRNGKey(3), i))
            assert jnp.isfinite(m["loss"])
            # table must have absorbed gradients (non-zero rows)
            tabs = jax.tree_util.tree_leaves(state["saga"].table)
            total = sum(float(jnp.sum(jnp.abs(t.astype(jnp.float32)))) for t in tabs)
            assert total > 0
        print("SAGA_OK", float(m["loss"]))
    """)
    assert "SAGA_OK" in out


def test_dryrun_single_combo_small_devices():
    """Exercise dryrun.lower_one end-to-end on an 8-device (2x4) stand-in
    via the same code path (mesh shrunk through make_host_mesh monkeypatch)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch import dryrun, mesh as mesh_lib
        mesh_lib.make_production_mesh = lambda multi_pod=False: (
            mesh_lib.make_host_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else mesh_lib.make_host_mesh((4, 2), ("data", "model")))
        dryrun.mesh_lib = mesh_lib
        for mp in (False, True):
            rec = dryrun.lower_one("whisper-tiny", "train_4k", multi_pod=mp)
            assert rec["flops_per_device"] > 0
            assert rec["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")
        print("DRYRUN_OK")
    """, timeout=600)
    assert "DRYRUN_OK" in out
