"""Multi-device integration tests.

Each test runs an inline program in a subprocess via tests/mesh_harness.py
(8 forced host devices); programs use repro.compat for every mesh/shard_map
touch so they run on jax 0.4.x through 0.7.x."""
import pytest

from mesh_harness import run_py
from repro.core.aggregators import AGGREGATOR_NAMES


def test_gather_vs_sharded_aggregation_agree():
    out = run_py("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig, distributed_aggregate, sharded_aggregate
        from repro.core.aggregators import geomed_agg
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        cfg = RobustConfig(aggregator="geomed", weiszfeld_iters=100, weiszfeld_tol=1e-9)
        ref = geomed_agg({"a": g1, "b": g2}, max_iters=100, tol=1e-9)
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P("data","model"), P("data",None,"model")),
                     out_specs=(P("model"), P(None,"model")), check_vma=False)
        out1 = sm(lambda a, b: tuple(distributed_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",), model_axes=("model",)).values()))(g1, g2)
        out2 = sm(lambda a, b: tuple(sharded_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",), model_axes=("model",), num_workers=4).values()))(g1, g2)
        import numpy as np
        for o in (out1, out2):
            np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]), atol=1e-5)
        print("AGREE")
    """)
    assert "AGREE" in out


def test_aggregator_names_covered_in_both_comm_modes():
    """Every name in AGGREGATOR_NAMES aggregates (no raising) in BOTH comm
    modes on the single-worker-axis mesh, matching the single-host reference
    aggregator."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import (AGGREGATOR_NAMES, GATHER_AGGREGATORS,
                                SHARDED_AGGREGATORS, RobustConfig,
                                distributed_aggregate, sharded_aggregate)
        # Since PR 2 both comm paths cover the whole registry.
        assert GATHER_AGGREGATORS == AGGREGATOR_NAMES
        assert SHARDED_AGGREGATORS == AGGREGATOR_NAMES
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P("data","model"), P("data",None,"model")),
                     out_specs=(P("model"), P(None,"model")), check_vma=False)
        for name in AGGREGATOR_NAMES:
            cfg = RobustConfig(aggregator=name, weiszfeld_iters=100,
                               weiszfeld_tol=1e-9, num_byzantine=1,
                               clip_radius=2.5)
            ref = cfg.aggregator_fn()({"a": g1, "b": g2})
            got = sm(lambda a, b: tuple(distributed_aggregate(
                {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",),
                model_axes=("model",)).values()))(g1, g2)
            got_s = sm(lambda a, b: tuple(sharded_aggregate(
                {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",),
                model_axes=("model",), num_workers=4).values()))(g1, g2)
            for comm, o in (("gather", got), ("sharded", got_s)):
                np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]),
                                           atol=2e-5, err_msg=f"{comm} {name} a")
                np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]),
                                           atol=2e-5, err_msg=f"{comm} {name} b")
        print("NAMES_COVERED")
    """, timeout=600)
    assert "NAMES_COVERED" in out


# One aggregator per subprocess: the (pod, data) worker-axis matrix case.
_MULTIPOD_CASE = """
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import RobustConfig, distributed_aggregate, sharded_aggregate
    wa = ("pod", "data")
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
    cfg = RobustConfig(aggregator=name, weiszfeld_iters=100, weiszfeld_tol=1e-9,
                       num_byzantine=1, clip_radius=2.5)
    ref = cfg.aggregator_fn()({"a": g1, "b": g2})
    sm = partial(compat.shard_map, mesh=mesh,
                 in_specs=(P(wa, "model"), P(wa, None, "model")),
                 out_specs=(P("model"), P(None, "model")), check_vma=False)
    outs = {}
    outs["gather"] = sm(lambda a, b: tuple(distributed_aggregate(
        {"a": a[0], "b": b[0]}, cfg, worker_axes=wa,
        model_axes=("model",)).values()))(g1, g2)
    outs["sharded"] = sm(lambda a, b: tuple(sharded_aggregate(
        {"a": a[0], "b": b[0]}, cfg, worker_axes=wa, model_axes=("model",),
        num_workers=4).values()))(g1, g2)
    # Both comm modes match the single-host reference AND each other.
    for comm, got in outs.items():
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref["a"]),
                                   atol=2e-5, err_msg=comm + " a")
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(ref["b"]),
                                   atol=2e-5, err_msg=comm + " b")
    for x, y in zip(outs["gather"], outs["sharded"]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)
    print("MULTIPOD_AGREE", name)
"""


@pytest.mark.parametrize("name", AGGREGATOR_NAMES)
def test_every_aggregator_gather_vs_sharded_on_pod_data_mesh(name):
    """Every registry aggregator produces gather-vs-sharded results within
    tolerance on a multi-pod (pod, data) worker-axis mesh (2, 2, 2)."""
    out = run_py(f"    name = {name!r}\n" + _MULTIPOD_CASE, timeout=600)
    assert f"MULTIPOD_AGREE {name}" in out


def test_sharded_krum_selection_index_regression():
    """Seeded gaussian attack, W=8 messages (5 honest + 3 Byzantine) on a
    (2, 4, 1) multi-pod mesh: krum's selection index is pinned to honest
    worker 2 by the seeds, and the sharded path (coordinate all_to_all +
    partial-Gram psum) must return exactly that worker's message."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig, krum_scores, sharded_aggregate
        from repro.core.aggregators import _pairwise_sq_dists
        from repro.core.attacks import AttackConfig, apply_attack
        honest = jax.random.normal(jax.random.PRNGKey(41), (5, 16))
        msgs = apply_attack(AttackConfig(name="gaussian", num_byzantine=3,
                                         gaussian_variance=100.0),
                            {"g": honest}, jax.random.PRNGKey(7))["g"]
        scores = krum_scores(_pairwise_sq_dists({"g": msgs}), 3)
        assert int(jnp.argmin(scores)) == 2, np.asarray(scores)  # seed-pinned
        mesh = compat.make_mesh((2, 4, 1), ("pod", "data", "model"))
        cfg = RobustConfig(aggregator="krum", num_byzantine=3)
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P(("pod", "data"), "model"),),
                     out_specs=P("model"), check_vma=False)
        got = sm(lambda g: sharded_aggregate(
            {"g": g[0]}, cfg, worker_axes=("pod", "data"),
            model_axes=("model",), num_workers=8)["g"])(msgs)
        # Krum SELECTS, so the sharded result is bit-exact, not just close.
        np.testing.assert_array_equal(np.asarray(got), np.asarray(msgs[2]))
        print("KRUM_SELECTS_2")
    """)
    assert "KRUM_SELECTS_2" in out


def test_train_step_runs_on_mesh_and_attack_is_neutralized():
    """Train on a FIXED batch so the learning signal is deterministic: with
    sign_flip magnitude -3 and W=4/B=1 the mean aggregate is exactly zero
    (the attack cancels the honest sum), so mean-aggregated training cannot
    move, while geomed discards the Byzantine row and learns."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("qwen2-7b").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        results = {}
        for agg in ("geomed", "mean"):
            robust = RobustConfig(aggregator=agg, vr="sgd", attack="sign_flip",
                                  num_byzantine=1, weiszfeld_iters=16)
            step_fn, _, _ = steps_lib.make_train_step(
                model, robust, TrainConfig(optimizer="adamw", lr=1e-3), mesh)
            with compat.use_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                opt = get_optimizer("adamw", 1e-3)
                state = {"params": params, "opt": opt.init(params),
                         "step": jnp.zeros((), jnp.int32)}
                jstep = jax.jit(step_fn)
                key = jax.random.PRNGKey(1)
                batch = make_batch(key, cfg, 4, 2, 32)
                losses = []
                for i in range(8):
                    state, m = jstep(state, batch, jax.random.fold_in(key, 100+i))
                    losses.append(float(m["loss"]))
            results[agg] = losses
        # geomed neutralizes the attack and fits the batch; the zeroed mean
        # aggregate leaves the model stuck at its initial loss.
        assert results["geomed"][-1] < results["geomed"][0] - 1.0, results["geomed"]
        assert results["geomed"][-1] < results["mean"][-1] - 1.0, results
        assert abs(results["mean"][-1] - results["mean"][0]) < 0.2, results["mean"]
        print("ROBUST", results["geomed"][0], "->", results["geomed"][-1],
              "| mean stuck at", results["mean"][-1])
    """)
    assert "ROBUST" in out


def test_sharded_comm_equals_gather_comm_training():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        outs = {}
        for comm in ("gather", "sharded"):
            robust = RobustConfig(aggregator="geomed", vr="sgd", attack="sign_flip",
                                  num_byzantine=1, comm=comm,
                                  weiszfeld_iters=32, weiszfeld_tol=1e-9)
            step_fn, _, _ = steps_lib.make_train_step(
                model, robust, TrainConfig(optimizer="sgd", lr=0.1), mesh)
            with compat.use_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                state = {"params": params, "opt": (), "step": jnp.zeros((), jnp.int32)}
                batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
                state, _ = jax.jit(step_fn)(state, batch, jax.random.PRNGKey(9))
                outs[comm] = state["params"]
        for a, b in zip(jax.tree_util.tree_leaves(outs["gather"]),
                        jax.tree_util.tree_leaves(outs["sharded"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=2e-3, atol=2e-4)
        print("EQUAL")
    """)
    assert "EQUAL" in out


def test_saga_distributed_train_step():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.core.saga import saga_init_zeros
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        robust = RobustConfig(aggregator="geomed", vr="saga", attack="gaussian",
                              num_byzantine=1, weiszfeld_iters=8)
        step_fn, _, sstructs = steps_lib.make_train_step(
            model, robust, TrainConfig(optimizer="sgd", lr=0.05), mesh,
            saga_num_samples=4)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            state = {"params": params, "opt": (), "step": jnp.zeros((), jnp.int32),
                     "saga": saga_init_zeros(params, 4, 4)}
            jstep = jax.jit(step_fn)
            for i in range(3):
                batch = make_batch(jax.random.fold_in(jax.random.PRNGKey(2), i), cfg, 4, 2, 32)
                state, m = jstep(state, batch, jax.random.fold_in(jax.random.PRNGKey(3), i))
            assert jnp.isfinite(m["loss"])
            # table must have absorbed gradients (non-zero rows)
            tabs = jax.tree_util.tree_leaves(state["saga"].table)
            total = sum(float(jnp.sum(jnp.abs(t.astype(jnp.float32)))) for t in tabs)
            assert total > 0
        print("SAGA_OK", float(m["loss"]))
    """)
    assert "SAGA_OK" in out


def test_dryrun_single_combo_small_devices():
    """Exercise dryrun.lower_one end-to-end on an 8-device (2x4) stand-in
    via the same code path (mesh shrunk through make_host_mesh monkeypatch)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch import dryrun, mesh as mesh_lib
        mesh_lib.make_production_mesh = lambda multi_pod=False: (
            mesh_lib.make_host_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else mesh_lib.make_host_mesh((4, 2), ("data", "model")))
        dryrun.mesh_lib = mesh_lib
        for mp in (False, True):
            rec = dryrun.lower_one("whisper-tiny", "train_4k", multi_pod=mp)
            assert rec["flops_per_device"] > 0
            assert rec["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")
        print("DRYRUN_OK")
    """, timeout=600)
    assert "DRYRUN_OK" in out


def test_require_distributed_and_comm_validation():
    """Capability probe degrades with a clear error, not an AttributeError
    from inside jit: bogus comm modes are rejected at step-build time."""
    out = run_py("""
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.models.api import build_model

        assert compat.HAS_SHARD_MAP
        compat.require_distributed(min_devices=8)
        try:
            compat.require_distributed(min_devices=10**6)
        except RuntimeError as e:
            assert "device" in str(e)
        else:
            raise AssertionError("expected RuntimeError for device count")

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        try:
            steps_lib.make_train_step(
                model, RobustConfig(comm="bogus"), TrainConfig(), mesh)
        except ValueError as e:
            assert "gather" in str(e) and "sharded" in str(e)
        else:
            raise AssertionError("expected ValueError for bogus comm")
        print("PROBE_OK")
    """)
    assert "PROBE_OK" in out
