"""Multi-device integration tests.

Each test runs an inline program in a subprocess via tests/mesh_harness.py
(8 forced host devices); programs use repro.compat for every mesh/shard_map
touch so they run on jax 0.4.x through 0.7.x."""
import pytest

from mesh_harness import run_py
from repro.core.aggregators import AGGREGATOR_NAMES
from repro.core.attacks import ATTACK_NAMES


def test_gather_vs_sharded_aggregation_agree():
    out = run_py("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig, distributed_aggregate, sharded_aggregate
        from repro.core.aggregators import geomed_agg
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        cfg = RobustConfig(aggregator="geomed", weiszfeld_iters=100, weiszfeld_tol=1e-9)
        ref = geomed_agg({"a": g1, "b": g2}, max_iters=100, tol=1e-9)
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P("data","model"), P("data",None,"model")),
                     out_specs=(P("model"), P(None,"model")), check_vma=False)
        out1 = sm(lambda a, b: tuple(distributed_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",), model_axes=("model",)).values()))(g1, g2)
        out2 = sm(lambda a, b: tuple(sharded_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",), model_axes=("model",), num_workers=4).values()))(g1, g2)
        import numpy as np
        for o in (out1, out2):
            np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]), atol=1e-5)
        print("AGREE")
    """)
    assert "AGREE" in out


def test_aggregator_names_covered_in_both_comm_modes():
    """Every name in AGGREGATOR_NAMES aggregates (no raising) in BOTH comm
    modes on the single-worker-axis mesh, matching the single-host reference
    aggregator."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import (AGGREGATOR_NAMES, GATHER_AGGREGATORS,
                                SHARDED_AGGREGATORS, RobustConfig,
                                distributed_aggregate, sharded_aggregate)
        # Since PR 2 both comm paths cover the whole registry.
        assert GATHER_AGGREGATORS == AGGREGATOR_NAMES
        assert SHARDED_AGGREGATORS == AGGREGATOR_NAMES
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P("data","model"), P("data",None,"model")),
                     out_specs=(P("model"), P(None,"model")), check_vma=False)
        for name in AGGREGATOR_NAMES:
            cfg = RobustConfig(aggregator=name, weiszfeld_iters=100,
                               weiszfeld_tol=1e-9, num_byzantine=1,
                               clip_radius=2.5)
            ref = cfg.aggregator_fn()({"a": g1, "b": g2})
            got = sm(lambda a, b: tuple(distributed_aggregate(
                {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",),
                model_axes=("model",)).values()))(g1, g2)
            got_s = sm(lambda a, b: tuple(sharded_aggregate(
                {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",),
                model_axes=("model",), num_workers=4).values()))(g1, g2)
            for comm, o in (("gather", got), ("sharded", got_s)):
                np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]),
                                           atol=2e-5, err_msg=f"{comm} {name} a")
                np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]),
                                           atol=2e-5, err_msg=f"{comm} {name} b")
        print("NAMES_COVERED")
    """, timeout=600)
    assert "NAMES_COVERED" in out


# One aggregator per subprocess: the (pod, data) worker-axis matrix case.
_MULTIPOD_CASE = """
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import RobustConfig, distributed_aggregate, sharded_aggregate
    wa = ("pod", "data")
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
    cfg = RobustConfig(aggregator=name, weiszfeld_iters=100, weiszfeld_tol=1e-9,
                       num_byzantine=1, clip_radius=2.5)
    ref = cfg.aggregator_fn()({"a": g1, "b": g2})
    sm = partial(compat.shard_map, mesh=mesh,
                 in_specs=(P(wa, "model"), P(wa, None, "model")),
                 out_specs=(P("model"), P(None, "model")), check_vma=False)
    outs = {}
    outs["gather"] = sm(lambda a, b: tuple(distributed_aggregate(
        {"a": a[0], "b": b[0]}, cfg, worker_axes=wa,
        model_axes=("model",)).values()))(g1, g2)
    outs["sharded"] = sm(lambda a, b: tuple(sharded_aggregate(
        {"a": a[0], "b": b[0]}, cfg, worker_axes=wa, model_axes=("model",),
        num_workers=4).values()))(g1, g2)
    # Both comm modes match the single-host reference AND each other.
    for comm, got in outs.items():
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref["a"]),
                                   atol=2e-5, err_msg=comm + " a")
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(ref["b"]),
                                   atol=2e-5, err_msg=comm + " b")
    for x, y in zip(outs["gather"], outs["sharded"]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)
    print("MULTIPOD_AGREE", name)
"""


@pytest.mark.parametrize("name", AGGREGATOR_NAMES)
def test_every_aggregator_gather_vs_sharded_on_pod_data_mesh(name):
    """Every registry aggregator produces gather-vs-sharded results within
    tolerance on a multi-pod (pod, data) worker-axis mesh (2, 2, 2)."""
    out = run_py(f"    name = {name!r}\n" + _MULTIPOD_CASE, timeout=600)
    assert f"MULTIPOD_AGREE {name}" in out


# Decentralized neighborhood aggregation on a multi-pod mesh: one
# aggregator per subprocess, every non-star topology inside, BOTH comm
# modes against the dense masked-reference (simulation semantics).
_DECENTRALIZED_CASE = """
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import RobustConfig
    from repro.topology import (build_exchange, decentralized_aggregate,
                                get_topology, masked_aggregate)
    wa = ("pod", "data")
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
    sm = partial(compat.shard_map, mesh=mesh,
                 in_specs=(P(wa, "model"), P(wa, None, "model")),
                 out_specs=(P(wa, "model"), P(wa, None, "model")),
                 check_vma=False)
    for tname in ("ring", "torus2d", "erdos_renyi"):
        topo = get_topology(tname, 4, seed=1, p=0.7)
        cfg = RobustConfig(aggregator=name, weiszfeld_iters=100,
                           weiszfeld_tol=1e-9, attack="sign_flip",
                           num_byzantine=1, clip_radius=2.5, trim=1)
        # Dense reference: per-edge attacks + masked rules on full arrays.
        M = jnp.asarray(topo.neighbor_mask)
        E = build_exchange({"a": g1, "b": g2}, cfg.attack_config(), M,
                           jnp.arange(4) < 1)
        ref = masked_aggregate(name, E, M, max_iters=100, tol=1e-9,
                               num_groups=4, trim=1, num_byzantine=1,
                               clip_radius=2.5,
                               mixing=jnp.asarray(topo.mixing, jnp.float32) * M)
        outs = {}
        for comm in ("gather", "sharded"):
            def agg_fn(a, b, comm=comm):
                out = decentralized_aggregate(
                    {"a": a[0], "b": b[0]}, cfg, topo, comm=comm,
                    worker_axes=wa, model_axes=("model",), num_workers=4)
                return out["a"][None], out["b"][None]
            outs[comm] = sm(agg_fn)(g1, g2)
        # Both comm modes match the dense reference AND each other,
        # PER NODE (each worker row is that node's own aggregate).
        for comm, o in outs.items():
            np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]),
                                       atol=5e-5, err_msg=tname + comm + " a")
            np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]),
                                       atol=5e-5, err_msg=tname + comm + " b")
        for x, y in zip(outs["gather"], outs["sharded"]):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=5e-5)
        print("DECENTRALIZED_AGREE", tname, name)

    # PARAMETER-channel messages over a TIME-VARYING schedule: the wire now
    # carries half-stepped models and round_index=1 must select the cyclic
    # schedule's SECOND graph inside shard_map in both comm modes, matching
    # the dense masked reference built from that same round's mask.
    from repro.topology import cyclic_schedule
    sched = cyclic_schedule([get_topology("ring", 4),
                             get_topology("torus2d", 4)])
    h1 = g1 - 0.05 * jax.random.normal(jax.random.PRNGKey(3), g1.shape)
    h2 = g2 - 0.05 * jax.random.normal(jax.random.PRNGKey(4), g2.shape)
    cfg = RobustConfig(aggregator=name, weiszfeld_iters=100,
                       weiszfeld_tol=1e-9, attack="sign_flip",
                       num_byzantine=1, clip_radius=2.5, trim=1,
                       gossip="params")
    topo1 = sched.topologies[1]
    M1 = jnp.asarray(topo1.neighbor_mask)
    E1 = build_exchange({"a": h1, "b": h2}, cfg.attack_config(), M1,
                        jnp.arange(4) < 1)
    ref1 = masked_aggregate(name, E1, M1, max_iters=100, tol=1e-9,
                            num_groups=4, trim=1, num_byzantine=1,
                            clip_radius=2.5,
                            mixing=jnp.asarray(topo1.mixing, jnp.float32) * M1)
    # ... on the (pod, data) mesh AND a 1-axis (data,) worker mesh.
    mesh1 = compat.make_mesh((4, 2), ("data", "model"))
    sm1 = partial(compat.shard_map, mesh=mesh1,
                  in_specs=(P("data", "model"), P("data", None, "model")),
                  out_specs=(P("data", "model"), P("data", None, "model")),
                  check_vma=False)
    for axes_label, waxes, smap in (("pod-data", wa, sm),
                                    ("data", ("data",), sm1)):
        for comm in ("gather", "sharded"):
            def agg_fn(a, b, comm=comm, waxes=waxes):
                out = decentralized_aggregate(
                    {"a": a[0], "b": b[0]}, cfg, sched, comm=comm,
                    worker_axes=waxes, model_axes=("model",), num_workers=4,
                    round_index=jnp.asarray(1, jnp.int32))
                return out["a"][None], out["b"][None]
            got = smap(agg_fn)(h1, h2)
            tag = "params " + axes_label + " " + comm
            np.testing.assert_allclose(np.asarray(got[0]),
                                       np.asarray(ref1["a"]),
                                       atol=5e-5, err_msg=tag + " a")
            np.testing.assert_allclose(np.asarray(got[1]),
                                       np.asarray(ref1["b"]),
                                       atol=5e-5, err_msg=tag + " b")
            print("PARAMS_SCHEDULE_AGREE", axes_label, comm, name)
"""


@pytest.mark.parametrize("name", AGGREGATOR_NAMES)
def test_every_aggregator_decentralized_on_pod_mesh(name):
    """Every registry aggregator aggregates decentralized on ring / torus2d
    / erdos_renyi in BOTH comm modes on a (2, 2, 2) multi-pod mesh, within
    tolerance of the dense masked reference (the acceptance matrix) -- for
    gradient messages on fixed graphs AND parameter messages over a
    time-varying cyclic schedule (round_index selection inside shard_map)."""
    out = run_py(f"    name = {name!r}\n" + _DECENTRALIZED_CASE, timeout=600)
    for tname in ("ring", "torus2d", "erdos_renyi"):
        assert f"DECENTRALIZED_AGREE {tname} {name}" in out
    for axes_label in ("pod-data", "data"):
        for comm in ("gather", "sharded"):
            assert f"PARAMS_SCHEDULE_AGREE {axes_label} {comm} {name}" in out


def test_decentralized_train_step_agrees_with_master_on_complete_graph():
    """Cross-path consistency for BOTH gossip modes: on the complete graph
    with the mean rule and no attack, every node's masked neighborhood is
    the whole federation with uniform Metropolis weights, so ONE
    decentralized train step from a replicated init must reproduce the
    master step's parameters on every node (and keep the copies in exact
    consensus).  For params gossip this additionally needs the LINEAR sgd
    optimizer: mean_i(x - lr*g_i) = x - lr*mean_i(g_i)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.topology import get_topology

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        train = TrainConfig(optimizer="sgd", lr=0.1)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
            key = jax.random.PRNGKey(9)
            robust = RobustConfig(aggregator="mean", vr="sgd", attack="none")
            mstep, _, _ = steps_lib.make_train_step(model, robust, train, mesh)
            mstate = {"params": params, "opt": (), "step": jnp.zeros((), jnp.int32)}
            mstate, _ = jax.jit(mstep)(mstate, batch, key)
            for gossip in ("gradient", "params"):
                drobust = RobustConfig(aggregator="mean", vr="sgd",
                                       attack="none", gossip=gossip)
                dstep, _, _ = steps_lib.make_decentralized_train_step(
                    model, drobust, train, mesh, get_topology("complete", 4))
                nodes = jax.tree_util.tree_map(
                    lambda p: jnp.broadcast_to(p[None], (4,) + p.shape) + 0, params)
                dstate = {"params": nodes, "opt": (), "step": jnp.zeros((), jnp.int32)}
                dstate, dm = jax.jit(dstep)(dstate, batch, key)
                assert float(dm["consensus_dist"]) < 1e-8, (gossip, float(dm["consensus_dist"]))
                for m, d in zip(jax.tree_util.tree_leaves(mstate["params"]),
                                jax.tree_util.tree_leaves(dstate["params"])):
                    dn = np.asarray(d, np.float32)
                    mn = np.asarray(m, np.float32)
                    for node in range(4):
                        np.testing.assert_allclose(dn[node], mn, rtol=2e-3,
                                                   atol=2e-4, err_msg=gossip)
                print("COMPLETE_EQUALS_MASTER", gossip)
    """, timeout=600)
    assert "COMPLETE_EQUALS_MASTER gradient" in out
    assert "COMPLETE_EQUALS_MASTER params" in out


def test_params_gossip_train_step_gather_vs_sharded_on_schedule():
    """End-to-end params-gossip decentralized training over a time-varying
    erdos_renyi schedule on a 1-axis worker mesh: the gather and sharded
    comm modes must produce the same per-node parameters after two steps
    (the schedule's round counter advances inside the compiled step)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        train = TrainConfig(optimizer="sgd", lr=0.05)
        outs = {}
        for comm in ("gather", "sharded"):
            robust = RobustConfig(aggregator="geomed", vr="sgd",
                                  attack="sign_flip", num_byzantine=1,
                                  comm=comm, weiszfeld_iters=32,
                                  weiszfeld_tol=1e-9, gossip="params",
                                  topology="ring", schedule="erdos_renyi",
                                  schedule_period=2, topology_p=0.7,
                                  topology_seed=1)  # seed 0 draws a
                                  # window-disconnected pair at N=4
            step_fn, _, _ = steps_lib.make_decentralized_train_step(
                model, robust, train, mesh, robust.topology)
            with compat.use_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                nodes = jax.tree_util.tree_map(
                    lambda p: jnp.broadcast_to(p[None], (4,) + p.shape) + 0,
                    params)
                state = {"params": nodes, "opt": (),
                         "step": jnp.zeros((), jnp.int32)}
                jstep = jax.jit(step_fn)
                for i in range(2):
                    batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
                    state, m = jstep(state, batch, jax.random.PRNGKey(9))
                outs[comm] = state["params"]
            assert np.isfinite(float(m["consensus_dist"]))
        for a, b in zip(jax.tree_util.tree_leaves(outs["gather"]),
                        jax.tree_util.tree_leaves(outs["sharded"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-4)
        print("PARAMS_SCHEDULE_TRAIN_EQUAL")
    """, timeout=600)
    assert "PARAMS_SCHEDULE_TRAIN_EQUAL" in out


@pytest.mark.parametrize("attack", ATTACK_NAMES)
def test_every_attack_runs_stacked_on_pod_data_mesh(attack):
    """Registry coverage (the _ATTACKS dict is the single source of truth):
    every attack name runs through apply_attack_stacked on messages sharded
    over a (pod, data) worker-axis mesh, leaving honest rows bit-intact and
    matching the unsharded result."""
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.attacks import _ATTACKS, ATTACK_NAMES, AttackConfig, apply_attack_stacked
        assert ATTACK_NAMES == tuple(_ATTACKS)  # derived, not hand-spliced
        attack = {attack!r}
        cfg = AttackConfig(name=attack, num_byzantine=3,
                           gaussian_variance=9.0)
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        msgs = {{"g": jax.random.normal(jax.random.PRNGKey(0), (8, 16)),
                 "h": jax.random.normal(jax.random.PRNGKey(1), (8, 4, 4))}}
        key = jax.random.PRNGKey(2)
        ref = apply_attack_stacked(cfg, msgs, key)

        def attacked(m):
            m = jax.tree_util.tree_map(
                lambda z: jax.lax.with_sharding_constraint(
                    z, jax.sharding.NamedSharding(
                        mesh, P(("pod", "data")))), m)
            return apply_attack_stacked(cfg, m, key)

        with compat.use_mesh(mesh):
            got = jax.jit(attacked)(msgs)
        for k in msgs:
            g = np.asarray(got[k]); r = np.asarray(ref[k])
            if attack != "nan":   # the nan fault is non-finite by contract
                assert np.isfinite(g).all(), attack
            np.testing.assert_array_equal(g[3:], np.asarray(msgs[k])[3:])
            if attack == "gaussian":
                # Draw layout depends on how jit partitions the RNG; check
                # the structural contract (centered on the honest mean)
                # like tests/test_attacks.py::test_stacked_gaussian_rows.
                hm = np.asarray(msgs[k])[3:].mean(axis=0)
                assert np.abs((g[:3] - hm[None]).mean()) < 3.0, attack
            else:
                np.testing.assert_allclose(g, r, rtol=1e-5, atol=1e-6,
                                           err_msg=attack + " " + k)
        print("ATTACK_OK", attack)
    """, timeout=600)
    assert f"ATTACK_OK {attack}" in out


def test_weiszfeld_blockwise_sharded_edge_cases():
    """geomed_blockwise on comm='sharded' with the shapes the happy-path
    sweep never hits: a SINGLE-leaf pytree (block count 1 < worker count)
    and a 3-leaf pytree (block count not a multiple of the 4 workers), both
    with total coordinate counts that force the padding/dummy-block path."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig, sharded_aggregate
        from repro.core.aggregators import geomed_blockwise_agg
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = RobustConfig(aggregator="geomed_blockwise", weiszfeld_iters=150,
                           weiszfeld_tol=1e-10)
        cases = {
            "single_leaf": {"only": jax.random.normal(jax.random.PRNGKey(0), (4, 10))},
            "three_leaves": {
                "a": jax.random.normal(jax.random.PRNGKey(1), (4, 6)),
                "b": jax.random.normal(jax.random.PRNGKey(2), (4, 3, 3)),
                "c": jax.random.normal(jax.random.PRNGKey(3), (4, 7)),
            },
        }
        for label, payload in cases.items():
            ref = geomed_blockwise_agg(payload, max_iters=150, tol=1e-10)
            in_specs = tuple(P("data", *([None] * (z.ndim - 1)))
                             for z in payload.values())
            out_specs = tuple(P(*([None] * (z.ndim - 1)))
                              for z in payload.values())
            keys = list(payload)
            def agg_fn(*leaves):
                local = {k: z[0] for k, z in zip(keys, leaves)}
                out = sharded_aggregate(local, cfg, worker_axes=("data",),
                                        model_axes=(), num_workers=4)
                return tuple(out[k] for k in keys)
            got = compat.shard_map(agg_fn, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_vma=False)(
                *payload.values())
            for k, o in zip(keys, got):
                np.testing.assert_allclose(np.asarray(o), np.asarray(ref[k]),
                                           atol=5e-5, err_msg=label + " " + k)
            print("BLOCKWISE_OK", label)
    """, timeout=600)
    assert "BLOCKWISE_OK single_leaf" in out
    assert "BLOCKWISE_OK three_leaves" in out


@pytest.mark.slow  # ~60s on a small runner: two full save/resume cycles
def test_distributed_resume_is_bit_exact():
    """Full-train-state checkpointing (params + Adam moments + SAGA
    table/avg + step): training 5 steps straight equals training 3 steps,
    checkpointing, restoring into a fresh state, and training 2 more --
    bit-exact on every leaf (same jitted step, same batches)."""
    out = run_py("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.core.saga import saga_init_zeros
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        robust = RobustConfig(aggregator="geomed", vr="saga", attack="gaussian",
                              num_byzantine=1, weiszfeld_iters=8)
        step_fn, _, _ = steps_lib.make_train_step(
            model, robust, TrainConfig(optimizer="adamw", lr=1e-3), mesh,
            saga_num_samples=2)
        key = jax.random.PRNGKey(0)
        with compat.use_mesh(mesh):
            params = model.init(key)
            opt = get_optimizer("adamw", 1e-3)
            def fresh():
                return {"params": params, "opt": opt.init(params),
                        "step": jnp.zeros((), jnp.int32),
                        "vr": saga_init_zeros(params, 4, 2)}
            jstep = jax.jit(step_fn)
            def run(state, lo, hi):
                for i in range(lo, hi):
                    batch = make_batch(jax.random.fold_in(key, 100 + i), cfg, 4, 2, 32)
                    state, _ = jstep(state, batch, jax.random.fold_in(key, i))
                return state
            straight = run(fresh(), 0, 5)
            ckpt = CheckpointManager(tempfile.mkdtemp())
            ckpt.save_train_state(3, run(fresh(), 0, 3))
            step0, restored = ckpt.restore_latest(fresh())
            assert step0 == 3
            resumed = run(restored, 3, 5)
        paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(straight)[0]]
        for path, a, b in zip(paths, jax.tree_util.tree_leaves(straight),
                              jax.tree_util.tree_leaves(resumed)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32),
                                          err_msg=str(path))
        print("RESUME_BIT_EXACT")
    """, timeout=600)
    assert "RESUME_BIT_EXACT" in out


def test_sharded_krum_selection_index_regression():
    """Seeded gaussian attack, W=8 messages (5 honest + 3 Byzantine) on a
    (2, 4, 1) multi-pod mesh: krum's selection index is pinned to honest
    worker 2 by the seeds, and the sharded path (coordinate all_to_all +
    partial-Gram psum) must return exactly that worker's message."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig, krum_scores, sharded_aggregate
        from repro.core.aggregators import _pairwise_sq_dists
        from repro.core.attacks import AttackConfig, apply_attack
        honest = jax.random.normal(jax.random.PRNGKey(41), (5, 16))
        msgs = apply_attack(AttackConfig(name="gaussian", num_byzantine=3,
                                         gaussian_variance=100.0),
                            {"g": honest}, jax.random.PRNGKey(7))["g"]
        scores = krum_scores(_pairwise_sq_dists({"g": msgs}), 3)
        assert int(jnp.argmin(scores)) == 2, np.asarray(scores)  # seed-pinned
        mesh = compat.make_mesh((2, 4, 1), ("pod", "data", "model"))
        cfg = RobustConfig(aggregator="krum", num_byzantine=3)
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P(("pod", "data"), "model"),),
                     out_specs=P("model"), check_vma=False)
        got = sm(lambda g: sharded_aggregate(
            {"g": g[0]}, cfg, worker_axes=("pod", "data"),
            model_axes=("model",), num_workers=8)["g"])(msgs)
        # Krum SELECTS, so the sharded result is bit-exact, not just close.
        np.testing.assert_array_equal(np.asarray(got), np.asarray(msgs[2]))
        print("KRUM_SELECTS_2")
    """)
    assert "KRUM_SELECTS_2" in out


def test_train_step_runs_on_mesh_and_attack_is_neutralized():
    """Train on a FIXED batch so the learning signal is deterministic: with
    sign_flip magnitude -3 and W=4/B=1 the mean aggregate is exactly zero
    (the attack cancels the honest sum), so mean-aggregated training cannot
    move, while geomed discards the Byzantine row and learns."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("qwen2-7b").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        results = {}
        for agg in ("geomed", "mean"):
            robust = RobustConfig(aggregator=agg, vr="sgd", attack="sign_flip",
                                  num_byzantine=1, weiszfeld_iters=16)
            step_fn, _, _ = steps_lib.make_train_step(
                model, robust, TrainConfig(optimizer="adamw", lr=1e-3), mesh)
            with compat.use_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                opt = get_optimizer("adamw", 1e-3)
                state = {"params": params, "opt": opt.init(params),
                         "step": jnp.zeros((), jnp.int32)}
                jstep = jax.jit(step_fn)
                key = jax.random.PRNGKey(1)
                batch = make_batch(key, cfg, 4, 2, 32)
                losses = []
                for i in range(8):
                    state, m = jstep(state, batch, jax.random.fold_in(key, 100+i))
                    losses.append(float(m["loss"]))
            results[agg] = losses
        # geomed neutralizes the attack and fits the batch; the zeroed mean
        # aggregate leaves the model stuck at its initial loss.
        assert results["geomed"][-1] < results["geomed"][0] - 1.0, results["geomed"]
        assert results["geomed"][-1] < results["mean"][-1] - 1.0, results
        assert abs(results["mean"][-1] - results["mean"][0]) < 0.2, results["mean"]
        print("ROBUST", results["geomed"][0], "->", results["geomed"][-1],
              "| mean stuck at", results["mean"][-1])
    """)
    assert "ROBUST" in out


def test_sharded_comm_equals_gather_comm_training():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        outs = {}
        for comm in ("gather", "sharded"):
            robust = RobustConfig(aggregator="geomed", vr="sgd", attack="sign_flip",
                                  num_byzantine=1, comm=comm,
                                  weiszfeld_iters=32, weiszfeld_tol=1e-9)
            step_fn, _, _ = steps_lib.make_train_step(
                model, robust, TrainConfig(optimizer="sgd", lr=0.1), mesh)
            with compat.use_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                state = {"params": params, "opt": (), "step": jnp.zeros((), jnp.int32)}
                batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
                state, _ = jax.jit(step_fn)(state, batch, jax.random.PRNGKey(9))
                outs[comm] = state["params"]
        for a, b in zip(jax.tree_util.tree_leaves(outs["gather"]),
                        jax.tree_util.tree_leaves(outs["sharded"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=2e-3, atol=2e-4)
        print("EQUAL")
    """)
    assert "EQUAL" in out


def test_saga_distributed_train_step():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.core.saga import saga_init_zeros
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        robust = RobustConfig(aggregator="geomed", vr="saga", attack="gaussian",
                              num_byzantine=1, weiszfeld_iters=8)
        step_fn, _, sstructs = steps_lib.make_train_step(
            model, robust, TrainConfig(optimizer="sgd", lr=0.05), mesh,
            saga_num_samples=4)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            state = {"params": params, "opt": (), "step": jnp.zeros((), jnp.int32),
                     "vr": saga_init_zeros(params, 4, 4)}
            jstep = jax.jit(step_fn)
            for i in range(3):
                batch = make_batch(jax.random.fold_in(jax.random.PRNGKey(2), i), cfg, 4, 2, 32)
                state, m = jstep(state, batch, jax.random.fold_in(jax.random.PRNGKey(3), i))
            assert jnp.isfinite(m["loss"])
            # table must have absorbed gradients (non-zero rows)
            tabs = jax.tree_util.tree_leaves(state["vr"].table)
            total = sum(float(jnp.sum(jnp.abs(t.astype(jnp.float32)))) for t in tabs)
            assert total > 0
        print("SAGA_OK", float(m["loss"]))
    """)
    assert "SAGA_OK" in out


def test_dryrun_single_combo_small_devices():
    """Exercise dryrun.lower_one end-to-end on an 8-device (2x4) stand-in
    via the same code path (mesh shrunk through make_host_mesh monkeypatch)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch import dryrun, mesh as mesh_lib
        mesh_lib.make_production_mesh = lambda multi_pod=False: (
            mesh_lib.make_host_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else mesh_lib.make_host_mesh((4, 2), ("data", "model")))
        dryrun.mesh_lib = mesh_lib
        for mp in (False, True):
            rec = dryrun.lower_one("whisper-tiny", "train_4k", multi_pod=mp)
            assert rec["flops_per_device"] > 0
            assert rec["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")
        print("DRYRUN_OK")
    """, timeout=600)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_packed_aggregation_matches_perleaf_distributed():
    """DESIGN.md Sec. 8 on the shard_map paths: for EVERY registry
    aggregator the packed gather master (one packed all_gather + flat
    engine) agrees with the per-leaf baseline (packed=False), and the
    sharded path (coordinate-packed internally either way) agrees with
    both; the selection rule (krum) is bit-exact.  Same sweep for the
    DECENTRALIZED per-node aggregation (masked flat engine) in both comm
    modes under a per-edge attack."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig, distributed_aggregate, sharded_aggregate
        from repro.core.aggregators import AGGREGATOR_NAMES
        from repro.topology import decentralized_aggregate, get_topology

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P("data","model"), P("data",None,"model")),
                     out_specs=(P("model"), P(None,"model")), check_vma=False)
        smd = partial(compat.shard_map, mesh=mesh,
                      in_specs=(P("data","model"), P("data",None,"model")),
                      out_specs=(P("data","model"), P("data",None,"model")),
                      check_vma=False)
        topo = get_topology("ring", 4)
        for name in AGGREGATOR_NAMES:
            cfg = RobustConfig(aggregator=name, weiszfeld_iters=60,
                               weiszfeld_tol=1e-9, num_byzantine=1,
                               clip_radius=2.5, num_groups=3,
                               attack="sign_flip")
            outs = {}
            for packed in (True, False):
                c = dataclasses.replace(cfg, packed=packed)
                outs[packed] = sm(lambda a, b: tuple(distributed_aggregate(
                    {"a": a[0], "b": b[0]}, c, worker_axes=("data",),
                    model_axes=("model",)).values()))(g1, g2)
            sh = sm(lambda a, b: tuple(sharded_aggregate(
                {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",),
                model_axes=("model",), num_workers=4).values()))(g1, g2)
            for label, got in (("perleaf", outs[False]), ("sharded", sh)):
                for x, y in zip(outs[True], got):
                    if name == "krum" and label == "perleaf":
                        np.testing.assert_array_equal(
                            np.asarray(x), np.asarray(y), err_msg=name)
                    else:
                        np.testing.assert_allclose(
                            np.asarray(x), np.asarray(y), atol=3e-5,
                            err_msg=f"{name} {label}")

            def dec(c, comm):
                def f(a, b):
                    out = decentralized_aggregate(
                        {"a": a[0], "b": b[0]}, c, topo, comm=comm,
                        worker_axes=("data",), model_axes=("model",),
                        num_workers=4, key=jax.random.PRNGKey(5))
                    return tuple(jax.tree_util.tree_map(
                        lambda x: x[None], out).values())
                return smd(f)(g1, g2)

            d_out = {}
            for packed in (True, False):
                d_out[packed] = dec(dataclasses.replace(cfg, packed=packed),
                                    "gather")
            d_sh = dec(cfg, "sharded")
            for label, got in (("perleaf", d_out[False]), ("sharded", d_sh)):
                for x, y in zip(d_out[True], got):
                    np.testing.assert_allclose(
                        np.asarray(x), np.asarray(y), atol=3e-5,
                        err_msg=f"decentralized {name} {label}")
            print("PACKED_OK", name)
    """, timeout=900)
    for name in AGGREGATOR_NAMES:
        assert f"PACKED_OK {name}" in out


def test_fused_topology_kernel_wired_into_sharded_path():
    """The PR-3 leftover closed: the sharded decentralized trimmed-mean
    routes through the fused Pallas masked-neighborhood kernel
    (use_topology_kernel=True, interpret mode on CPU) and agrees with the
    jnp flat path."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig
        from repro.topology import decentralized_aggregate, get_topology

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        topo = get_topology("complete", 4)
        cfg = RobustConfig(aggregator="trimmed_mean", trim=1,
                           attack="sign_flip", num_byzantine=1)
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P("data","model"), P("data",None,"model")),
                     out_specs=(P("data","model"), P("data",None,"model")),
                     check_vma=False)

        def run(use_kernel):
            def f(a, b):
                out = decentralized_aggregate(
                    {"a": a[0], "b": b[0]}, cfg, topo, comm="sharded",
                    worker_axes=("data",), model_axes=("model",),
                    num_workers=4, key=jax.random.PRNGKey(5),
                    use_topology_kernel=use_kernel)
                return tuple(jax.tree_util.tree_map(
                    lambda x: x[None], out).values())
            return sm(f)(g1, g2)

        ref, ker = run(False), run(True)
        for x, y in zip(ker, ref):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=2e-5)
        print("TOPOLOGY_KERNEL_WIRED")
    """, timeout=600)
    assert "TOPOLOGY_KERNEL_WIRED" in out


@pytest.mark.slow
def test_train_step_packed_matches_perleaf_on_mesh():
    """End-to-end make_train_step: two steps of geomed training under
    sign_flip, packed vs per-leaf, on both comm modes (deterministic
    attack -- the gaussian RNG layout under auto-jit partitioning is the
    pre-existing caveat of test_every_attack_runs_stacked)."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32,
                            loss_chunk=32)
        train = TrainConfig(optimizer="adamw", lr=1e-3)
        from repro.core.saga import saga_init_zeros
        for comm in ("gather", "sharded"):
            outs = {}
            for packed in (True, False):
                robust = RobustConfig(aggregator="geomed", vr="saga",
                                      attack="sign_flip", num_byzantine=1,
                                      comm=comm, weiszfeld_iters=16,
                                      weiszfeld_tol=1e-9, packed=packed)
                step_fn, _, _ = steps_lib.make_train_step(
                    model, robust, train, mesh, saga_num_samples=2)
                with compat.use_mesh(mesh):
                    params = model.init(jax.random.PRNGKey(0))
                    opt = get_optimizer("adamw", 1e-3)
                    state = {"params": params, "opt": opt.init(params),
                             "step": jnp.zeros((), jnp.int32),
                             "vr": saga_init_zeros(params, 4, 2)}
                    jstep = steps_lib.compile_train_step(step_fn)
                    key = jax.random.PRNGKey(1)
                    for i in range(2):
                        batch = make_batch(jax.random.fold_in(key, i), cfg,
                                           4, 2, 32)
                        state, m = jstep(state, batch,
                                         jax.random.fold_in(key, 100 + i))
                    outs[packed] = state["params"]
            for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                            jax.tree_util.tree_leaves(outs[False])):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           rtol=2e-3, atol=2e-4,
                                           err_msg=comm)
            print("TRAIN_PACKED_OK", comm)
    """, timeout=900)
    assert "TRAIN_PACKED_OK gather" in out
    assert "TRAIN_PACKED_OK sharded" in out


def test_require_distributed_and_comm_validation():
    """Capability probe degrades with a clear error, not an AttributeError
    from inside jit: bogus comm modes are rejected at step-build time."""
    out = run_py("""
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.models.api import build_model

        assert compat.HAS_SHARD_MAP
        compat.require_distributed(min_devices=8)
        try:
            compat.require_distributed(min_devices=10**6)
        except RuntimeError as e:
            assert "device" in str(e)
        else:
            raise AssertionError("expected RuntimeError for device count")

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        try:
            steps_lib.make_train_step(
                model, RobustConfig(comm="bogus"), TrainConfig(), mesh)
        except ValueError as e:
            assert "gather" in str(e) and "sharded" in str(e)
        else:
            raise AssertionError("expected ValueError for bogus comm")
        print("PROBE_OK")
    """)
    assert "PROBE_OK" in out


# ---------------------------------------------------------------------------
# Client-scale virtualization (DESIGN.md Sec. 10): partial participation +
# bounded-staleness weighting across the execution paths.
# ---------------------------------------------------------------------------

def test_weighted_aggregation_sim_vs_gather_vs_sharded():
    """The weighted flat engines are ONE implementation surfaced three
    ways: the host (sim) packed engine, the gather master, and the
    sharded coordinate-slice master must agree for every registry
    aggregator under the same per-row staleness weights (incl. an exact
    weight-0 row -- the dropout mask-out)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import (AGGREGATOR_NAMES, RobustConfig,
                                distributed_aggregate, packing,
                                sharded_aggregate)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        rw = jnp.asarray([1.0, 0.0, 1.0, 0.5], jnp.float32)
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P("data", "model"), P("data", None, "model"),
                               P()),
                     out_specs=(P("model"), P(None, "model")),
                     check_vma=False)
        for name in AGGREGATOR_NAMES:
            cfg = RobustConfig(aggregator=name, weiszfeld_iters=100,
                               weiszfeld_tol=1e-9, num_byzantine=1,
                               clip_radius=2.5)
            msgs = {"a": g1, "b": g2}
            spec = packing.pack_spec(msgs)
            vec = cfg.flat_aggregator_fn(spec)(spec.pack(msgs),
                                               row_weights=rw)
            ref = spec.unpack(vec, batch_ndim=0)
            got = sm(lambda a, b, w: tuple(distributed_aggregate(
                {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",),
                model_axes=("model",), row_weights=w).values()))(g1, g2, rw)
            got_s = sm(lambda a, b, w: tuple(sharded_aggregate(
                {"a": a[0], "b": b[0]}, cfg, worker_axes=("data",),
                model_axes=("model",), num_workers=4,
                row_weights=w).values()))(g1, g2, rw)
            for comm, o in (("gather", got), ("sharded", got_s)):
                np.testing.assert_allclose(np.asarray(o[0]),
                                           np.asarray(ref["a"]), atol=5e-5,
                                           err_msg=f"{comm} {name} a")
                np.testing.assert_allclose(np.asarray(o[1]),
                                           np.asarray(ref["b"]), atol=5e-5,
                                           err_msg=f"{comm} {name} b")
        print("WEIGHTED_AGREE")
    """, timeout=600)
    assert "WEIGHTED_AGREE" in out


@pytest.mark.slow
def test_full_participation_train_step_is_bit_exact_with_master():
    """The participation refactor's bit-exactness pin: num_clients equal to
    the worker count (and num_clients=0) must compile the SAME master step
    -- parameters AND resident VR state (saga table / lsvrg anchor)
    bitwise identical after 3 steps, for both VR methods."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        train = TrainConfig(optimizer="sgd", lr=0.05)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
            for vr in ("saga", "lsvrg"):
                outs = {}
                for nc in (0, 4):
                    robust = RobustConfig(aggregator="geomed", vr=vr,
                                          attack="sign_flip", num_byzantine=1,
                                          weiszfeld_iters=8, num_clients=nc)
                    step_fn, _, sstructs = steps_lib.make_train_step(
                        model, robust, train, mesh, saga_num_samples=4)
                    st = sstructs()
                    assert "staleness" not in st, nc  # full-participation bypass
                    state = {"params": params, "opt": (),
                             "step": jnp.zeros((), jnp.int32),
                             "vr": jax.tree_util.tree_map(
                                 lambda s: jnp.zeros(s.shape, s.dtype),
                                 st["vr"])}
                    jstep = jax.jit(step_fn)
                    for i in range(3):
                        state, m = jstep(state, batch,
                                         jax.random.fold_in(jax.random.PRNGKey(3), i))
                    outs[nc] = state
                for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                                jax.tree_util.tree_leaves(outs[4])):
                    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                                  err_msg=vr)
                print("BIT_EXACT", vr)
    """, timeout=600)
    assert "BIT_EXACT saga" in out
    assert "BIT_EXACT lsvrg" in out


@pytest.mark.slow
def test_sampled_cohort_train_gather_vs_sharded_agree():
    """Sampled-cohort training (8 virtual clients on the 4-slot mesh, with
    a staleness attack in the mix) must produce the same parameters and the
    IDENTICAL integer staleness counters via the gather and sharded comm
    paths, separately jitted."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        train = TrainConfig(optimizer="sgd", lr=0.05)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
            outs = {}
            for comm in ("gather", "sharded"):
                robust = RobustConfig(aggregator="geomed", vr="saga",
                                      attack="straggler", num_byzantine=1,
                                      weiszfeld_iters=32, weiszfeld_tol=1e-9,
                                      comm=comm, num_clients=8)
                step_fn, _, sstructs = steps_lib.make_train_step(
                    model, robust, train, mesh, saga_num_samples=4)
                st = sstructs()
                assert st["staleness"].shape == (8,)
                state = {"params": params, "opt": (),
                         "step": jnp.zeros((), jnp.int32),
                         "vr": jax.tree_util.tree_map(
                             lambda s: jnp.zeros(s.shape, s.dtype), st["vr"]),
                         "staleness": jnp.zeros((8,), jnp.int32)}
                jstep = jax.jit(step_fn)
                for i in range(3):
                    state, m = jstep(state, batch,
                                     jax.random.fold_in(jax.random.PRNGKey(3), i))
                outs[comm] = state
                assert np.isfinite(float(m["loss"])), comm
            np.testing.assert_array_equal(
                np.asarray(outs["gather"]["staleness"]),
                np.asarray(outs["sharded"]["staleness"]))
            for a, b in zip(jax.tree_util.tree_leaves(outs["gather"]["params"]),
                            jax.tree_util.tree_leaves(outs["sharded"]["params"])):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           rtol=2e-3, atol=2e-4)
        print("COHORT_PATHS_AGREE")
    """, timeout=600)
    assert "COHORT_PATHS_AGREE" in out


@pytest.mark.slow
def test_every_attack_runs_with_participation_on_pod_mesh():
    """Attack x participation x topology coverage on the (2, 2, 2) pod
    mesh: every registry attack aggregates without raising (finite output)
    under full AND sampled-cohort row weights, through the star
    (distributed_aggregate) and ring (decentralized_aggregate) paths."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import RobustConfig, distributed_aggregate
        from repro.core.robust_step import distributed_attack
        from repro.core.attacks import _ATTACKS, ATTACK_NAMES, FAULT_ATTACKS
        from repro.core import participation as part
        from repro.topology import decentralized_aggregate, get_topology
        assert "straggler" in _ATTACKS and "dropout" in _ATTACKS
        wa = ("pod", "data")
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
        topo = get_topology("ring", 4)
        sm = partial(compat.shard_map, mesh=mesh,
                     in_specs=(P(wa, "model"), P(wa, None, "model"), P()),
                     out_specs=(P("model"), P(None, "model")),
                     check_vma=False)
        smd = partial(compat.shard_map, mesh=mesh,
                      in_specs=(P(wa, "model"), P(wa, None, "model"), P()),
                      out_specs=(P(wa, "model"), P(wa, None, "model")),
                      check_vma=False)
        stal = jnp.array([0, 2, 0, 1], jnp.int32)
        for attack in ATTACK_NAMES:
            # Fault attacks inject non-finite / overflow payloads the bare
            # rules cannot digest; they run with the containment guards on
            # (which is also their registry-coverage for this mesh).
            cfg = RobustConfig(aggregator="geomed", attack=attack,
                               num_byzantine=1, weiszfeld_iters=16,
                               gaussian_variance=4.0,
                               guards=attack in FAULT_ATTACKS)
            slot = part.slot_staleness(stal, attack, 1, straggler_k=4,
                                       max_staleness=64, byz_first=True)
            sampled = part.staleness_weights(slot, decay=1.0,
                                             max_staleness=64)
            for label, rw in (("full", None), ("sampled", sampled)):
                def star_fn(a, b, w, rw=rw):
                    m = distributed_attack({"a": a[0], "b": b[0]}, cfg,
                                           worker_axes=wa,
                                           key=jax.random.PRNGKey(7))
                    return tuple(distributed_aggregate(
                        m, cfg, worker_axes=wa, model_axes=("model",),
                        row_weights=None if rw is None else w).values())
                star = sm(star_fn)(g1, g2, sampled)
                ring = smd(lambda a, b, w, rw=rw: (lambda o:
                    (o["a"][None], o["b"][None]))(decentralized_aggregate(
                        {"a": a[0], "b": b[0]}, cfg, topo,
                        worker_axes=wa, model_axes=("model",), num_workers=4,
                        key=jax.random.PRNGKey(7),
                        row_weights=None if rw is None else w,
                    )))(g1, g2, sampled)
                for path, o in (("star", star), ("ring", ring)):
                    for arr in o:
                        assert np.isfinite(np.asarray(arr)).all(), \
                            (attack, label, path)
                print("COVERED", attack, label)
        print("MATRIX_OK")
    """, timeout=600)
    assert "MATRIX_OK" in out
    for attack in ATTACK_NAMES:
        assert f"COVERED {attack} sampled" in out


# ---------------------------------------------------------------------------
# Quantized wire formats across execution paths (DESIGN.md Sec. 12).
# ---------------------------------------------------------------------------

# One wire format per subprocess, every registry aggregator inside: the
# single-host reference (round-trip the packed rows, then the flat rule)
# vs gather vs sharded on the multi-pod (2, 2, 2) worker-axis mesh.
_QUANTIZED_MULTIPOD_CASE = """
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import (AGGREGATOR_NAMES, RobustConfig,
                            distributed_aggregate, sharded_aggregate)
    wa = ("pod", "data")
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
    sm = partial(compat.shard_map, mesh=mesh,
                 in_specs=(P(wa, "model"), P(wa, None, "model")),
                 out_specs=(P("model"), P(None, "model")), check_vma=False)
    for name in AGGREGATOR_NAMES:
        cfg = RobustConfig(aggregator=name, weiszfeld_iters=100,
                           weiszfeld_tol=1e-9, num_byzantine=1,
                           clip_radius=2.5, trim=1, message_dtype=dtype)
        # Single-host reference: quantize + dequantize the stacked rows
        # with the SAME spec the distributed paths build, then run the
        # plain pytree aggregator on what the receiver would see.
        spec = cfg.message_spec({"a": g1, "b": g2}, batch_ndim=1)
        assert spec.quantized
        wire = spec.unpack(spec.wire_roundtrip(spec.pack({"a": g1, "b": g2})))
        ref = cfg.aggregator_fn()(wire)
        got = sm(lambda a, b: tuple(distributed_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=wa,
            model_axes=("model",)).values()))(g1, g2)
        got_s = sm(lambda a, b: tuple(sharded_aggregate(
            {"a": a[0], "b": b[0]}, cfg, worker_axes=wa,
            model_axes=("model",), num_workers=4).values()))(g1, g2)
        # int8 block stats reduce to the exact same amax on-mesh; sign1
        # scales agree up to f32 summation order, hence allclose.
        for comm, o in (("gather", got), ("sharded", got_s)):
            np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]),
                                       atol=2e-4, err_msg=f"{comm} {name} a")
            np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]),
                                       atol=2e-4, err_msg=f"{comm} {name} b")
        for x, y in zip(got, got_s):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=2e-4, err_msg=name)
        print("QUANTIZED_AGREE", dtype, name)
"""


@pytest.mark.parametrize("dtype", ["int8", "sign1"])
def test_every_aggregator_quantized_sim_gather_sharded_agree(dtype):
    """int8 / sign1 wire: every registry aggregator agrees (allclose)
    between the single-host round-trip reference and BOTH distributed comm
    paths on the multi-pod (pod, data) worker-axis mesh."""
    out = run_py(f"    dtype = {dtype!r}\n" + _QUANTIZED_MULTIPOD_CASE,
                 timeout=600)
    for name in AGGREGATOR_NAMES:
        assert f"QUANTIZED_AGREE {dtype} {name}" in out


# Quantized decentralized aggregation: the attacks must act on the
# DEQUANTIZED honest messages (the wire is what anyone -- including the
# adversary -- observes), so the dense masked reference round-trips the
# node rows before build_exchange.
_QUANTIZED_DECENTRALIZED_CASE = """
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import RobustConfig
    from repro.topology import (build_exchange, decentralized_aggregate,
                                get_topology, masked_aggregate)
    wa = ("pod", "data")
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    g2 = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 4))
    sm = partial(compat.shard_map, mesh=mesh,
                 in_specs=(P(wa, "model"), P(wa, None, "model")),
                 out_specs=(P(wa, "model"), P(wa, None, "model")),
                 check_vma=False)
    for tname in ("ring", "torus2d"):
        topo = get_topology(tname, 4, seed=1, p=0.7)
        cfg = RobustConfig(aggregator="geomed", weiszfeld_iters=100,
                           weiszfeld_tol=1e-9, attack="sign_flip",
                           num_byzantine=1, message_dtype=dtype)
        spec = cfg.message_spec({"a": g1, "b": g2}, batch_ndim=1)
        wire = spec.unpack(spec.wire_roundtrip(spec.pack({"a": g1, "b": g2})))
        M = jnp.asarray(topo.neighbor_mask)
        E = build_exchange(wire, cfg.attack_config(), M, jnp.arange(4) < 1)
        ref = masked_aggregate("geomed", E, M, max_iters=100, tol=1e-9)
        for comm in ("gather", "sharded"):
            def agg_fn(a, b, comm=comm):
                out = decentralized_aggregate(
                    {"a": a[0], "b": b[0]}, cfg, topo, comm=comm,
                    worker_axes=wa, model_axes=("model",), num_workers=4)
                return out["a"][None], out["b"][None]
            o = sm(agg_fn)(g1, g2)
            np.testing.assert_allclose(np.asarray(o[0]), np.asarray(ref["a"]),
                                       atol=2e-4, err_msg=tname + comm + " a")
            np.testing.assert_allclose(np.asarray(o[1]), np.asarray(ref["b"]),
                                       atol=2e-4, err_msg=tname + comm + " b")
            print("QUANTIZED_DECENTRALIZED_AGREE", dtype, tname, comm)
"""


@pytest.mark.parametrize("dtype", ["int8", "sign1"])
def test_quantized_decentralized_attacks_act_on_dequantized(dtype):
    """Non-star topologies with a quantized wire: both comm modes match
    the dense masked reference built from the ROUND-TRIPPED node rows
    (sign_flip observes the dequantized honest messages)."""
    out = run_py(f"    dtype = {dtype!r}\n" + _QUANTIZED_DECENTRALIZED_CASE,
                 timeout=600)
    for tname in ("ring", "torus2d"):
        for comm in ("gather", "sharded"):
            assert f"QUANTIZED_DECENTRALIZED_AGREE {dtype} {tname} {comm}" \
                in out


@pytest.mark.slow
def test_sampled_cohort_sign1_ef_rides_participation_across_comm_modes():
    """sign1 + error feedback under client-scale virtualization: the
    per-client EF residual table is gathered/scattered with the cohort
    exactly like the VR state.  Within one jaxpr the state evolution is
    bit-identical (re-running the gather step from the same init
    reproduces the table bit for bit); ACROSS comm modes the standing
    invariant is allclose (different XLA programs reorder the gradient
    math), so after the first step the tables agree to a couple of ulps
    with the SAME set of touched (scattered) rows, and after 3 steps
    within the usual cross-engine tolerance.  Integer staleness counters
    stay bitwise equal throughout."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model

        cfg = get_config("mamba2-130m").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32, loss_chunk=32)
        train = TrainConfig(optimizer="sgd", lr=0.05)
        with compat.use_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            batch = make_batch(jax.random.PRNGKey(5), cfg, 4, 2, 32)
            outs = {}
            for comm in ("gather", "sharded"):
                robust = RobustConfig(aggregator="geomed", vr="saga",
                                      attack="sign_flip", num_byzantine=1,
                                      weiszfeld_iters=32, weiszfeld_tol=1e-9,
                                      comm=comm, num_clients=8,
                                      message_dtype="sign1")
                step_fn, _, sstructs = steps_lib.make_train_step(
                    model, robust, train, mesh, saga_num_samples=4)
                st = sstructs()
                assert st["ef"].shape[0] == 8          # resident per CLIENT
                state = {"params": params, "opt": (),
                         "step": jnp.zeros((), jnp.int32),
                         "vr": jax.tree_util.tree_map(
                             lambda s: jnp.zeros(s.shape, s.dtype), st["vr"]),
                         "staleness": jnp.zeros((8,), jnp.int32),
                         "ef": jnp.zeros(st["ef"].shape, jnp.float32)}
                jstep = jax.jit(step_fn)

                def run3(state, jstep=jstep):
                    ef1 = None
                    for i in range(3):
                        state, m = jstep(state, batch,
                                         jax.random.fold_in(jax.random.PRNGKey(3), i))
                        if i == 0:
                            ef1 = np.asarray(state["ef"])
                    return state, ef1, m

                state0 = jax.tree_util.tree_map(lambda x: x + 0, state)
                state, ef1, m = run3(state)
                outs[comm] = state
                outs[comm + "_ef1"] = ef1
                assert np.isfinite(float(m["loss"])), comm
                if comm == "gather":
                    # Same jaxpr, same init: bit-identical EF evolution.
                    again, _, _ = run3(state0)
                    np.testing.assert_array_equal(np.asarray(state["ef"]),
                                                  np.asarray(again["ef"]))
            assert np.abs(outs["gather_ef1"]).max() > 0, "EF never updated"
            # Step 1: both modes scattered residuals into the SAME client
            # rows (identical cohort plan), agreeing to a couple of ulps.
            np.testing.assert_array_equal(
                np.abs(outs["gather_ef1"]).max(axis=1) > 0,
                np.abs(outs["sharded_ef1"]).max(axis=1) > 0)
            np.testing.assert_allclose(outs["gather_ef1"],
                                       outs["sharded_ef1"], atol=5e-7)
            np.testing.assert_allclose(np.asarray(outs["gather"]["ef"]),
                                       np.asarray(outs["sharded"]["ef"]),
                                       rtol=2e-2, atol=1e-2)
            np.testing.assert_array_equal(
                np.asarray(outs["gather"]["staleness"]),
                np.asarray(outs["sharded"]["staleness"]))
            for a, b in zip(jax.tree_util.tree_leaves(outs["gather"]["params"]),
                            jax.tree_util.tree_leaves(outs["sharded"]["params"])):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           rtol=2e-3, atol=2e-4)
        print("SIGN1_EF_COHORT_AGREE")
    """, timeout=600)
    assert "SIGN1_EF_COHORT_AGREE" in out


@pytest.mark.slow  # five 8-step model train runs in one subprocess
def test_nan_fault_contained_on_gather_and_sharded_within_2x_floor():
    """Acceptance pin for the in-graph containment layer on the DISTRIBUTED
    paths (the sim-path twin lives in tests/test_guards.py): with guards on,
    a nan-attacked run (byz < W/2) stays finite and lands within 2x the
    attack-free loss floor on both comm modes, because the poisoned rows get
    aggregation weight exactly 0; with guards off the very first nan row
    destroys the model."""
    out = run_py("""
        import math
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core import init_health
        from repro.core.robust_step import RobustConfig
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.launch.train import make_batch
        from repro.models.api import build_model
        from repro.optim import get_optimizer

        cfg = get_config("qwen2-7b").reduced()
        mesh = mesh_lib.make_host_mesh((4, 2), ("data", "model"))
        model = build_model(cfg, remat=False, q_chunk=32, kv_chunk=32,
                            loss_chunk=32)

        def train(robust, steps=8):
            step_fn, _, _ = steps_lib.make_train_step(
                model, robust, TrainConfig(optimizer="adamw", lr=1e-3), mesh)
            with compat.use_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                opt = get_optimizer("adamw", 1e-3)
                state = {"params": params, "opt": opt.init(params),
                         "step": jnp.zeros((), jnp.int32)}
                if robust.guards:
                    state["health"] = init_health()
                jstep = jax.jit(step_fn)
                key = jax.random.PRNGKey(1)
                batch = make_batch(key, cfg, 4, 2, 32)
                m = None
                for i in range(steps):
                    state, m = jstep(state, batch,
                                     jax.random.fold_in(key, 100 + i))
            return {k: float(v) for k, v in m.items()
                    if k in ("loss", "quarantined_rows", "round_accepted")}

        results = {}
        for comm in ("gather", "sharded"):
            floor = train(RobustConfig(aggregator="geomed", vr="sgd",
                                       comm=comm, weiszfeld_iters=16))
            guarded = train(RobustConfig(aggregator="geomed", vr="sgd",
                                         attack="nan", num_byzantine=1,
                                         comm=comm, guards=True,
                                         weiszfeld_iters=16))
            assert math.isfinite(guarded["loss"]), (comm, guarded)
            assert guarded["loss"] <= 2.0 * floor["loss"], (comm, guarded,
                                                            floor)
            if comm == "gather":
                # The sharded path quarantines inside sharded_aggregate and
                # does not surface the count; gather reports it.
                assert guarded["quarantined_rows"] == 1.0, (comm, guarded)
            assert guarded["round_accepted"] == 1.0, (comm, guarded)
            results[comm] = (floor["loss"], guarded["loss"])
        unguarded = train(RobustConfig(aggregator="geomed", vr="sgd",
                                       attack="nan", num_byzantine=1,
                                       comm="gather"), steps=4)
        assert not math.isfinite(unguarded["loss"]), unguarded
        print("NAN_CONTAINED", results)
    """, timeout=600)
    assert "NAN_CONTAINED" in out
