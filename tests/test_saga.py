"""SAGA table semantics (paper Alg. 1): correctness + unbiasedness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import saga


def _state(w=3, j=5, shape=(4,)):
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (w, j) + shape)
    return saga.SagaState(table=table,
                          avg=jnp.mean(table, axis=1)), w, j, shape


@pytest.mark.parametrize("fn", [saga.saga_correct, saga.saga_correct_scatter])
def test_correction_formula(fn):
    state, w, j, shape = _state()
    grads = {"p": jax.random.normal(jax.random.PRNGKey(1), (w,) + shape)}
    st = saga.SagaState(table={"p": state.table}, avg={"p": state.avg})
    idx = jnp.array([0, 3, 4], jnp.int32)
    msgs, new = fn(st, grads, idx)
    for wi in range(w):
        old = np.asarray(state.table[wi, int(idx[wi])])
        want = np.asarray(grads["p"][wi]) - old + np.asarray(state.avg[wi])
        np.testing.assert_allclose(np.asarray(msgs["p"][wi]), want, rtol=1e-5, atol=1e-6)
        # table row replaced, others untouched
        np.testing.assert_allclose(np.asarray(new.table["p"][wi, int(idx[wi])]),
                                   np.asarray(grads["p"][wi]), rtol=1e-6)
        for jj in range(5):
            if jj != int(idx[wi]):
                np.testing.assert_allclose(np.asarray(new.table["p"][wi, jj]),
                                           np.asarray(state.table[wi, jj]), rtol=1e-6)
        # avg updated incrementally
        want_avg = np.asarray(state.avg[wi]) + (np.asarray(grads["p"][wi]) - old) / 5
        np.testing.assert_allclose(np.asarray(new.avg["p"][wi]), want_avg, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_correct_equals_correct_scatter(dt):
    """The one-hot oracle and the scatter-based fast path must agree on
    messages, tables and averages, in both f32 and bf16."""
    w, j, shape = 4, 6, (3, 5)
    key = jax.random.PRNGKey(7)
    table = jax.random.normal(key, (w, j) + shape).astype(dt)
    st0 = saga.SagaState(
        table={"p": table},
        avg={"p": jnp.mean(table.astype(jnp.float32), axis=1).astype(dt)})
    grads = {"p": jax.random.normal(jax.random.PRNGKey(8), (w,) + shape).astype(dt)}
    idx = jnp.array([0, 5, 2, 2], jnp.int32)
    msgs_a, new_a = saga.saga_correct(st0, grads, idx)
    msgs_b, new_b = saga.saga_correct_scatter(st0, grads, idx)
    tol = dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(msgs_a["p"], np.float32),
                               np.asarray(msgs_b["p"], np.float32), **tol)
    np.testing.assert_allclose(np.asarray(new_a.table["p"], np.float32),
                               np.asarray(new_b.table["p"], np.float32), **tol)
    np.testing.assert_allclose(np.asarray(new_a.avg["p"], np.float32),
                               np.asarray(new_b.avg["p"], np.float32), **tol)
    assert msgs_b["p"].dtype == dt and new_b.table["p"].dtype == dt


def test_avg_consistency_after_updates():
    """After arbitrary updates, avg == mean(table) (the invariant Alg. 1
    maintains incrementally)."""
    state, w, j, shape = _state()
    st = saga.SagaState(table={"p": state.table}, avg={"p": state.avg})
    key = jax.random.PRNGKey(2)
    for t in range(10):
        key, k1, k2 = jax.random.split(key, 3)
        grads = {"p": jax.random.normal(k1, (w,) + shape)}
        idx = jax.random.randint(k2, (w,), 0, j)
        _, st = saga.saga_correct_scatter(st, grads, idx)
    np.testing.assert_allclose(np.asarray(st.avg["p"]),
                               np.asarray(jnp.mean(st.table["p"], axis=1)),
                               rtol=1e-4, atol=1e-5)


def test_unbiasedness():
    """E_i[m_w] over i uniform = full local gradient mean (paper eq. (18)):
    enumerate all J choices exactly."""
    state, w, j, shape = _state()
    st = saga.SagaState(table={"p": state.table}, avg={"p": state.avg})
    grads_true = {"p": jax.random.normal(jax.random.PRNGKey(3), (w, j) + shape)}
    msgs = []
    for i in range(j):
        idx = jnp.full((w,), i, jnp.int32)
        g_i = {"p": grads_true["p"][:, i]}
        m, _ = saga.saga_correct_scatter(st, g_i, idx)
        msgs.append(m["p"])
    mean_msg = jnp.mean(jnp.stack(msgs), axis=0)
    want = jnp.mean(grads_true["p"], axis=1)  # (1/J) sum_i f'_i(x)
    np.testing.assert_allclose(np.asarray(mean_msg), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_init_zeros_shapes():
    params = {"a": jnp.zeros((3, 2)), "b": jnp.zeros((5,))}
    st = saga.saga_init_zeros(params, num_workers=4, num_samples=6)
    assert st.table["a"].shape == (4, 6, 3, 2)
    assert st.avg["b"].shape == (4, 5)
    assert st.num_samples == 6
