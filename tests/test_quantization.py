"""Quantized wire-format properties (DESIGN.md Sec. 12).

Hypothesis-driven (with the seeded ``tests/_hypothesis_fallback.py`` shim
when the real package is absent) pins on the wire layer in isolation --
the cross-path step-level agreement pins live in
``tests/test_distributed.py`` and the convergence floors in
``tests/test_convergence.py``:

* int8 round-trip error is bounded per coordinate by the per-block
  symmetric scale: ``|decode(encode(v)) - v| <= amax_block / 254``.
* sign1 codes are EXACTLY +-1 on real coordinates (never 0; only padding
  encodes to 0), and the per-block scale is the EF-signSGD ``mean |v|``.
* Quantization is deterministic and batch-rank-agnostic: encoding a
  stacked ``(W, D)`` buffer row-by-row gives bitwise the same codes and
  scales as encoding the batch at once.
* ``message_dtype="float32"`` is a byte-identical bypass: the round-trip
  returns the SAME array object and every registry aggregator produces
  bitwise the same aggregate as the legacy raw-dtype spec.
* Error feedback: the sign1 residual carried through
  :meth:`PackSpec.transmit` conserves the message (wire + residual ==
  signal) and stays bounded over a simulated trajectory -- both a direct
  quantizer loop and a real ``make_federated_step`` run under attack.
* The :data:`WIRE_FORMATS` dict is the single registry: unknown names
  raise naming the registered set, from both the resolver and the config.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    import hypothesis.extra.numpy as hnp
except ImportError:  # keep the suite collectable without the dev extra
    from _hypothesis_fallback import hypothesis, st, hnp

from repro.core import RobustConfig, make_federated_step
from repro.core import aggregators as agg_lib
from repro.core import packing
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer

W = 8
OPTS = {"trimmed_mean": {"trim": 1}, "krum": {"num_byzantine": 2},
        "geomed_groups": {"num_groups": 4},
        "centered_clip": {"clip_radius": 1.0}}


def _spec(wire, pad_to=1):
    # Two-leaf tree so the per-block scales have real boundaries.
    tree = {"a": jnp.zeros((W, 20), jnp.float32),
            "b": jnp.zeros((W, 13), jnp.float32)}
    return packing.pack_spec(tree, batch_ndim=1, wire=wire, pad_to=pad_to)


def _buf(key, spec, scale=1.0):
    return scale * jax.random.normal(key, (W, spec.padded_dim), jnp.float32)


# ---------------------------------------------------------------------------
# int8: per-block symmetric scales.
# ---------------------------------------------------------------------------

@hypothesis.given(
    raw=hnp.arrays(np.float32, (W, 33),
                   elements=st.floats(min_value=-50.0, max_value=50.0,
                                      width=32)),
    gain=st.floats(min_value=1e-3, max_value=1e3))
@hypothesis.settings(deadline=None, max_examples=25)
def test_int8_roundtrip_error_bounded_by_block_scale(raw, gain):
    spec = _spec("int8")
    buf = jnp.asarray(raw * np.float32(gain))
    rt = np.asarray(spec.wire_roundtrip(buf))
    assert np.all(np.isfinite(rt))
    for a, b in spec.boundaries:
        v = np.asarray(buf)[:, a:b]
        amax = np.abs(v).max(axis=1, keepdims=True)
        err = np.abs(rt[:, a:b] - v)
        # Symmetric amax/127 scaling: worst case half a quantization bin,
        # plus a couple of ulps of slack for the f32 divide/round/multiply.
        bound = amax / 254.0 + 1e-6 * amax + 1e-30
        assert np.all(err <= bound), (err.max(), bound.max())


def test_int8_all_zero_block_is_exact():
    spec = _spec("int8")
    buf = jnp.zeros((W, spec.padded_dim), jnp.float32)
    codes, scales = spec.encode(buf)
    np.testing.assert_array_equal(np.asarray(codes), 0)
    np.testing.assert_array_equal(np.asarray(scales), 0.0)
    np.testing.assert_array_equal(np.asarray(spec.decode(codes, scales)), 0.0)


def test_int8_roundtrip_is_exactly_idempotent():
    # Receivers see decode(encode(v)); re-quantizing that wire value (what
    # the master paths do to attacked rows) must be a fixed point, so
    # honest rows pass the post-attack round-trip untouched.
    spec = _spec("int8")
    wire = spec.wire_roundtrip(_buf(jax.random.PRNGKey(0), spec))
    np.testing.assert_array_equal(np.asarray(spec.wire_roundtrip(wire)),
                                  np.asarray(wire))


# ---------------------------------------------------------------------------
# sign1: 1-bit codes + mean-magnitude scales.
# ---------------------------------------------------------------------------

@hypothesis.given(
    raw=hnp.arrays(np.float32, (W, 33),
                   elements=st.floats(min_value=-8.0, max_value=8.0,
                                      width=32)))
@hypothesis.settings(deadline=None, max_examples=25)
def test_sign1_codes_are_exactly_pm1(raw):
    spec = _spec("sign1", pad_to=64)   # force real padding coordinates
    buf = spec.pack({"a": jnp.asarray(raw[:, :20]),
                     "b": jnp.asarray(raw[:, 20:])})
    codes, scales = spec.encode(buf)
    assert codes.dtype == jnp.int8
    c = np.asarray(codes)
    assert np.all(np.isin(c[:, :spec.dim], (-1, 1))), "codes must be +-1"
    np.testing.assert_array_equal(c[:, spec.dim:], 0)  # padding encodes 0
    for i, (a, b) in enumerate(spec.boundaries):
        want = np.abs(raw[:, a:b]).mean(axis=1)
        np.testing.assert_allclose(np.asarray(scales)[:, i], want,
                                   rtol=1e-5, atol=1e-7)


def test_sign1_codes_idempotent_values_allclose():
    # The sign1 scale is a mean of |code * scale| = scale, recomputed as a
    # fresh f32 sum -- identical values in a different summation order --
    # so the VALUE round-trip is allclose (not bitwise) while the CODES
    # are exactly reproduced (sign(code * scale) == code for scale > 0).
    spec = _spec("sign1")
    buf = _buf(jax.random.PRNGKey(3), spec)
    codes, scales = spec.encode(buf)
    wire = spec.decode(codes, scales)
    codes2, scales2 = spec.encode(wire)
    np.testing.assert_array_equal(np.asarray(codes2), np.asarray(codes))
    np.testing.assert_allclose(np.asarray(spec.decode(codes2, scales2)),
                               np.asarray(wire), rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Determinism / batch-rank agnosticism.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", ["int8", "sign1"])
def test_encode_deterministic_and_batch_rank_agnostic(wire):
    spec = _spec(wire, pad_to=16)
    buf = _buf(jax.random.PRNGKey(1), spec, scale=3.0)
    codes, scales = spec.encode(buf)
    codes_again, scales_again = spec.encode(buf)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_again))
    np.testing.assert_array_equal(np.asarray(scales),
                                  np.asarray(scales_again))
    # Per-row (rank-1 batch-free) encode == the matching row of the batch
    # encode: block statistics are strictly per batch element.
    for i in range(W):
        ci, si = spec.encode(buf[i])
        np.testing.assert_array_equal(np.asarray(ci), np.asarray(codes)[i])
        np.testing.assert_array_equal(np.asarray(si), np.asarray(scales)[i])
    # And a higher-rank batch (masked-topology exchange shape) agrees too.
    ex = jnp.broadcast_to(buf[None], (2,) + buf.shape) + 0
    ce, se = spec.encode(ex)
    np.testing.assert_array_equal(np.asarray(ce[0]), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(se[1]), np.asarray(scales))


def test_dequantize_slice_matches_decode():
    # The sharded paths decode arbitrary coordinate slices per seg id; on
    # the full buffer that must agree with the blockwise decode exactly.
    for wire in ("int8", "sign1"):
        spec = _spec(wire, pad_to=64)
        codes, scales = spec.encode(_buf(jax.random.PRNGKey(5), spec))
        np.testing.assert_array_equal(
            np.asarray(packing.dequantize_slice(codes, scales,
                                                spec.seg_ids())),
            np.asarray(spec.decode(codes, scales)))


# ---------------------------------------------------------------------------
# float32 bypass: byte identical, zero copies, per registry aggregator.
# ---------------------------------------------------------------------------

def test_float32_roundtrip_is_the_same_object():
    spec = _spec("float32")
    buf = _buf(jax.random.PRNGKey(2), spec)
    assert spec.wire_roundtrip(buf) is buf
    wire, resid = spec.transmit(buf, None)
    assert wire is buf and resid is None


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_float32_bypass_bitexact_per_aggregator(name):
    # wire="float32" through the new registry must aggregate bitwise the
    # same as the legacy raw-dtype spec -- the pre-registry behaviour.
    legacy = packing.pack_spec({"a": jnp.zeros((W, 20), jnp.float32),
                                "b": jnp.zeros((W, 13), jnp.float32)},
                               batch_ndim=1, message_dtype=jnp.float32)
    spec = _spec("float32")
    buf = _buf(jax.random.PRNGKey(4), spec)
    opts = OPTS.get(name, {})
    out_legacy = agg_lib.get_flat_aggregator(name, legacy, **opts)(buf)
    out_wire = agg_lib.get_flat_aggregator(name, spec, **opts)(
        spec.wire_roundtrip(buf))
    np.testing.assert_array_equal(np.asarray(out_legacy),
                                  np.asarray(out_wire))


# ---------------------------------------------------------------------------
# Error feedback.
# ---------------------------------------------------------------------------

def test_transmit_conserves_signal_and_requires_residual():
    spec = _spec("sign1")
    buf = _buf(jax.random.PRNGKey(6), spec)
    resid0 = jnp.zeros_like(buf)
    wire, resid1 = spec.transmit(buf, resid0)
    # wire + residual reconstructs the (EF-folded) signal.
    np.testing.assert_allclose(np.asarray(wire + resid1), np.asarray(buf),
                               rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="error feedback"):
        spec.transmit(buf, None)
    # int8 carries no EF: the residual passes through untouched.
    i8 = _spec("int8")
    wire8, resid8 = i8.transmit(buf, resid0)
    assert resid8 is resid0
    np.testing.assert_array_equal(np.asarray(wire8),
                                  np.asarray(i8.wire_roundtrip(buf)))


@hypothesis.given(gain=st.floats(min_value=0.1, max_value=10.0),
                  seed=st.integers(min_value=0, max_value=1000))
@hypothesis.settings(deadline=None, max_examples=10)
def test_sign1_ef_residual_bounded_direct_loop(gain, seed):
    # The mean-|v| sign quantizer is a contraction (delta-compressor with
    # delta = ||v||_1^2 / (D ||v||_2^2)), so the EF residual stays bounded
    # for a bounded gradient stream instead of accumulating.
    spec = _spec("sign1")
    key = jax.random.PRNGKey(seed)
    resid = jnp.zeros((W, spec.padded_dim), jnp.float32)
    norms = []
    for t in range(60):
        g = _buf(jax.random.fold_in(key, t), spec, scale=gain)
        _, resid = spec.transmit(g, resid)
        norms.append(float(jnp.max(jnp.linalg.norm(resid, axis=-1))))
    norms = np.asarray(norms)
    assert np.all(np.isfinite(norms))
    ref = float(gain) * np.sqrt(spec.padded_dim)   # ~ one gradient's norm
    assert norms.max() < 5.0 * ref, (norms.max(), ref)
    # No late-trajectory growth: the second half stays in the first
    # half's envelope.
    assert norms[30:].max() <= 1.5 * norms[:30].max() + 0.1 * ref


def test_sign1_ef_state_bounded_over_federated_trajectory():
    # End-to-end: the residual rows carried in FederatedState.ef under a
    # real sign_flip run stay bounded while training makes progress.
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=240)
    loss = logreg_loss(0.01)
    wd = partition({"a": data.x, "b": data.y}, W - 2, seed=1)
    cfg = RobustConfig(aggregator="geomed", vr="sgd", attack="sign_flip",
                       num_byzantine=2, message_dtype="sign1")
    init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                           get_optimizer("sgd", 0.05))
    st_ = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(7))
    assert st_.ef is not None and st_.ef.shape[0] == W - 2
    jstep = jax.jit(step_fn)
    norms = []
    for _ in range(150):
        st_, _ = jstep(st_)
        norms.append(float(jnp.max(jnp.linalg.norm(st_.ef, axis=-1))))
    norms = np.asarray(norms)
    assert np.all(np.isfinite(norms))
    assert norms[75:].max() <= 2.0 * norms[:75].max() + 1e-3, \
        f"EF residual grew late in the trajectory: {norms.max()}"


def test_non_ef_formats_carry_no_ef_state():
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=120)
    wd = partition({"a": data.x, "b": data.y}, 4, seed=1)
    for dtype, wants_ef in (("float32", False), ("bfloat16", False),
                            ("int8", False), ("sign1", True)):
        cfg = RobustConfig(aggregator="mean", message_dtype=dtype)
        init_fn, _ = make_federated_step(logreg_loss(0.01), wd, cfg,
                                         get_optimizer("sgd", 0.05))
        st_ = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                      jax.random.PRNGKey(1))
        assert (st_.ef is not None) == wants_ef, dtype


# ---------------------------------------------------------------------------
# Registry: single source of truth.
# ---------------------------------------------------------------------------

def test_unknown_wire_format_errors_name_the_registry():
    for bad_call in (lambda: packing.resolve_wire_format("int4"),
                     lambda: packing.resolve_message_dtype("int4"),
                     lambda: RobustConfig(message_dtype="int4").wire_format()):
        with pytest.raises(ValueError) as ei:
            bad_call()
        for name in packing.WIRE_FORMAT_NAMES:
            assert name in str(ei.value)
        assert "int4" in str(ei.value)


def test_registry_is_consistent():
    assert packing.WIRE_FORMAT_NAMES == tuple(packing.WIRE_FORMATS)
    for name, fmt in packing.WIRE_FORMATS.items():
        assert fmt.name == name
        assert packing.resolve_wire_format(name) is fmt
    # Raw-dtype spellings keep resolving (legacy callers).
    assert packing.resolve_wire_format(jnp.bfloat16).name == "bfloat16"
    assert packing.resolve_message_dtype("sign1") == jnp.dtype(jnp.float32)
    with pytest.raises(ValueError, match="not both"):
        packing.pack_spec({"a": jnp.zeros((2, 3))}, wire="int8",
                          message_dtype=jnp.float32)


def test_quantized_requires_packed_path():
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=120)
    wd = partition({"a": data.x, "b": data.y}, 4, seed=1)
    cfg = RobustConfig(aggregator="mean", message_dtype="int8", packed=False)
    with pytest.raises(ValueError, match="packed"):
        make_federated_step(logreg_loss(0.01), wd, cfg,
                            get_optimizer("sgd", 0.05))


def test_wire_bytes_accounting():
    sizes = {w: _spec(w).wire_bytes() for w in packing.WIRE_FORMAT_NAMES}
    d, leaves = 33, 2
    assert sizes["float32"] == 4 * d
    assert sizes["bfloat16"] == 2 * d
    assert sizes["int8"] == d + 4 * leaves
    assert sizes["sign1"] == (d + 7) // 8 + 4 * leaves
    assert sizes["sign1"] * 8 < sizes["float32"], "sign1 must be < 1/8 f32"
