"""End-to-end behaviour tests: the full Byrd-SAGA federation simulation
(paper Alg. 1) against the paper's threat model, fast CPU scale."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_full_loss_and_opt, logreg_loss, partition
from repro.optim import get_optimizer


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=600)
    loss = logreg_loss(0.01)
    _, f_star = logreg_full_loss_and_opt(data, iters=3000, lr=0.5)
    wd = partition({"a": data.x, "b": data.y}, 10, seed=1)
    return loss, {"a": data.x, "b": data.y}, f_star, wd


def _train(loss, wd, cfg, steps=400, lr=0.02):
    opt = get_optimizer("sgd", lr)
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(3))
    jstep = jax.jit(step_fn)
    metrics = None
    for _ in range(steps):
        st, metrics = jstep(st)
    return st, metrics


def test_byrd_saga_end_to_end(setup):
    loss, batch, f_star, wd = setup
    cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                       num_byzantine=4)
    st, metrics = _train(loss, wd, cfg)
    gap = float(loss(st.params, batch)) - f_star
    assert gap < 0.1, gap
    assert int(st.step) == 400
    assert bool(jnp.isfinite(metrics["honest_variance"]))


def test_variance_reduction_observable(setup):
    """The paper's bottom-row plots: honest-message variance under SAGA is
    far below SGD's after convergence."""
    loss, batch, f_star, wd = setup
    _, m_saga = _train(loss, wd, RobustConfig(aggregator="geomed", vr="saga",
                                              attack="none", num_byzantine=0))
    _, m_sgd = _train(loss, wd, RobustConfig(aggregator="geomed", vr="sgd",
                                             attack="none", num_byzantine=0))
    assert float(m_saga["honest_variance"]) < 0.2 * float(m_sgd["honest_variance"])


def test_minibatch_between_sgd_and_saga(setup):
    loss, batch, f_star, wd = setup
    _, m_b = _train(loss, wd, RobustConfig(aggregator="geomed", vr="minibatch",
                                           minibatch_size=20, attack="none",
                                           num_byzantine=0))
    _, m_sgd = _train(loss, wd, RobustConfig(aggregator="geomed", vr="sgd",
                                             attack="none", num_byzantine=0))
    assert float(m_b["honest_variance"]) < float(m_sgd["honest_variance"])


def test_state_is_checkpointable(setup, tmp_path):
    import os

    import numpy as np

    from repro.checkpoint import load, save
    loss, _, _, wd = setup
    cfg = RobustConfig(aggregator="geomed", vr="saga", attack="none", num_byzantine=0)
    st, _ = _train(loss, wd, cfg, steps=5)
    p = os.path.join(tmp_path, "st.npz")
    save(p, st._asdict())
    got = load(p, st._asdict())
    np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                               np.asarray(st.params["w"]))


def test_simulation_resume_is_bit_exact(setup, tmp_path):
    """Full train state round-trip (params + SAGA table/avg + opt state +
    step + PRNG key): 5 straight steps == 3 steps, checkpoint, restore, 2
    more -- bit-exact on every leaf, because the state carries everything
    the trajectory depends on."""
    import numpy as np

    from repro.checkpoint import CheckpointManager
    loss, _, _, wd = setup
    cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                       num_byzantine=3)
    opt = get_optimizer("momentum", 0.02)  # exercises non-trivial opt state
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    jstep = jax.jit(step_fn)

    def run(st, steps):
        for _ in range(steps):
            st, _ = jstep(st)
        return st

    st0 = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(3))
    straight = run(st0, 5)
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save_train_state(3, run(st0, 3)._asdict())
    step0, restored = ckpt.restore_latest(st0._asdict())
    assert step0 == 3
    resumed = run(type(st0)(**restored), 2)
    assert int(resumed.step) == 5
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(
        straight._asdict())[0]]
    for path, a, b in zip(paths,
                          jax.tree_util.tree_leaves(straight._asdict()),
                          jax.tree_util.tree_leaves(resumed._asdict())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(path))
