"""Client-scale virtualization properties (DESIGN.md Sec. 10).

Hypothesis-driven (with the seeded ``tests/_hypothesis_fallback.py`` shim
when the real package is absent) pins on the participation layer in
isolation -- the cross-path step-level pins live in
``tests/test_distributed.py`` and the convergence story in
``tests/test_convergence.py``:

* Cohort sampling is a pure function of (seed, round): rebuilt plans agree
  element-wise, different seeds give different epoch shuffles, and
  ``cohort_at`` under jit matches the precomputed stack.
* Every cohort has exactly W DISTINCT members (the per-client state
  scatter must be alias-free).
* Deterministic coverage: every client participates at least once per
  shuffled epoch -- within ceil(C/W) rounds, not a coupon-collector tail.
* Staleness counters never go negative, reset to 0 exactly for the
  cohort, and grow by 1 per missed round; the weight map sends
  counters at/beyond ``max_staleness`` to exactly 0.
* ``slot_staleness`` places the attack sentinel on the right rows under
  both buffer conventions (sim append vs distributed first-B replace).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # keep the suite collectable without the dev extra
    from _hypothesis_fallback import hypothesis, st

from repro.core import participation as part
from repro.core.robust_step import RobustConfig


# ---------------------------------------------------------------------------
# Cohort sampling.
# ---------------------------------------------------------------------------

@hypothesis.given(c=st.integers(min_value=1, max_value=97),
                  w=st.integers(min_value=1, max_value=97),
                  seed=st.integers(min_value=0, max_value=10_000))
@hypothesis.settings(deadline=None, max_examples=25)
def test_cohorts_are_deterministic_and_duplicate_free(c, w, seed):
    hypothesis.assume(w <= c)
    plan = part.ParticipationPlan(num_clients=c, cohort_size=w, seed=seed)
    again = part.ParticipationPlan(num_clients=c, cohort_size=w, seed=seed)
    stack = plan.stacked_cohorts
    assert stack.shape == (plan.num_rounds, w)
    assert stack.dtype == np.int32
    np.testing.assert_array_equal(stack, again.stacked_cohorts)
    # Exactly W distinct in-range members per round: the scatter back into
    # the (C, ...) resident tables never aliases.
    for row in stack:
        assert len(set(row.tolist())) == w
        assert row.min() >= 0 and row.max() < c


@hypothesis.given(c=st.integers(min_value=2, max_value=64),
                  seed=st.integers(min_value=0, max_value=1_000))
@hypothesis.settings(deadline=None, max_examples=15)
def test_every_client_covered_each_epoch(c, seed):
    w = max(1, c // 3)
    plan = part.ParticipationPlan(num_clients=c, cohort_size=w, seed=seed)
    r = plan.rounds_per_epoch
    stack = plan.stacked_cohorts
    for e in range(plan.epochs):
        epoch_rows = stack[e * r:(e + 1) * r]
        assert set(epoch_rows.ravel().tolist()) == set(range(c)), \
            f"epoch {e} missed clients within its ceil(C/W)={r} rounds"


def test_seed_changes_the_shuffle():
    mk = lambda s: part.ParticipationPlan(24, 6, seed=s).stacked_cohorts
    assert not np.array_equal(mk(0), mk(1))


def test_cohort_at_matches_stack_and_wraps_under_jit():
    plan = part.ParticipationPlan(num_clients=10, cohort_size=3, seed=7)
    at = jax.jit(plan.cohort_at)
    for t in range(2 * plan.num_rounds + 1):
        np.testing.assert_array_equal(
            np.asarray(at(t)), plan.stacked_cohorts[t % plan.num_rounds])


def test_resolve_participation_bypass_and_validation():
    cfg = RobustConfig(aggregator="mean", num_clients=0)
    assert part.resolve_participation(cfg, 8) is None
    cfg = RobustConfig(aggregator="mean", num_clients=8)
    assert part.resolve_participation(cfg, 8) is None   # full participation
    cfg = RobustConfig(aggregator="mean", num_clients=32,
                       participation_seed=3)
    plan = part.resolve_participation(cfg, 8)
    assert plan.num_clients == 32 and plan.cohort_size == 8
    assert plan.seed == 3
    with pytest.raises(ValueError, match="smaller than"):
        part.resolve_participation(
            RobustConfig(aggregator="mean", num_clients=4), 8)
    with pytest.raises(ValueError, match="does not match"):
        part.resolve_participation(
            RobustConfig(aggregator="mean", num_clients=32, cohort_size=6), 8)


def test_gather_scatter_round_trip():
    plan = part.ParticipationPlan(num_clients=12, cohort_size=4, seed=1)
    tree = {"t": jnp.arange(24.0).reshape(12, 2),
            "s": jnp.arange(12, dtype=jnp.int32)}
    cohort = plan.cohort_at(5)
    rows = part.gather_rows(tree, cohort)
    assert rows["t"].shape == (4, 2) and rows["s"].shape == (4,)
    # Writing the gathered rows straight back is the identity (alias-free
    # cohorts), and writing modified rows changes exactly the cohort.
    same = part.scatter_rows(tree, cohort, rows)
    jax.tree_util.tree_map(np.testing.assert_array_equal, tree, same)
    bumped = part.scatter_rows(
        tree, cohort, jax.tree_util.tree_map(lambda r: r + 100, rows))
    mask = np.zeros(12, bool)
    mask[np.asarray(cohort)] = True
    np.testing.assert_array_equal(np.asarray(bumped["s"])[~mask],
                                  np.asarray(tree["s"])[~mask])
    np.testing.assert_array_equal(np.asarray(bumped["s"])[mask],
                                  np.asarray(tree["s"])[mask] + 100)


# ---------------------------------------------------------------------------
# Staleness counters and weights.
# ---------------------------------------------------------------------------

@hypothesis.given(seed=st.integers(min_value=0, max_value=500),
                  rounds=st.integers(min_value=1, max_value=40))
@hypothesis.settings(deadline=None, max_examples=15)
def test_staleness_counters_never_negative_and_reset_on_participation(
        seed, rounds):
    c, w = 13, 4
    plan = part.ParticipationPlan(num_clients=c, cohort_size=w, seed=seed)
    s = part.init_staleness(c)
    tick = jax.jit(part.tick_staleness)
    last_seen = -np.ones(c, int)
    for t in range(rounds):
        cohort = np.asarray(plan.cohort_at(t))
        s = tick(s, cohort)
        last_seen[cohort] = t
        arr = np.asarray(s)
        assert (arr >= 0).all()
        assert (arr[cohort] == 0).all(), "participants must reset to 0"
        # Everyone else's counter is exactly rounds-since-last-seen
        # (t+1 for the never-seen).
        expect = np.where(last_seen >= 0, t - last_seen, t + 1)
        np.testing.assert_array_equal(arr, expect)


def test_staleness_weights_decay_and_cutoff():
    s = jnp.array([0, 1, 2, 7, 8, 100], jnp.int32)
    w = part.staleness_weights(s, decay=0.5, max_staleness=8)
    np.testing.assert_allclose(np.asarray(w),
                               [1.0, 0.5, 0.25, 0.5 ** 7, 0.0, 0.0])
    # decay=1.0 is pure dropout masking: 0/1 weights only.
    w1 = part.staleness_weights(s, decay=1.0, max_staleness=8)
    np.testing.assert_array_equal(np.asarray(w1), [1, 1, 1, 1, 0, 0])


def test_slot_staleness_conventions():
    honest = jnp.array([3, 0, 5, 1], jnp.int32)
    # Sim convention: B byzantine rows APPENDED after the honest cohort.
    out = part.slot_staleness(honest, "straggler", 2, straggler_k=6,
                              max_staleness=64)
    np.testing.assert_array_equal(np.asarray(out), [3, 0, 5, 1, 6, 6])
    # Distributed convention: first B rows of the full-width buffer were
    # mask-replaced by the attack.
    out = part.slot_staleness(honest, "dropout", 2, straggler_k=6,
                              max_staleness=64, byz_first=True)
    np.testing.assert_array_equal(np.asarray(out), [64, 64, 5, 1])
    # Non-staleness attacks report fresh rows; attack "none" is the
    # identity either way.
    out = part.slot_staleness(honest, "sign_flip", 2, straggler_k=6,
                              max_staleness=64, byz_first=True)
    np.testing.assert_array_equal(np.asarray(out), [0, 0, 5, 1])
    out = part.slot_staleness(honest, "none", 2, straggler_k=6,
                              max_staleness=64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(honest))


def test_uses_staleness_switch():
    mk = lambda **kw: RobustConfig(aggregator="mean", **kw)
    assert not part.uses_staleness(mk(), None)
    assert part.uses_staleness(mk(attack="straggler"), None)
    assert part.uses_staleness(mk(attack="dropout"), None)
    plan = part.ParticipationPlan(16, 4)
    assert part.uses_staleness(mk(), plan)
