"""repro.topology: graphs, masked aggregation, decentralized training.

Covers the DESIGN.md Sec. 6 contracts: mixing matrices are doubly
stochastic, masked rules restrict EXACTLY to each node's neighborhood
(against a naive slice-based reference -- slicing is fine in a test
oracle), full masks reduce to the registry aggregators, per-edge attacks
hit each receiver's own neighborhood statistics, and ``topology="star"``
through the new entry point is bit-exact with the master path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RobustConfig, make_federated_step
from repro.core import aggregators as agg_lib
from repro.core.attacks import ATTACK_NAMES, FAULT_ATTACKS, AttackConfig
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer
from repro.topology import (
    MASKED_AGGREGATOR_NAMES,
    TOPOLOGY_NAMES,
    build_exchange,
    cyclic_schedule,
    get_schedule,
    get_topology,
    make_decentralized_step,
    masked_aggregate,
    static_schedule,
)
from repro.topology import graphs

KEY = jax.random.PRNGKey(0)

AGG_OPTS = dict(max_iters=150, tol=1e-9, num_groups=3, trim=1,
                num_byzantine=1, clip_radius=2.0)


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TOPOLOGY_NAMES)
def test_mixing_is_doubly_stochastic_and_symmetric(name):
    t = get_topology(name, 8, seed=2, p=0.5)
    m = t.mixing
    np.testing.assert_allclose(m.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(m.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(m, m.T, atol=1e-12)
    assert (m >= 0).all()
    assert t.is_connected()
    # Self-loops live in the neighbor mask, not the adjacency.
    assert not t.adjacency.diagonal().any()
    assert (t.neighbor_mask.diagonal() == 1).all()


def test_spectral_gap_ordering():
    gaps = {n: get_topology(n, 16).spectral_gap()
            for n in ("ring", "torus2d", "complete")}
    assert gaps["complete"] > gaps["torus2d"] > gaps["ring"] > 0


def test_erdos_renyi_deterministic_and_seed_sensitive():
    a = get_topology("erdos_renyi", 12, seed=5, p=0.4)
    b = get_topology("erdos_renyi", 12, seed=5, p=0.4)
    c = get_topology("erdos_renyi", 12, seed=6, p=0.4)
    np.testing.assert_array_equal(a.adjacency, b.adjacency)
    assert (a.adjacency != c.adjacency).any()
    with pytest.raises(ValueError, match="connected"):
        graphs.erdos_renyi(24, p=0.001, seed=0, max_tries=4)


def test_topology_shapes_and_errors():
    s = get_topology("star", 6)
    assert s.degrees[0] == 5 and (s.degrees[1:] == 1).all()
    r = get_topology("ring", 6)
    assert (r.degrees == 2).all() and r.min_neighborhood == 3
    t = graphs.torus2d(8)
    assert t.describe()["degree_max"] <= 4
    with pytest.raises(ValueError, match="ring"):
        graphs.torus2d(7)  # prime: no 2-D grid
    with pytest.raises(ValueError, match="known"):
        get_topology("mesh3d", 8)
    with pytest.raises(ValueError, match="symmetric"):
        graphs.Topology("bad", 3, np.triu(np.ones((3, 3), bool), 1))


# ---------------------------------------------------------------------------
# Masked aggregation
# ---------------------------------------------------------------------------

def test_masked_registry_mirrors_aggregator_registry():
    assert set(MASKED_AGGREGATOR_NAMES) == set(agg_lib.AGGREGATOR_NAMES)
    with pytest.raises(ValueError, match="known"):
        masked_aggregate("wat", {"g": jnp.zeros((1, 2, 3))}, jnp.ones((1, 2)))


def _payload(s=6):
    return {"a": jax.random.normal(KEY, (s, 16)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (s, 3, 4))}


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_full_mask_reduces_to_registry_aggregator(name):
    z = _payload()
    exchange = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (1,) + v.shape), z)
    ref = agg_lib.get_aggregator(name, **AGG_OPTS)(z)
    got = masked_aggregate(name, exchange, jnp.ones((1, 6)), **AGG_OPTS)
    for k in z:
        np.testing.assert_allclose(np.asarray(got[k][0]), np.asarray(ref[k]),
                                   atol=2e-5, err_msg=f"{name} {k}")


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_masked_restriction_matches_sliced_reference(name):
    """Per node, the masked rule equals the registry rule applied to the
    materialized neighborhood (the slice-based construction a test can
    afford; production code must never slice the sender axis)."""
    topo = graphs.ring(8)
    mask = jnp.asarray(topo.neighbor_mask)
    z = _payload(8)
    exchange = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (8,) + v.shape), z)
    got = masked_aggregate(name, exchange, mask, **AGG_OPTS)
    gids = (np.arange(8) * AGG_OPTS["num_groups"]) // 8
    for r in range(8):
        nbrs = np.nonzero(np.asarray(mask[r]))[0]
        sub = {k: v[nbrs] for k, v in z.items()}
        if name == "geomed_groups":
            # Masked group means keep the GLOBAL slot partition.
            grouped = {}
            for k, v in z.items():
                rows = [np.mean(np.asarray(v)[[i for i in nbrs
                                               if gids[i] == g]], axis=0)
                        for g in range(AGG_OPTS["num_groups"])
                        if any(gids[i] == g for i in nbrs)]
                grouped[k] = jnp.asarray(np.stack(rows))
            ref = agg_lib.geomed_agg(grouped, max_iters=150, tol=1e-9)
        else:
            ref = agg_lib.get_aggregator(name, **AGG_OPTS)(sub)
        for k in z:
            np.testing.assert_allclose(
                np.asarray(got[k][r]), np.asarray(ref[k]), atol=5e-5,
                err_msg=f"{name} node {r} {k}")


def test_masked_mean_with_mixing_is_one_gossip_step():
    topo = graphs.ring(6)
    mask = jnp.asarray(topo.neighbor_mask)
    mix = jnp.asarray(topo.mixing, jnp.float32)
    z = jax.random.normal(KEY, (6, 5))
    exchange = {"g": jnp.broadcast_to(z[None], (6, 6, 5))}
    got = masked_aggregate("mean", exchange, mask, mixing=mix * mask)["g"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(mix) @ np.asarray(z),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Per-edge attacks
# ---------------------------------------------------------------------------

def test_zero_gradient_zeroes_every_neighborhood_mean():
    topo = graphs.erdos_renyi(10, p=0.6, seed=3)
    mask = jnp.asarray(topo.neighbor_mask)
    is_byz = jnp.arange(10) >= 7  # last 3 nodes Byzantine
    msgs = {"g": jax.random.normal(KEY, (10, 7))}
    cfg = AttackConfig(name="zero_gradient", num_byzantine=3)
    ex = build_exchange(msgs, cfg, mask, is_byz)["g"]  # (10, 10, 7)
    nbr_mean = (jnp.einsum("rs,rsp->rp", mask, ex)
                / jnp.sum(mask, axis=1)[:, None])
    # Only receivers that actually see a Byzantine sender are zeroed.
    sees_byz = np.asarray(jnp.sum(mask * is_byz[None, :], axis=1)) > 0
    np.testing.assert_allclose(np.asarray(nbr_mean)[sees_byz], 0.0, atol=1e-5)


@pytest.mark.parametrize("attack", [n for n in ATTACK_NAMES if n != "none"])
def test_per_edge_attacks_touch_only_byzantine_senders(attack):
    topo = graphs.complete(8)
    mask = jnp.asarray(topo.neighbor_mask)
    is_byz = jnp.arange(8) >= 6
    msgs = {"g": jax.random.normal(KEY, (8, 5)),
            "h": jax.random.normal(jax.random.PRNGKey(2), (8, 2, 2))}
    # bitflip's default per-coordinate probability is sparse by design;
    # raise it so the few byz coordinates here are guaranteed to flip.
    cfg = AttackConfig(name=attack, num_byzantine=2, bitflip_prob=0.9)
    ex = build_exchange(msgs, cfg, mask, is_byz, jax.random.PRNGKey(7))
    for k, z in msgs.items():
        e = np.asarray(ex[k])
        if attack not in FAULT_ATTACKS:
            assert np.isfinite(e).all(), (attack, k)
        # Honest sender columns are the broadcast original message.
        np.testing.assert_array_equal(
            e[:, :6], np.broadcast_to(np.asarray(z)[None, :6], e[:, :6].shape))
        # Byzantine columns differ from what the sender honestly computed.
        assert (e[:, 6:] != np.asarray(z)[None, 6:]).any(), (attack, k)


def test_sign_flip_is_per_edge_on_a_ring():
    """Different receivers border different honest sets on a ring, so the
    same Byzantine sender must inject DIFFERENT vectors per edge."""
    topo = graphs.ring(8)
    mask = jnp.asarray(topo.neighbor_mask)
    is_byz = jnp.arange(8) >= 7  # node 7, neighbors 6 and 0
    msgs = {"g": jax.random.normal(KEY, (8, 6))}
    cfg = AttackConfig(name="sign_flip", num_byzantine=1)
    ex = np.asarray(build_exchange(msgs, cfg, mask, is_byz)["g"])
    # Receiver 6 sees honest {5, 6}; receiver 0 sees honest {0, 1}.
    z = np.asarray(msgs["g"])
    np.testing.assert_allclose(ex[6, 7], -3.0 * z[[5, 6]].mean(0), atol=1e-5)
    np.testing.assert_allclose(ex[0, 7], -3.0 * z[[0, 1]].mean(0), atol=1e-5)
    assert (ex[6, 7] != ex[0, 7]).any()


def test_build_exchange_rejects_unknown_attack():
    with pytest.raises(ValueError, match="known"):
        build_exchange({"g": jnp.zeros((2, 3))},
                       AttackConfig(name="wat", num_byzantine=1),
                       jnp.ones((2, 2)), jnp.arange(2) < 1)


# ---------------------------------------------------------------------------
# Decentralized training (simulation path)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def logreg():
    data = ijcnn1_like(jax.random.PRNGKey(0), n=600)
    wd = partition({"a": data.x, "b": data.y}, 8, seed=1)
    return logreg_loss(0.01), wd


def _train_decentralized(loss, wd, cfg, topo, steps):
    init_fn, step_fn = make_federated_step(
        loss, wd, cfg, get_optimizer("sgd", 0.05), topology=topo)
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(3))
    jstep = jax.jit(step_fn)
    for _ in range(steps):
        st, metrics = jstep(st)
    return st, metrics


def test_star_topology_is_bit_exact_with_master_path(logreg):
    """The acceptance regression: topology='star' through the new parameter
    must reproduce the existing make_federated_step outputs BIT-exactly on
    a seeded run (it routes onto the identical code path)."""
    loss, wd = logreg
    cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                       num_byzantine=3, weiszfeld_iters=32)
    opt = get_optimizer("sgd", 0.02)
    outs = {}
    for label, kwargs in (("default", {}), ("star", {"topology": "star"})):
        init_fn, step_fn = make_federated_step(loss, wd, cfg, opt, **kwargs)
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                     jax.random.PRNGKey(11))
        jstep = jax.jit(step_fn)
        for _ in range(25):
            st, _ = jstep(st)
        outs[label] = st
    np.testing.assert_array_equal(np.asarray(outs["default"].params["w"]),
                                  np.asarray(outs["star"].params["w"]))
    for a, b in zip(jax.tree_util.tree_leaves(outs["default"].vr),
                    jax.tree_util.tree_leaves(outs["star"].vr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # And RobustConfig.topology="star" (the default) is the same route.
    assert make_federated_step(loss, wd, cfg, opt)  # builds, no per-node axis


@pytest.mark.parametrize("gossip", ["gradient", "params"])
@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_every_aggregator_trains_decentralized_on_a_ring(logreg, name, gossip):
    loss, wd = logreg
    cfg = RobustConfig(aggregator=name, vr="sgd", attack="ipm",
                       num_byzantine=2, weiszfeld_iters=16, num_groups=3,
                       gossip=gossip)
    topo = get_topology("ring", 10)
    st, metrics = _train_decentralized(loss, wd, cfg, topo, steps=5)
    assert st.params["w"].shape == (10, 22)  # per-node copies
    assert np.isfinite(np.asarray(st.params["w"])).all()
    assert np.isfinite(float(metrics["consensus_dist"]))


def test_static_schedule_is_bit_exact_with_fixed_topology(logreg):
    """Cross-path regression: routing the SAME graph through a static
    GraphSchedule must reproduce the PR-3 fixed-topology path BIT-exactly
    (the static branch of mask_at emits the identical constants and no
    round indexing)."""
    loss, wd = logreg
    cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                       num_byzantine=3, weiszfeld_iters=32)
    topo = get_topology("ring", 11)
    opt = get_optimizer("sgd", 0.02)
    outs = {}
    for label, kwargs in (("topology", {"topology": topo}),
                          ("schedule", {"schedule": static_schedule(topo)})):
        init_fn, step_fn = make_federated_step(loss, wd, cfg, opt, **kwargs)
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                     jax.random.PRNGKey(11))
        jstep = jax.jit(step_fn)
        for _ in range(20):
            st, _ = jstep(st)
        outs[label] = st
    np.testing.assert_array_equal(np.asarray(outs["topology"].params["w"]),
                                  np.asarray(outs["schedule"].params["w"]))
    for a, b in zip(jax.tree_util.tree_leaves(outs["topology"].vr),
                    jax.tree_util.tree_leaves(outs["schedule"].vr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cyclic_schedule_round_zero_matches_fixed_graph(logreg):
    """One step of a cyclic [ring, complete] schedule is BIT-exact with one
    step on the fixed ring (round 0 selects the first graph), while a
    second step diverges from the pure-ring run (round 1 is the complete
    graph) -- pinning that the traced step counter actually drives the
    dynamic mask selection."""
    loss, wd = logreg
    cfg = RobustConfig(aggregator="median", vr="sgd", attack="sign_flip",
                       num_byzantine=2, weiszfeld_iters=16)
    ring, comp = get_topology("ring", 10), get_topology("complete", 10)
    opt = get_optimizer("sgd", 0.05)
    states = {}
    for label, kwargs in (("ring", {"topology": ring}),
                          ("cyc", {"schedule": cyclic_schedule([ring, comp])})):
        init_fn, step_fn = make_federated_step(loss, wd, cfg, opt, **kwargs)
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                     jax.random.PRNGKey(5))
        jstep = jax.jit(step_fn)
        st1, _ = jstep(st)
        st2, _ = jstep(st1)
        states[label] = (st1, st2)
    np.testing.assert_array_equal(np.asarray(states["ring"][0].params["w"]),
                                  np.asarray(states["cyc"][0].params["w"]))
    assert (np.asarray(states["ring"][1].params["w"])
            != np.asarray(states["cyc"][1].params["w"])).any()


@pytest.mark.parametrize("gossip", ["gradient", "params"])
def test_schedule_requires_window_connectivity(logreg, gossip):
    """A schedule whose union graph cannot connect is rejected at build
    time for BOTH gossip modes (single rounds may be disconnected, the
    window union may not)."""
    loss, wd = logreg
    cfg = RobustConfig(aggregator="geomed", vr="sgd", attack="none",
                       gossip=gossip)
    # p tiny: every draw is near-empty, the union of 2 rounds stays
    # disconnected for 10 nodes with overwhelming probability.
    sched = get_schedule("erdos_renyi", 8, p=0.01, seed=3, period=2)
    assert not sched.is_connected_over_window()
    with pytest.raises(ValueError, match="window"):
        make_federated_step(loss, wd, cfg, get_optimizer("sgd", 0.05),
                            schedule=sched)


def test_params_gossip_star_static_routes_to_master(logreg):
    """star + static is the master path regardless of gossip mode: the
    returned state has NO per-node axis (DESIGN.md Sec. 7)."""
    loss, wd = logreg
    cfg = RobustConfig(aggregator="geomed", vr="sgd", attack="none",
                       gossip="params")
    init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                           get_optimizer("sgd", 0.05))
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(0))
    assert st.params["w"].shape == (22,)  # master: one shared copy


def test_params_gossip_complete_mean_sgd_equals_master_step(logreg):
    """Cross-path anchor for the params channel: on the complete graph with
    the (Metropolis-uniform) mean rule, no attack, and the LINEAR sgd
    optimizer, aggregate-the-half-steps equals step-with-the-aggregate:
    mean_i(x - lr*g_i) = x - lr*mean_i(g_i).  One params-gossip step from a
    replicated init must therefore match the master step on every node."""
    loss, wd = logreg
    opt = get_optimizer("sgd", 0.05)
    outs = {}
    for label, cfg in (
            ("master", RobustConfig(aggregator="mean", vr="sgd",
                                    attack="none")),
            ("params", RobustConfig(aggregator="mean", vr="sgd",
                                    attack="none", gossip="params",
                                    topology="complete"))):
        init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                     jax.random.PRNGKey(21))
        st, _ = jax.jit(step_fn)(st)
        outs[label] = np.asarray(st.params["w"])
    master = outs["master"]                      # (22,)
    nodes = outs["params"]                       # (8, 22) per-node copies
    np.testing.assert_allclose(nodes, np.broadcast_to(master, nodes.shape),
                               atol=1e-6)


def test_ring_geomed_learns_under_attack_and_beats_mean(logreg):
    loss, wd = logreg
    losses = {}
    for agg in ("geomed", "mean"):
        cfg = RobustConfig(aggregator=agg, vr="saga", attack="sign_flip",
                           num_byzantine=2, weiszfeld_iters=32)
        st, _ = _train_decentralized(loss, wd, cfg, get_topology("ring", 10),
                                     steps=150)
        losses[agg] = float(np.mean([
            loss({"w": st.params["w"][i]},
                 {"a": wd["a"][i], "b": wd["b"][i]}) for i in range(8)]))
    assert losses["geomed"] < 0.60          # learns (from ln 2 ~ 0.693)
    assert losses["geomed"] < losses["mean"] - 0.02


def test_complete_graph_keeps_exact_consensus(logreg):
    loss, wd = logreg
    cfg = RobustConfig(aggregator="geomed", vr="sgd", attack="sign_flip",
                       num_byzantine=2, weiszfeld_iters=32)
    st, metrics = _train_decentralized(loss, wd, cfg,
                                       get_topology("complete", 10), steps=30)
    # Every node sees every message: copies can never drift.
    assert float(metrics["consensus_dist"]) < 1e-8
    w = np.asarray(st.params["w"][:8])
    np.testing.assert_allclose(w, np.broadcast_to(w[:1], w.shape), atol=1e-5)


def test_trimmed_mean_infeasible_neighborhood_raises(logreg):
    loss, wd = logreg
    cfg = RobustConfig(aggregator="trimmed_mean", trim=2, vr="sgd",
                       attack="ipm", num_byzantine=2)
    with pytest.raises(ValueError, match="trimmed_mean"):
        make_federated_step(loss, wd, cfg, get_optimizer("sgd", 0.05),
                            topology="ring")  # ring neighborhood = 3 <= 2*2


def test_topology_node_count_mismatch_raises(logreg):
    loss, wd = logreg
    cfg = RobustConfig(aggregator="geomed", vr="sgd", attack="none")
    with pytest.raises(ValueError, match="nodes"):
        make_federated_step(loss, wd, cfg, get_optimizer("sgd", 0.05),
                            topology=get_topology("ring", 5))


# ---------------------------------------------------------------------------
# Tier-2 convergence (slow; still runs in CI)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("attack", ["sign_flip", "gaussian"])
def test_params_gossip_error_floor_within_2x_of_gradient_mode(attack):
    """Tier-2 convergence claim for the parameter channel (DESIGN.md
    Sec. 7): robust PARAMETER gossip on a ring under attack reaches an
    error floor within 2x of gradient-mode Byrd-SAGA's on the synthetic
    logreg task.  (Empirically it lands BELOW gradient mode -- aggregating
    iterates also enforces consensus -- but only the 2x bound is the
    pinned contract.)"""
    from repro.data import logreg_full_loss_and_opt
    h, b, steps = 10, 2, 500
    data = ijcnn1_like(jax.random.PRNGKey(0), n=800)
    _, f_star = logreg_full_loss_and_opt(data, iters=4000, lr=0.5)
    wd = partition({"a": data.x, "b": data.y}, h, seed=1)
    loss = logreg_loss(0.01)
    gaps = {}
    for gossip in ("gradient", "params"):
        cfg = RobustConfig(aggregator="geomed", vr="saga", attack=attack,
                           num_byzantine=b, weiszfeld_iters=32,
                           gossip=gossip, topology="ring")
        init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                               get_optimizer("sgd", 0.02))
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                     jax.random.PRNGKey(7))
        jstep = jax.jit(step_fn)
        for _ in range(steps):
            st, _ = jstep(st)
        ml = float(np.mean([
            loss({"w": st.params["w"][i]},
                 {"a": wd["a"][i], "b": wd["b"][i]}) for i in range(h)]))
        gaps[gossip] = ml - f_star
    assert gaps["gradient"] < 0.15, gaps   # gradient mode learns at all
    assert gaps["params"] < 0.15, gaps     # params mode learns at all
    # The pinned ordering: the params-channel floor is within 2x of the
    # gradient channel's (small additive slack absorbs run-to-run noise).
    assert gaps["params"] <= 2.0 * gaps["gradient"] + 0.01, gaps
