"""Telemetry subsystem tests (DESIGN.md Sec. 11).

Three layers:

* engine pins -- ``diagnostics=True`` must not perturb the aggregate
  (bitwise, eager) for every registry aggregator, flat and masked,
  weighted and not;
* semantics -- under seeded sign_flip / gaussian corruption the known-
  Byzantine rows rank worst by implicit geomed weight and are never the
  krum selection;
* host sinks -- RunLogger JSONL/meta layout, PhaseTimer, and the shared
  metric helpers.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import RobustConfig, make_federated_step
from repro.core import aggregators as agg_lib
from repro.core import packing
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer
from repro.topology import masked as masked_lib

W, B = 8, 2          # rows per aggregation / Byzantine count
OPTS = {"trimmed_mean": {"trim": 1}, "krum": {"num_byzantine": B},
        "geomed_groups": {"num_groups": 4},
        "centered_clip": {"clip_radius": 1.0}}


def _spec():
    # Two-leaf tree so geomed_blockwise has real block boundaries.
    tree = {"a": jnp.zeros((W, 20), jnp.float32),
            "b": jnp.zeros((W, 13), jnp.float32)}
    return packing.pack_spec(tree, batch_ndim=1)


def _buf(spec, key):
    return jax.random.normal(key, (W, spec.padded_dim), jnp.float32)


def _attacked(spec, key, attack):
    """(W, D) buffer whose LAST B rows are corrupted."""
    base = 0.3 * jax.random.normal(key, (W, spec.padded_dim), jnp.float32)
    honest = base.at[:, 0].add(2.0)          # coherent honest direction
    hmean = jnp.mean(honest[:W - B], axis=0)
    if attack == "sign_flip":
        poison = -4.0 * hmean
    else:                                    # gaussian
        poison = hmean + 8.0 * jax.random.normal(
            jax.random.fold_in(key, 1), (B, spec.padded_dim), jnp.float32)
    return honest.at[W - B:].set(poison)


# ---------------- engine pins: diagnostics=True never moves the aggregate


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_flat_engine_diag_off_is_bitexact(name):
    spec = _spec()
    buf = _buf(spec, jax.random.PRNGKey(0))
    opts = OPTS.get(name, {})
    off = agg_lib.get_flat_aggregator(name, spec, **opts)(buf)
    assert isinstance(off, jnp.ndarray)      # bare array, no tuple
    on, diag = agg_lib.get_flat_aggregator(
        name, spec, diagnostics=True, **opts)(buf)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    assert isinstance(diag, telemetry.AggDiagnostics)
    assert diag.dist.shape == (W,) and diag.weight.shape == (W,)
    w = np.asarray(diag.weight)
    assert np.all(w >= 0) and abs(w.sum() - 1.0) < 1e-5


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_flat_engine_diag_weighted_bitexact(name):
    spec = _spec()
    buf = _buf(spec, jax.random.PRNGKey(1))
    rw = jnp.array([1.0, 0.0, 1.0, 1.0, 0.5, 1.0, 1.0, 1.0], jnp.float32)
    opts = OPTS.get(name, {})
    off = agg_lib.get_flat_aggregator(name, spec, **opts)(
        buf, row_weights=rw)
    on, diag = agg_lib.get_flat_aggregator(
        name, spec, diagnostics=True, **opts)(buf, row_weights=rw)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    # A zero-weight row contributes nothing, and the implicit weight says so.
    if name in ("mean", "geomed", "geomed_groups", "geomed_blockwise",
                "centered_clip"):
        assert float(diag.weight[1]) == 0.0


@pytest.mark.parametrize("name", masked_lib.MASKED_AGGREGATOR_NAMES)
def test_masked_engine_diag_off_is_bitexact(name):
    spec = _spec()
    key = jax.random.PRNGKey(2)
    buf = jax.random.normal(key, (W, W, spec.padded_dim), jnp.float32)
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (W, W)) < 0.7)
    mask = jnp.asarray(mask, jnp.float32)
    mask = jnp.maximum(mask, jnp.eye(W))     # self-loops keep rows live
    opts = dict(OPTS.get(name, {}), spec=spec)
    off = masked_lib.masked_aggregate_flat(name, buf, mask, **opts)
    on, diag = masked_lib.masked_aggregate_flat(
        name, buf, mask, diagnostics=True, **opts)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    assert diag.dist.shape == (W, W)
    # Non-neighbors carry exactly zero weight and distance.
    dead = np.asarray(mask) == 0
    assert np.all(np.asarray(diag.weight)[dead] == 0)
    assert np.all(np.asarray(diag.dist)[dead] == 0)
    red = telemetry.reduce_masked_diagnostics(diag, mask)
    assert red.dist.shape == (W,) and red.weight.shape == (W,)
    assert abs(float(jnp.sum(red.weight)) - 1.0) < 1e-5


# ---------------- semantics under seeded corruption


@pytest.mark.parametrize("attack", ["sign_flip", "gaussian"])
def test_geomed_implicit_weight_ranks_byzantine_last(attack):
    spec = _spec()
    for seed in range(3):
        buf = _attacked(spec, jax.random.PRNGKey(10 + seed), attack)
        _, diag = agg_lib.get_flat_aggregator(
            "geomed", spec, diagnostics=True, max_iters=64)(buf)
        w = np.asarray(diag.weight)
        assert w[W - B:].max() < w[:W - B].min(), (seed, w)
        assert bool(diag.converged)


@pytest.mark.parametrize("attack", ["sign_flip", "gaussian"])
def test_krum_never_selects_byzantine(attack):
    spec = _spec()
    for seed in range(3):
        buf = _attacked(spec, jax.random.PRNGKey(20 + seed), attack)
        _, diag = agg_lib.get_flat_aggregator(
            "krum", spec, diagnostics=True, num_byzantine=B)(buf)
        sel = int(diag.selected)
        assert 0 <= sel < W - B, (seed, sel)
        # Byzantine krum scores are the worst of the field.
        s = np.asarray(diag.score)
        assert s[W - B:].min() > s[:W - B].max(), (seed, s)
        # weight is the selection one-hot.
        np.testing.assert_allclose(
            np.asarray(diag.weight), np.eye(W)[sel], atol=1e-6)


# ---------------- step-level integration (sim federation)


def _sim(aggregator, *, diagnostics, steps=25, attack="sign_flip"):
    data = ijcnn1_like(jax.random.PRNGKey(0), n=240)
    wd = partition({"a": data.x, "b": data.y}, 6, seed=1)
    cfg = RobustConfig(aggregator=aggregator, vr="sgd", attack=attack,
                       num_byzantine=B, weiszfeld_iters=32,
                       diagnostics=diagnostics)
    init_fn, step_fn = make_federated_step(
        logreg_loss(0.01), wd, cfg, get_optimizer("sgd", 0.05))
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(7))
    jstep = jax.jit(step_fn)
    metrics = {}
    for _ in range(steps):
        st, metrics = jstep(st)
    return st.params, metrics


def test_step_diagnostics_off_is_bitexact_and_on_ranks_byzantine():
    p_off, m_off = _sim("geomed", diagnostics=False)
    p_on, m_on = _sim("geomed", diagnostics=True)
    np.testing.assert_array_equal(np.asarray(p_off["w"]),
                                  np.asarray(p_on["w"]))
    assert "diag_weight" not in m_off and "honest_variance" in m_off
    w = np.asarray(m_on["diag_weight"])      # sim appends Byzantine LAST
    assert w.shape == (6 + B,)
    assert w[-B:].max() < w[:-B].min()
    assert float(m_on["honest_variance"]) >= 0.0


def test_step_krum_diag_selects_honest():
    _, m = _sim("krum", diagnostics=True, steps=8)
    assert 0 <= int(m["diag_selected"]) < 6


# ---------------- host sinks


def test_runlogger_jsonl_and_meta(tmp_path):
    d = os.path.join(tmp_path, "run")
    seen = []
    with telemetry.RunLogger(d, log_every=2, flush_every=4,
                             console=lambda s, row: seen.append(s),
                             console_every=5) as lg:
        lg.write_meta(config={"lr": 0.1}, jax_version=jax.__version__)
        for i in range(11):
            lg.log_step(i, {"loss": jnp.float32(i), "vec": jnp.arange(2.0)},
                        host={"time_step_s": 0.5})
    rows = [json.loads(l) for l in open(os.path.join(d, "metrics.jsonl"))]
    assert [r["step"] for r in rows] == [0, 2, 4, 6, 8, 10]
    assert rows[3] == {"step": 6, "loss": 6.0, "vec": [0.0, 1.0],
                       "time_step_s": 0.5}
    assert seen == [0, 5, 10]                # console cadence, incl. step 5
    meta = json.load(open(os.path.join(d, "meta.json")))
    assert meta["config"] == {"lr": 0.1}


def test_runlogger_console_only_mode(tmp_path):
    seen = []
    with telemetry.RunLogger(None, console=lambda s, row: seen.append(row),
                             console_every=2) as lg:
        lg.write_meta(anything=1)            # no-op without a directory
        for i in range(4):
            lg.log_step(i, {"loss": jnp.float32(i)})
    assert [r["loss"] for r in seen] == [0.0, 2.0]
    assert not os.listdir(tmp_path)


def test_runlogger_on_row_sees_every_flushed_row_in_order(tmp_path):
    rows = []
    with telemetry.RunLogger(str(tmp_path), log_every=2, flush_every=2,
                             on_row=rows.append) as lg:
        for i in range(6):
            lg.log_step(i, {"loss": jnp.float32(i), "vec": jnp.arange(2.0)})
    assert [r["step"] for r in rows] == [0, 2, 4]
    # Rows arrive already materialized (the batched device_get happened):
    # plain python scalars/lists, safe for a host-side health monitor.
    assert rows[1]["loss"] == 2.0 and rows[1]["vec"] == [0.0, 1.0]


def test_runlogger_atexit_flushes_buffered_rows_on_crash(tmp_path):
    """A run that dies mid-loop (uncaught exception -> interpreter exit)
    without ever reaching close() must still land its buffered rows in
    metrics.jsonl via the atexit hook registered at construction."""
    import subprocess
    import sys
    import textwrap
    d = os.path.join(tmp_path, "run")
    code = textwrap.dedent(f"""
        from repro.telemetry import RunLogger
        lg = RunLogger({str(d)!r}, flush_every=100)
        for i in range(3):
            lg.log_step(i, {{"loss": float(i)}})
        raise SystemExit(3)   # crash before close(); buffer still pending
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 3, out.stderr
    rows = [json.loads(l) for l in open(os.path.join(d, "metrics.jsonl"))]
    assert [r["step"] for r in rows] == [0, 1, 2]
    assert rows[2]["loss"] == 2.0


def test_phase_timer_accumulates_and_drains():
    t = telemetry.PhaseTimer()
    with t.phase("data"):
        pass
    with t.phase("data"):
        pass
    with t.phase("step"):
        pass
    snap = t.snapshot()
    assert set(snap) == {"time_data_s", "time_step_s"}
    assert all(v >= 0 for v in snap.values())
    assert t.snapshot() == {}                # drained


def test_metric_helpers():
    h = jnp.ones((4, 3), jnp.float32)
    assert float(telemetry.honest_variance(h, 4)) == 0.0
    tree = {"w": jnp.stack([jnp.zeros(3), jnp.ones(3), 5 * jnp.ones(3)])}
    mask = jnp.array([1.0, 1.0, 0.0])
    assert float(telemetry.consensus_dist(tree, mask, 2)) > 0.0
    same = {"w": jnp.ones((3, 2))}
    assert float(telemetry.consensus_dist(same, jnp.ones(3), 3)) == 0.0
    assert telemetry.staleness_metrics(None) == {}
    out = telemetry.staleness_metrics(jnp.array([0.0, 2.0]))
    assert float(out["mean_staleness"]) == 1.0
