"""Checkpoint auto-rollback (DESIGN.md Sec. 13): the host-side recovery
layer on top of the in-graph guards -- RunHealth state machine, degradation
ladder, checkpoint integrity/last-good anchoring, and the end-to-end
rollback paths (simulation in-process, distributed driver in a
subprocess)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from mesh_harness import run_py
from repro.checkpoint import CheckpointManager
from repro.core import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.launch.health import (
    RunHealth,
    apply_rung,
    parse_ladder,
)
from repro.optim import get_optimizer


# ---------------------------------------------------------------------------
# RunHealth state machine
# ---------------------------------------------------------------------------


def test_runhealth_patience_on_rejected_rounds():
    h = RunHealth(patience=3)
    for _ in range(2):
        h.observe({"round_accepted": 0.0, "loss": 1.0})
    assert not h.rollback_pending
    h.observe({"round_accepted": 1.0, "loss": 1.0})   # good round resets
    assert h.healthy
    for _ in range(3):
        h.observe({"round_accepted": 0.0, "loss": 1.0})
    assert h.rollback_pending and not h.healthy


def test_runhealth_nonfinite_and_blowup_losses_are_bad():
    h = RunHealth(patience=2, blowup=10.0)
    h.observe({"loss": 1.0})
    h.observe({"loss": float("nan")})
    h.observe({"loss": float("inf")})
    assert h.rollback_pending
    h2 = RunHealth(patience=2, blowup=10.0)
    h2.observe({"loss": 1.0})
    h2.observe({"loss": 5.0})          # within blowup x best: fine
    assert h2.healthy
    h2.observe({"loss": 11.0})         # > 10 x best(=1.0)
    h2.observe({"loss": 12.0})
    assert h2.rollback_pending


def test_runhealth_rollback_and_dismiss_bookkeeping():
    h = RunHealth(patience=1)
    h.observe({"round_accepted": 0.0})
    assert h.rollback_pending
    h.on_rollback()
    assert h.rollbacks == 1 and not h.rollback_pending and h.healthy
    h.observe({"round_accepted": 0.0})
    assert h.rollback_pending
    h.dismiss()                        # no checkpoint available
    assert h.rollbacks == 1 and not h.rollback_pending
    assert h.summary() == {"rollbacks": 1, "ladder_rungs_used": 0}
    with pytest.raises(ValueError):
        RunHealth(patience=0)


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


def test_parse_ladder_groups_and_errors():
    rungs = parse_ladder("trim=2; aggregator=trimmed_mean , trim=3 ;")
    assert rungs == [{"trim": "2"},
                     {"aggregator": "trimmed_mean", "trim": "3"}]
    assert parse_ladder("") == []
    with pytest.raises(ValueError, match="key=value"):
        parse_ladder("trim")


def test_apply_rung_coerces_to_field_types():
    base = RobustConfig()
    out = apply_rung(base, {"trim": "2", "guard_multiplier": "4.5",
                            "diagnostics": "true", "aggregator": "krum"})
    assert out.trim == 2 and isinstance(out.trim, int)
    assert out.guard_multiplier == 4.5
    assert out.diagnostics is True
    assert out.aggregator == "krum"
    assert base.trim == 1              # frozen original untouched


def test_apply_rung_refuses_unknown_and_structural_fields():
    base = RobustConfig()
    with pytest.raises(ValueError, match="no field"):
        apply_rung(base, {"not_a_field": "1"})
    # Structure-changing fields would invalidate the checkpoint being
    # restored: escalation must refuse them.
    for field in ("vr", "message_dtype", "num_clients", "guards", "comm",
                  "packed", "topology"):
        with pytest.raises(ValueError, match="structure"):
            apply_rung(base, {field: "x"})


def test_escalate_walks_rungs_then_exhausts():
    h = RunHealth(patience=1, ladder="trim=2;trim=3,aggregator=geomed")
    base = RobustConfig(aggregator="trimmed_mean")
    assert h.escalate(base) is base    # no rollback yet
    h.on_rollback()
    r1 = h.escalate(base)
    assert r1.trim == 2 and r1.aggregator == "trimmed_mean"
    h.on_rollback()
    r2 = h.escalate(base)
    assert r2.trim == 3 and r2.aggregator == "geomed"
    h.on_rollback()
    assert h.escalate(base) is base    # ladder exhausted
    assert h.summary() == {"rollbacks": 3, "ladder_rungs_used": 2}


# ---------------------------------------------------------------------------
# Checkpoint integrity + last-good anchor
# ---------------------------------------------------------------------------


def _tree(step):
    return {"w": np.arange(6.0, dtype=np.float32) + step,
            "b": np.float32(step)}


def test_restore_latest_skips_truncated_checkpoint(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, _tree(1))
    p2 = ckpt.save(2, _tree(2))
    blob = open(p2, "rb").read()
    with open(p2, "wb") as f:              # truncate: checksum mismatch
        f.write(blob[: len(blob) // 2])
    with pytest.warns(UserWarning, match="checksum"):
        step, got = ckpt.restore_latest(_tree(0))
    assert step == 1
    np.testing.assert_array_equal(got["w"], _tree(1)["w"])


def test_restore_latest_skips_unreadable_checkpoint(tmp_path):
    """A file whose CONTENT matches the manifest but is not a loadable npz
    (bit rot after the checksum was forged / manifest rebuilt) is skipped
    via the load-exception path, not the checksum path."""
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, _tree(1))
    p2 = ckpt.save(2, _tree(2))
    with open(p2, "wb") as f:
        f.write(b"not an npz at all")
    m = json.load(open(os.path.join(tmp_path, "manifest.json")))
    import hashlib
    m["checksums"][os.path.basename(p2)] = hashlib.sha256(
        b"not an npz at all").hexdigest()
    with open(os.path.join(tmp_path, "manifest.json"), "w") as f:
        json.dump(m, f)
    with pytest.warns(UserWarning, match="unreadable"):
        step, got = ckpt.restore_latest(_tree(0))
    assert step == 1
    np.testing.assert_array_equal(got["w"], _tree(1)["w"])


def test_manifest_checksums_and_legacy_verify(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, _tree(1))
    m = json.load(open(os.path.join(tmp_path, "manifest.json")))
    assert "step_00000001.npz" in m["checksums"]
    assert ckpt.verify(1)
    # Legacy checkpoints (no recorded checksum) must still verify.
    del m["checksums"]["step_00000001.npz"]
    with open(os.path.join(tmp_path, "manifest.json"), "w") as f:
        json.dump(m, f)
    assert ckpt.verify(1)
    assert not ckpt.verify(99)


def test_mark_good_survives_gc_and_restores(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    for s in range(1, 6):
        ckpt.save(s, _tree(s))
        if s == 1:
            ckpt.mark_good(1)
    # keep=2 would normally leave {4, 5}; the last-good anchor survives.
    assert ckpt.all_steps() == [1, 4, 5]
    assert ckpt.last_good_step() == 1
    step, got = ckpt.restore_last_good(_tree(0))
    assert step == 1
    np.testing.assert_array_equal(got["w"], _tree(1)["w"])
    # Stale checksum entries for GC'd files are pruned.
    m = json.load(open(os.path.join(tmp_path, "manifest.json")))
    assert set(m["checksums"]) == {"step_00000001.npz", "step_00000004.npz",
                                   "step_00000005.npz"}
    with pytest.raises(FileNotFoundError):
        ckpt.mark_good(42)


def test_restore_last_good_falls_back_to_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, _tree(1))
    step, got = ckpt.restore_last_good(_tree(0))  # no marker yet
    assert step == 1
    np.testing.assert_array_equal(got["b"], _tree(1)["b"])


# ---------------------------------------------------------------------------
# Simulation rollback: bit-exact recovery
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim():
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=600)
    loss = logreg_loss(0.01)
    wd = partition({"a": data.x, "b": data.y}, 10, seed=1)
    return loss, wd


def test_simulation_rollback_recovers_bit_exact(sim, tmp_path):
    """The full recovery loop at simulation scale: honest guarded training,
    last-good checkpoint, a sustained-rejection phase (health vector poisoned
    so the in-graph verdict rejects every round), RunHealth arming the
    rollback, restore_last_good, and a re-descent that matches a straight
    honest run BIT-EXACTLY on every train-state leaf (the state carries its
    own PRNG key, so the seeded schedule replays)."""
    loss, wd = sim
    cfg = RobustConfig(aggregator="geomed", vr="saga", guards=True)
    opt = get_optimizer("momentum", 0.02)
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    jstep = jax.jit(step_fn)

    def run(st, steps, monitor=None):
        for _ in range(steps):
            st, m = jstep(st)
            if monitor is not None:
                monitor.observe({"round_accepted":
                                 float(m["round_accepted"])})
        return st

    st0 = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(3))
    straight = run(st0, 5)                      # the honest reference

    monitor = RunHealth(patience=2)
    st3 = run(st0, 3, monitor)
    assert monitor.healthy                      # warmup rounds all accepted
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save_train_state(3, st3._asdict())
    ckpt.mark_good(3)

    # Sustained-rejection phase: a collapsed EMA (tiny mean/var, seen past
    # warmup) makes every subsequent aggregate a huge z-score outlier, so
    # the in-graph verdict rejects each round and HOLDS the state.
    poisoned = st3._replace(health=jnp.asarray(
        [1e-8, 1e-16, 0.0, 10.0], jnp.float32))
    bad = run(poisoned, 2, monitor)
    np.testing.assert_array_equal(np.asarray(bad.params["w"]),
                                  np.asarray(st3.params["w"]))
    assert int(bad.step) == 5                   # step counter still advances
    assert monitor.rollback_pending             # 2 rejected rounds = patience

    gstep, restored = ckpt.restore_last_good(st3._asdict())
    assert gstep == 3
    monitor.on_rollback()
    assert monitor.rollbacks == 1
    resumed = run(type(st0)(**restored), 2, monitor)
    assert monitor.healthy                      # re-descent rounds accepted

    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(
        straight._asdict())[0]]
    for path, a, b in zip(paths,
                          jax.tree_util.tree_leaves(straight._asdict()),
                          jax.tree_util.tree_leaves(resumed._asdict())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(path))


# ---------------------------------------------------------------------------
# Distributed driver rollback (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow  # two full 16-step driver runs in one subprocess
def test_distributed_driver_rollback_is_deterministic(tmp_path):
    """The launch driver end to end: an over-tight verdict gate
    (--reject-zmax 0.02) makes post-warmup rounds reject, RunHealth arms
    after 2, and the loop restores the last-good checkpoint and re-descends
    to completion.  Two identical runs -- each rolling back the same way --
    must land on the bit-identical final loss: the recovery path is as
    deterministic as the trajectory it restores."""
    out = run_py(f"""
        import json, math, os, sys

        def drive(tag):
            ck = os.path.join({str(tmp_path)!r}, tag + "-ckpt")
            lg = os.path.join({str(tmp_path)!r}, tag + "-log")
            sys.argv = ["train", "--arch", "mamba2-130m", "--reduced",
                        "--steps", "16", "--seq", "32", "--mesh", "4x2",
                        "--aggregator", "mean", "--guards",
                        "--reject-zmax", "0.02",
                        "--rollback-patience", "2",
                        "--checkpoint-dir", ck, "--checkpoint-every", "2",
                        "--log-dir", lg, "--log-every", "1"]
            from repro.launch.train import main
            main()
            meta = json.load(open(os.path.join(lg, "meta.json")))
            return meta["resilience"]

        r1 = drive("a")
        r2 = drive("b")
        assert r1["rollbacks"] >= 1, r1
        assert r1["rejected_rounds"] > 0, r1
        assert math.isfinite(r1["final_loss"]), r1
        assert r1 == r2, (r1, r2)
        print("RESILIENCE", json.dumps(r1))
    """, devices=8, timeout=600)
    assert "rollback #1: restored step" in out
    assert "RESILIENCE" in out
