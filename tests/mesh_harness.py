"""Shared subprocess harness for multi-device tests.

Multi-device tests need >1 host device, and jax locks the device count at
first initialization, so each test runs in a fresh subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=<devices> while the main
pytest process keeps its default single device.  Inline test programs should
go through ``repro.compat`` (make_mesh / use_mesh / shard_map) so they run on
every supported jax version.

Launches are retried with bounded exponential backoff: a loaded CI box can
transiently kill a subprocess spawn or starve it past the per-attempt
timeout, and one flaky launch should not fail the suite.  The final
failure's assertion message carries every attempt's outcome plus the last
child's stderr tail, so the real error lands in the pytest report.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420,
           extra_env: dict | None = None, attempts: int = 3,
           backoff: float = 2.0) -> str:
    """Run ``code`` (dedented) in a subprocess with ``devices`` forced host
    devices and PYTHONPATH=src; assert exit 0 and return stdout.

    Retries up to ``attempts`` times on non-zero exit or per-attempt
    timeout, sleeping ``backoff``, ``2*backoff``, ... between attempts."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if extra_env:
        env.update(extra_env)
    program = textwrap.dedent(code)
    outcomes: list[str] = []
    stderr_tail = ""
    for attempt in range(1, max(int(attempts), 1) + 1):
        try:
            out = subprocess.run([sys.executable, "-c", program],
                                 capture_output=True, text=True,
                                 timeout=timeout, env=env)
        except subprocess.TimeoutExpired as e:
            outcomes.append(f"attempt {attempt}: timeout after {timeout}s")
            err = e.stderr
            if isinstance(err, bytes):
                err = err.decode(errors="replace")
            stderr_tail = (err or "")[-3000:]
        else:
            if out.returncode == 0:
                return out.stdout
            outcomes.append(f"attempt {attempt}: exit {out.returncode}")
            stderr_tail = out.stderr[-3000:]
        if attempt < attempts:
            time.sleep(backoff * (2 ** (attempt - 1)))
    raise AssertionError(
        f"subprocess failed after {len(outcomes)} attempt(s) "
        f"({'; '.join(outcomes)})\nstderr tail:\n{stderr_tail}")
