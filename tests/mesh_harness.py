"""Shared subprocess harness for multi-device tests.

Multi-device tests need >1 host device, and jax locks the device count at
first initialization, so each test runs in a fresh subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=<devices> while the main
pytest process keeps its default single device.  Inline test programs should
go through ``repro.compat`` (make_mesh / use_mesh / shard_map) so they run on
every supported jax version.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420,
           extra_env: dict | None = None) -> str:
    """Run ``code`` (dedented) in a subprocess with ``devices`` forced host
    devices and PYTHONPATH=src; assert exit 0 and return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if extra_env:
        env.update(extra_env)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout
