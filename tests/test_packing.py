"""Flat-packed aggregation core (DESIGN.md Sec. 8).

Three layers of contracts:

* ``PackSpec`` round-trip/layout properties (hypothesis-driven with the
  seeded ``tests/_hypothesis_fallback.py`` shim): pack -> unpack is the
  identity for any tree of mixed dtypes/shapes -- scalar leaves, empty
  leaves, and padding included -- and independently built specs for the
  same tree agree (determinism).

* The PIN of the refactor: for every registry aggregator (and every
  masked topology counterpart) the pytree API is BIT-EXACT with the flat
  engine -- the pytree rules really are pack -> flat -> unpack shims, so
  packed callers and pytree callers can never drift apart.  The retained
  pre-refactor per-leaf implementations (``perleaf=True``) are the
  tolerance anchor: same math to within reduction-reassociation ulps.

* Step-level regressions: packed vs per-leaf simulated federation (master
  AND decentralized, every attack incl. the RNG-mirrored gaussian) stays
  bit-exact on the paper's logreg workload and within float tolerance on
  a many-leaf MLP; the bfloat16 message mode halves the wire and tracks
  the f32 trajectory.

The distributed (shard_map) packed-vs-per-leaf pins live in
``tests/test_distributed.py`` (they need the 8-device harness).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # keep the suite collectable without the dev extra
    from _hypothesis_fallback import hypothesis, st

from repro.core import RobustConfig, make_federated_step, packing
from repro.core import aggregators as agg_lib
from repro.core.attacks import ATTACK_NAMES
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer
from repro.topology import graphs, masked_aggregate, masked_aggregate_flat

KEY = jax.random.PRNGKey(0)

AGG_OPTS = dict(max_iters=80, tol=1e-8, num_groups=3, trim=1,
                num_byzantine=1, clip_radius=2.0)


def _payload(w=9):
    """Mixed-shape f32 worker messages: matrix, 3-d, vector, scalar."""
    ks = jax.random.split(KEY, 4)
    return {
        "a": jax.random.normal(ks[0], (w, 7)),
        "b": jax.random.normal(ks[1], (w, 3, 2)),
        "c": jax.random.normal(ks[2], (w,)),
        "d": jax.random.normal(ks[3], (w, 2, 2, 2)),
    }


# ---------------------------------------------------------------------------
# PackSpec properties
# ---------------------------------------------------------------------------

@hypothesis.given(
    num_leaves=st.integers(1, 6),
    batch=st.integers(1, 5),
    seed=st.integers(0, 2**16),
    pad_to=st.integers(1, 7),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip_property(num_leaves, batch, seed, pad_to):
    rng = np.random.default_rng(seed)
    dtypes = [jnp.float32, jnp.bfloat16, jnp.float16]
    tree = {}
    for i in range(num_leaves):
        shape = tuple(int(s) for s in rng.integers(0, 4, rng.integers(0, 3)))
        dt = dtypes[int(rng.integers(0, len(dtypes)))]
        tree[f"leaf{i}"] = jnp.asarray(
            rng.standard_normal((batch,) + shape), jnp.float32).astype(dt)
    spec = packing.pack_spec(tree, pad_to=pad_to)
    buf = spec.pack(tree)
    assert buf.shape == (batch, spec.padded_dim)
    assert spec.padded_dim % pad_to == 0
    assert spec.padded_dim - spec.dim < pad_to
    back = spec.unpack(buf)
    for k in tree:
        # f32 wire: every supported leaf dtype survives the round trip
        # exactly (bf16/f16 -> f32 -> back is lossless).
        np.testing.assert_array_equal(
            np.asarray(back[k], np.float32), np.asarray(tree[k], np.float32),
            err_msg=k)
        assert back[k].dtype == tree[k].dtype


def test_pack_spec_deterministic_and_struct_built():
    tree = _payload()
    s1 = packing.pack_spec(tree)
    s2 = packing.pack_spec(tree)
    # Specs built independently (and from ShapeDtypeStructs instead of
    # concrete arrays) agree on the whole layout.
    structs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    s3 = packing.pack_spec(structs)
    for s in (s2, s3):
        assert s1.shapes == s.shapes and s1.dtypes == s.dtypes
        assert s1.offsets == s.offsets and s1.dim == s.dim
        assert s1.boundaries == s.boundaries
    np.testing.assert_array_equal(np.asarray(s1.seg_ids()),
                                  np.asarray(s3.seg_ids()))


def test_pack_edge_cases_scalar_empty_and_errors():
    w = 5
    tree = {"s": jnp.arange(w, dtype=jnp.float32),       # scalar messages
            "e": jnp.zeros((w, 0)),                      # empty leaf
            "m": jnp.ones((w, 2, 3))}
    spec = packing.pack_spec(tree)
    assert spec.sizes == (0, 2 * 3, 1)  # dict order: e, m, s
    buf = spec.pack(tree)
    assert buf.shape == (w, 7)
    back = spec.unpack(buf)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]), err_msg=k)
    # seg ids cover every leaf + the padding dummy block
    spec_p = packing.pack_spec(tree, pad_to=4)
    ids = np.asarray(spec_p.seg_ids())
    assert ids.shape == (8,)
    assert ids[-1] == spec_p.num_leaves  # dummy id on the padding
    # shape mismatch is rejected at pack time, dim mismatch at unpack time
    with pytest.raises(ValueError, match="does not match"):
        spec.pack({"s": tree["s"], "e": tree["e"], "m": jnp.ones((w, 3, 2))})
    with pytest.raises(ValueError, match="padded_dim"):
        spec.unpack(jnp.zeros((w, 9)))
    with pytest.raises(ValueError, match="message_dtype"):
        packing.resolve_message_dtype("float8")


def test_pack_empty_tree():
    spec = packing.pack_spec({})
    assert spec.dim == 0 and spec.num_leaves == 0
    assert spec.unpack(spec.pack({}), batch_ndim=0) == {}


def test_bf16_wire_halves_bytes_and_quantizes_once():
    tree = _payload()
    spec32 = packing.pack_spec(tree)
    spec16 = packing.pack_spec(tree, message_dtype=jnp.bfloat16)
    b32, b16 = spec32.pack(tree), spec16.pack(tree)
    assert b16.dtype == jnp.bfloat16
    assert b16.nbytes * 2 == b32.nbytes
    # unpack restores the leaf dtype; values are the one-time bf16 rounding
    back = spec16.unpack(b16)
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(back[k]),
            np.asarray(tree[k].astype(jnp.bfloat16).astype(tree[k].dtype)),
            err_msg=k)


# ---------------------------------------------------------------------------
# The pin: pytree aggregator API == flat engine, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_pytree_aggregator_is_bit_exact_with_flat_engine(name):
    tree = _payload()
    spec = packing.pack_spec(tree)
    shim = agg_lib.get_aggregator(name, **AGG_OPTS)(tree)
    flat = spec.unpack(
        agg_lib.get_flat_aggregator(name, spec, **AGG_OPTS)(spec.pack(tree)),
        batch_ndim=0)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(shim[k]),
                                      np.asarray(flat[k]),
                                      err_msg=f"{name} {k}")


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_flat_engine_matches_perleaf_baseline(name):
    """The retained pre-refactor per-leaf implementations are the
    tolerance anchor: identical math modulo reduction reassociation."""
    tree = _payload()
    new = agg_lib.get_aggregator(name, **AGG_OPTS)(tree)
    old = agg_lib.get_aggregator(name, perleaf=True, **AGG_OPTS)(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(new[k]), np.asarray(old[k]),
                                   atol=3e-5, err_msg=f"{name} {k}")


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_masked_pytree_is_bit_exact_with_flat_engine(name):
    z = _payload(8)
    exchange = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (8,) + v.shape), z)
    mask = jnp.asarray(graphs.ring(8).neighbor_mask)
    spec = packing.pack_spec(exchange, batch_ndim=2)
    shim = masked_aggregate(name, exchange, mask, **AGG_OPTS)
    flat = spec.unpack(
        masked_aggregate_flat(name, spec.pack(exchange, batch_ndim=2), mask,
                              spec=spec, **AGG_OPTS), batch_ndim=1)
    legacy = masked_aggregate(name, exchange, mask, perleaf=True, **AGG_OPTS)
    for k in z:
        np.testing.assert_array_equal(np.asarray(shim[k]),
                                      np.asarray(flat[k]),
                                      err_msg=f"{name} {k}")
        np.testing.assert_allclose(np.asarray(shim[k]), np.asarray(legacy[k]),
                                   atol=5e-5, err_msg=f"{name} legacy {k}")


# ---------------------------------------------------------------------------
# Step-level packed-vs-per-leaf regressions (simulation paths)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def logreg():
    data = ijcnn1_like(jax.random.PRNGKey(0), n=400)
    wd = partition({"a": data.x, "b": data.y}, 8, seed=1)
    return logreg_loss(0.01), wd


def _run_sim(loss, wd, cfg, steps=5, topology=None):
    kwargs = {} if topology is None else {"topology": topology}
    init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                           get_optimizer("sgd", 0.02),
                                           **kwargs)
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(3))
    jstep = jax.jit(step_fn)
    for _ in range(steps):
        st, metrics = jstep(st)
    return st, metrics


@pytest.mark.parametrize("attack", [a for a in ATTACK_NAMES if a != "none"])
def test_master_sim_step_packed_equals_perleaf_bitwise(logreg, attack):
    """Full Byrd-SAGA trajectories, packed vs per-leaf, bit-exact on the
    paper workload FOR EVERY ATTACK -- the gaussian case pins the
    RNG-mirrored packed draws (packed_gaussian_noise)."""
    loss, wd = logreg
    outs = {}
    for packed in (True, False):
        cfg = RobustConfig(aggregator="geomed", vr="saga", attack=attack,
                           num_byzantine=2, weiszfeld_iters=16, packed=packed)
        outs[packed], _ = _run_sim(loss, wd, cfg)
    np.testing.assert_array_equal(np.asarray(outs[True].params["w"]),
                                  np.asarray(outs[False].params["w"]))


@pytest.mark.parametrize("gossip", ["gradient", "params"])
def test_decentralized_sim_step_packed_equals_perleaf_bitwise(logreg, gossip):
    loss, wd = logreg
    outs = {}
    for packed in (True, False):
        cfg = RobustConfig(aggregator="geomed", vr="saga", attack="gaussian",
                           num_byzantine=2, weiszfeld_iters=16,
                           gossip=gossip, topology="ring", packed=packed)
        outs[packed], m = _run_sim(loss, wd, cfg, steps=4)
        assert np.isfinite(float(m["consensus_dist"]))
    np.testing.assert_array_equal(np.asarray(outs[True].params["w"]),
                                  np.asarray(outs[False].params["w"]))


def _mlp(key, layers=4, h=8, din=22):
    p = {}
    ks = jax.random.split(key, layers + 1)
    for i in range(layers):
        p[f"w{i}"] = 0.3 * jax.random.normal(ks[i], (din if i == 0 else h, h))
        p[f"b{i}"] = jnp.zeros((h,))
    p["wout"] = 0.3 * jax.random.normal(ks[-1], (h,))
    p["bout"] = jnp.zeros(())
    return p


def _mlp_loss(params, batch, layers=4):
    x, y = batch["a"], batch["b"]
    for i in range(layers):
        x = jnp.tanh(x @ params[f"w{i}"] + params[f"b{i}"])
    logit = x @ params["wout"] + params["bout"]
    return jnp.mean(jnp.logaddexp(0.0, -y * logit))


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_multileaf_sim_step_packed_tracks_perleaf(logreg, name):
    """Many-leaf model (10 blocks incl. a scalar): every registry
    aggregator's packed trajectory tracks the per-leaf one to float
    tolerance over 4 steps (bitwise is not defined across the two engines
    -- XLA reassociates the cross-leaf norm reductions)."""
    _, wd = logreg
    outs = {}
    for packed in (True, False):
        cfg = RobustConfig(aggregator=name, vr="saga", attack="gaussian",
                           num_byzantine=2, weiszfeld_iters=16, num_groups=3,
                           packed=packed)
        init_fn, step_fn = make_federated_step(_mlp_loss, wd, cfg,
                                               get_optimizer("sgd", 0.05))
        st = init_fn(_mlp(jax.random.PRNGKey(1)), jax.random.PRNGKey(3))
        jstep = jax.jit(step_fn)
        for _ in range(4):
            st, _ = jstep(st)
        outs[packed] = st
    for a, b in zip(jax.tree_util.tree_leaves(outs[True].params),
                    jax.tree_util.tree_leaves(outs[False].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


def test_bf16_message_mode_runs_and_tracks_f32(logreg):
    """message_dtype='bfloat16' halves the wire; the f32-accumulating
    robust rules keep the trajectory near the f32-wire run."""
    loss, wd = logreg
    outs = {}
    for mdt in ("float32", "bfloat16"):
        cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                           num_byzantine=2, weiszfeld_iters=16,
                           message_dtype=mdt)
        outs[mdt], m = _run_sim(loss, wd, cfg, steps=10)
        assert np.isfinite(float(m["honest_variance"]))
    w16 = np.asarray(outs["bfloat16"].params["w"])
    w32 = np.asarray(outs["float32"].params["w"])
    assert np.isfinite(w16).all()
    # bf16 has ~3 decimal digits; 10 steps of drift stays small
    np.testing.assert_allclose(w16, w32, atol=5e-2)
    # and the SAGA memory really lives on the half-width wire
    assert outs["bfloat16"].vr.table.dtype == jnp.bfloat16