"""Property suite for the fault-containment guards (DESIGN.md Sec. 13).

Bit-identity contract pinned here (the Sec. 13 fine print):

- ``guards=False`` is the pre-PR code path, byte-identical by construction
  (no guard code runs) -- the packed-vs-perleaf pins in test_packing.py
  already cover it.
- With guards ON and a clean (all-valid) round, the ENGINE-level call is
  bit-identical to the raw engine under jit for every registry aggregator
  (``guarded_flat_call`` selects the RAW double-compute output, with
  optimization barriers keeping XLA from multi-output-fusing the two
  reductions).
- STEP-level guards-on/off bit-identity is pinned EAGERLY: under jit the
  guards-off graph can fuse the message producers into its reduction with
  FMA contraction, which no differently-shaped graph can reproduce (~1e-9
  on mean); eager execution removes the fusion variable and pins the
  mathematical claim -- same messages, same aggregate, same trajectory.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg_lib
from repro.core import guards, packing
from repro.core.robust_step import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.optim import get_optimizer

KEY = jax.random.PRNGKey(0)


def _cfg(name, **kw):
    kw.setdefault("weiszfeld_iters", 16)
    kw.setdefault("num_groups", 3)
    kw.setdefault("num_byzantine", kw.pop("byz", 2))
    return RobustConfig(aggregator=name, **kw)


def _flat_fn(name, spec, **kw):
    return _cfg(name, **kw).flat_aggregator_fn(spec)


@pytest.fixture(scope="module")
def buf_spec():
    tree = {"a": jax.random.normal(KEY, (8, 22)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (8, 3, 5))}
    spec = packing.pack_spec(tree)
    return spec.pack(tree), spec


@pytest.fixture(scope="module")
def logreg():
    data = ijcnn1_like(jax.random.PRNGKey(0), n=600)
    wd = partition({"a": data.x, "b": data.y}, 8, seed=1)
    return logreg_loss(0.01), {"a": data.x, "b": data.y}, wd


# ---------------------------------------------------------------------------
# Engine-level bit-identity under jit (clean rounds).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_engine_guarded_call_bitwise_identical_under_jit(name, buf_spec):
    buf, spec = buf_spec
    flat_fn = _flat_fn(name, spec)
    mask = guards.guard_mask(buf)
    np.testing.assert_array_equal(np.asarray(mask), 1.0)  # honest data
    raw = jax.jit(flat_fn)(buf)
    grd = jax.jit(lambda b, m: guards.guarded_flat_call(flat_fn, b, m))(
        buf, mask)
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(grd), err_msg=name)


@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_engine_guarded_call_bitwise_identical_weighted(name, buf_spec):
    buf, spec = buf_spec
    flat_fn = _flat_fn(name, spec)
    rw = jnp.array([1.0, 0.5, 2.0, 1.0, 0.0, 1.0, 1.5, 1.0], jnp.float32)
    mask = guards.guard_mask(buf, base_weights=rw)
    raw = jax.jit(lambda b: flat_fn(b, row_weights=rw))(buf)
    grd = jax.jit(lambda b, m: guards.guarded_flat_call(
        flat_fn, b, m, row_weights=rw))(buf, mask)
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(grd), err_msg=name)


# ---------------------------------------------------------------------------
# Step-level bit-identity, eager (every registry aggregator).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", agg_lib.AGGREGATOR_NAMES)
def test_step_guards_onoff_bitwise_identical_eager(name, logreg):
    loss, _, wd = logreg
    outs = {}
    for on in (False, True):
        cfg = _cfg(name, vr="saga", attack="none", byz=0, guards=on)
        init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                               get_optimizer("sgd", 0.05))
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                     jax.random.PRNGKey(3))
        with jax.disable_jit():
            for _ in range(2):
                st, m = step_fn(st)
        outs[on] = st
        if on:
            assert float(m["quarantined_rows"]) == 0.0
            assert float(m["round_accepted"]) == 1.0
    np.testing.assert_array_equal(np.asarray(outs[False].params["w"]),
                                  np.asarray(outs[True].params["w"]),
                                  err_msg=name)


# ---------------------------------------------------------------------------
# The guard mask itself.
# ---------------------------------------------------------------------------

def test_nonfinite_row_gets_weight_exactly_zero(buf_spec):
    buf, _ = buf_spec
    for poison in (jnp.nan, jnp.inf, -jnp.inf):
        bad = buf.at[3, 7].set(poison)   # ONE poisoned coordinate
        mask = np.asarray(guards.guard_mask(bad))
        assert mask[3] == 0.0
        expect = np.ones(8); expect[3] = 0.0
        np.testing.assert_array_equal(mask, expect)


def test_magnitude_gate_quarantines_overflow_row(buf_spec):
    buf, _ = buf_spec
    bad = buf.at[5].set(1e30)
    mask = np.asarray(guards.guard_mask(bad, multiplier=10.0))
    assert mask[5] == 0.0 and mask.sum() == 7.0


@pytest.mark.parametrize("seed", range(10))
def test_magnitude_gate_spares_honest_rows(seed):
    """Seeded honest-only data: the x10 gate never quarantines anything --
    and even a x3 gate stays within the Byzantine budget (< W/2)."""
    z = jax.random.normal(jax.random.PRNGKey(seed), (10, 33))
    assert float(jnp.sum(1.0 - guards.guard_mask(z, multiplier=10.0))) == 0.0
    q3 = float(jnp.sum(1.0 - guards.guard_mask(z, multiplier=3.0)))
    assert q3 < 5.0, q3


def test_zero_weight_rows_excluded_from_median(buf_spec):
    """base_weights=0 rows (dropped cohort slots) neither poison the median
    norm nor count as quarantined by the guard."""
    buf, _ = buf_spec
    rw = jnp.ones((8,), jnp.float32).at[2].set(0.0)
    bad = buf.at[2].set(jnp.nan)   # dead slot carries garbage
    mask = np.asarray(guards.guard_mask(bad, base_weights=rw))
    np.testing.assert_array_equal(mask, np.ones(8) - np.eye(8)[2])


def test_sanitize_rows_zeroes_only_masked_rows(buf_spec):
    buf, _ = buf_spec
    bad = buf.at[1].set(jnp.inf)
    mask = guards.guard_mask(bad)
    clean = np.asarray(guards.sanitize_rows(bad, mask))
    np.testing.assert_array_equal(clean[1], 0.0)
    np.testing.assert_array_equal(clean[0], np.asarray(buf)[0])
    assert np.isfinite(clean).all()


def test_pairwise_guard_mask_is_per_receiver():
    """The decentralized gate medians over each receiver's own neighborhood:
    a poisoned SENDER is quarantined on exactly its live edges."""
    ex = jax.random.normal(KEY, (6, 6, 9))
    wmask = jnp.ones((6, 6)) - jnp.eye(6)
    bad = ex.at[:, 4].set(jnp.nan)          # sender 4 poisons every edge
    emask = np.asarray(guards.pairwise_guard_mask(bad, wmask))
    np.testing.assert_array_equal(emask[:, 4] * np.asarray(wmask)[:, 4], 0.0)
    keep = np.ones((6, 6)); keep[:, 4] = 0.0
    np.testing.assert_array_equal(emask * np.asarray(wmask),
                                  keep * np.asarray(wmask))


# ---------------------------------------------------------------------------
# Round-health verdict.
# ---------------------------------------------------------------------------

def test_round_verdict_warmup_accepts_then_spike_rejected():
    health = guards.init_health()
    for _ in range(8):   # warmup: everything finite is accepted
        accept, health = guards.round_verdict(jnp.float32(1.0), health,
                                              warmup=8)
        assert bool(accept)
    accept, health = guards.round_verdict(jnp.float32(100.0), health,
                                          warmup=8)
    assert not bool(accept)
    assert float(health[2]) == 1.0          # rejected counter
    assert float(health[0]) == 1.0          # EMA held on the rejected round
    accept, health = guards.round_verdict(jnp.float32(1.04), health, warmup=8)
    assert bool(accept)
    # The EMA advances on the ACCEPTED round (0.9 * 1.0 + 0.1 * 1.04).
    np.testing.assert_allclose(float(health[0]), 1.004, rtol=1e-5)


def test_round_verdict_nonfinite_always_rejected():
    health = guards.init_health()
    for norm in (jnp.float32(jnp.nan), jnp.float32(jnp.inf)):
        accept, health = guards.round_verdict(norm, health, warmup=8)
        assert not bool(accept)   # even during warmup
    assert float(health[2]) == 2.0


def test_round_verdict_zmax_nonpositive_is_finite_only_gate():
    health = guards.init_health()
    for _ in range(10):
        accept, health = guards.round_verdict(jnp.float32(1.0), health,
                                              zmax=0.0, warmup=2)
        assert bool(accept)
    accept, _ = guards.round_verdict(jnp.float32(1e6), health, zmax=0.0,
                                     warmup=2)
    assert bool(accept)


def test_step_level_reject_holds_train_state(logreg):
    """A rejected round advances step/key/health but holds params, opt
    moments and the SAGA table bit-exactly (the in-graph select)."""
    loss, _, wd = logreg
    cfg = _cfg("geomed", vr="saga", attack="none", byz=0, guards=True,
               reject_warmup=2)
    init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                           get_optimizer("momentum", 0.05))
    jstep = jax.jit(step_fn)
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(3))
    for _ in range(3):
        st, _ = jstep(st)
    # Re-seed the health EMA to a microscopic norm: the next (honest)
    # aggregate is a guaranteed z-score outlier.
    poisoned = st._replace(health=jnp.array([1e-8, 1e-16, 0.0, 10.0],
                                            jnp.float32))
    nxt, m = jstep(poisoned)
    assert float(m["round_accepted"]) == 0.0
    assert float(m["rejected_rounds"]) == 1.0
    assert int(nxt.step) == int(poisoned.step) + 1
    np.testing.assert_array_equal(np.asarray(nxt.params["w"]),
                                  np.asarray(poisoned.params["w"]))
    for a, b in zip(jax.tree_util.tree_leaves(nxt.opt_state),
                    jax.tree_util.tree_leaves(poisoned.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(nxt.vr),
                    jax.tree_util.tree_leaves(poisoned.vr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Containment end-to-end (sim master, both engines).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attack", ["nan", "inf_overflow", "bitflip"])
@pytest.mark.parametrize("packed", [True, False])
def test_fault_attacks_contained_on_sim_master(logreg, attack, packed):
    """byz < W/2 fault rows with guards on: the run stays finite and lands
    within 2x the attack-free floor; the nan attack with guards OFF
    destroys the run (non-finite loss)."""
    loss, batch, wd = logreg
    def train(cfg, steps=150):
        init_fn, step_fn = make_federated_step(loss, wd, cfg,
                                               get_optimizer("sgd", 0.05))
        st = init_fn({"w": jnp.zeros((22,), jnp.float32)},
                     jax.random.PRNGKey(3))
        jstep = jax.jit(step_fn)
        for _ in range(steps):
            st, _ = jstep(st)
        return float(loss(st.params, batch))
    floor = train(_cfg("geomed", vr="saga", attack="none", byz=0,
                       packed=packed))
    guarded = train(_cfg("geomed", vr="saga", attack=attack, byz=3,
                         packed=packed, guards=True, bitflip_prob=0.5))
    assert np.isfinite(guarded)
    assert guarded <= 2.0 * floor + 1e-3, (attack, guarded, floor)
    if attack == "nan":
        bare = train(_cfg("geomed", vr="saga", attack="nan", byz=3,
                          packed=packed), steps=5)
        assert not np.isfinite(bare)
