"""Property-based contracts for every topology constructor and schedule.

Hypothesis-driven (with the seeded ``tests/_hypothesis_fallback.py`` shim
when the dev extra is absent) algebraic invariants of DESIGN.md Secs. 6-7,
across random ``num_nodes`` / ``p`` / ``seed`` rather than hand-picked
examples:

* mixing matrices are symmetric and doubly stochastic to 1e-12 (float64
  Metropolis-Hastings construction);
* neighbor masks carry an all-ones diagonal (self-loops) and are symmetric;
* spectral gaps (per-graph and joint-over-a-period) live in [0, 1];
* constructed graphs are connected; schedules are connected over their
  window even when single rounds are not, and a static schedule's joint
  gap equals its graph's spectral gap exactly.
"""
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # keep the suite collectable without the dev extra
    from _hypothesis_fallback import hypothesis, st

from repro.topology import (
    SCHEDULE_NAMES,
    TOPOLOGY_NAMES,
    as_schedule,
    cyclic_schedule,
    erdos_renyi_schedule,
    get_schedule,
    get_topology,
    static_schedule,
)
from repro.topology import graphs


def _check_mixing(mixing, n):
    """Symmetric + doubly stochastic to 1e-12, non-negative, positive
    diagonal (the self-weight that makes window products scrambling)."""
    assert mixing.shape == (n, n)
    np.testing.assert_allclose(mixing.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(mixing.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(mixing, mixing.T, atol=1e-12)
    assert (mixing >= 0).all()
    assert (np.diagonal(mixing) > 0).all()


def _check_mask(mask, n):
    """All-ones diagonal (self-loops) and symmetric, values in {0, 1}."""
    assert mask.shape == (n, n)
    assert (np.diagonal(mask) == 1).all()
    np.testing.assert_array_equal(mask, mask.T)
    assert set(np.unique(mask)).issubset({0.0, 1.0})


def _valid_nodes(name: str, n: int) -> bool:
    if name == "torus2d":
        # Needs a rows x cols factorization with both sides >= 2.
        return n >= 4 and any(n % d == 0 for d in range(2, int(n**0.5) + 1))
    return n >= 2


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(num_nodes=st.integers(2, 20), p=st.floats(0.3, 0.9),
                  seed=st.integers(0, 2**16))
def test_every_constructor_invariants(num_nodes, p, seed):
    # Loop the registry inside the example (the fallback shim's given()
    # cannot stack with pytest.mark.parametrize): EVERY constructor must
    # satisfy the invariants on every drawn (N, p, seed) it accepts.
    for name in TOPOLOGY_NAMES:
        if not _valid_nodes(name, num_nodes):
            continue
        t = get_topology(name, num_nodes, seed=seed, p=p)
        assert t.num_nodes == num_nodes
        _check_mixing(t.mixing, num_nodes)
        _check_mask(t.neighbor_mask, num_nodes)
        assert not t.adjacency.diagonal().any()
        assert (t.adjacency == t.adjacency.T).all()
        assert t.is_connected()
        gap = t.spectral_gap()
        assert 0.0 <= gap <= 1.0 + 1e-12, name
        assert gap > 0.0, name  # connected + positive mixing diagonal
        assert t.min_neighborhood == int(t.degrees.min()) + 1


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(num_nodes=st.integers(4, 16), p=st.floats(0.35, 0.9),
                  seed=st.integers(0, 2**16), period=st.integers(1, 5),
                  pick=st.integers(0, 2**8))
def test_every_schedule_invariants(num_nodes, p, seed, period, pick):
    base = ("ring", "complete", "star")[pick % 3]
    for name in SCHEDULE_NAMES:
        sched = get_schedule(name, num_nodes, topology=base, period=period,
                             seed=seed, p=p)
        assert sched.num_nodes == num_nodes
        if name == "static":
            assert sched.period == 1
        elif name == "erdos_renyi":
            assert sched.period == period
        # Stacked compile-time constants agree with the per-round matrices.
        masks, mixing = sched.stacked_masks, sched.stacked_mixing
        assert masks.shape == (sched.period, num_nodes, num_nodes)
        for t in range(sched.period):
            _check_mask(masks[t], num_nodes)
            _check_mixing(mixing[t], num_nodes)
            np.testing.assert_array_equal(masks[t],
                                          sched.topologies[t].neighbor_mask)
            np.testing.assert_array_equal(np.asarray(sched.mask_at(t)),
                                          masks[t])
            # Round selection wraps modulo the period.
            np.testing.assert_array_equal(
                np.asarray(sched.mask_at(t + 3 * sched.period)), masks[t])
        per_round_gaps = [t.spectral_gap() for t in sched.topologies]
        assert all(0.0 <= g <= 1.0 + 1e-12 for g in per_round_gaps)
        joint = sched.joint_spectral_gap()
        assert 0.0 <= joint <= 1.0 + 1e-12, name
        # Window connectivity: single rounds may be disconnected
        # (erdos_renyi draws are raw), the union over the period is what
        # gossip needs -- and exactly when it holds, the joint contraction
        # is strict.
        if sched.is_connected_over_window():
            assert joint > 0.0, name
        else:
            assert name == "erdos_renyi"  # the only raw-draw schedule
            assert joint <= 1e-9


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(num_nodes=st.integers(2, 20), p=st.floats(0.3, 0.9),
                  seed=st.integers(0, 2**16), pick=st.integers(0, 2**8))
def test_static_schedule_matches_its_topology(num_nodes, p, seed, pick):
    name = TOPOLOGY_NAMES[pick % len(TOPOLOGY_NAMES)]
    hypothesis.assume(_valid_nodes(name, num_nodes))
    topo = get_topology(name, num_nodes, seed=seed, p=p)
    sched = static_schedule(topo)
    assert sched.is_static and sched.period == 1
    np.testing.assert_array_equal(sched.stacked_masks[0], topo.neighbor_mask)
    np.testing.assert_array_equal(sched.stacked_mixing[0], topo.mixing)
    # T = 1 joint gap reduces exactly to the symmetric eigen-gap.
    np.testing.assert_allclose(sched.joint_spectral_gap(),
                               topo.spectral_gap(), atol=1e-9)
    assert sched.is_connected_over_window() == topo.is_connected()
    # as_schedule round-trips both representations.
    assert as_schedule(topo).topologies == (topo,)
    assert as_schedule(sched) is sched


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(num_nodes=st.integers(4, 16), p=st.floats(0.35, 0.9),
                  seed=st.integers(0, 2**16), period=st.integers(1, 5))
def test_erdos_renyi_schedule_deterministic_and_seed_sensitive(
        num_nodes, p, seed, period):
    a = erdos_renyi_schedule(num_nodes, p=p, seed=seed, period=period)
    b = erdos_renyi_schedule(num_nodes, p=p, seed=seed, period=period)
    np.testing.assert_array_equal(a.stacked_masks, b.stacked_masks)
    c = erdos_renyi_schedule(num_nodes, p=p, seed=seed + 1, period=period)
    # Seed-sensitivity and round-independence are ASSERTED, but only on
    # configurations where an honest coincidence is essentially impossible
    # (>= C(8,2)=28 edge draws at a non-extreme p: collision odds < 1e-6 --
    # at N=4 / p=0.9 two independent draws genuinely coincide often).
    decisive = num_nodes >= 8 and p <= 0.7
    if decisive and period >= 2:
        # Different seeds must not alias onto the same draw sequence.
        assert (a.stacked_masks != c.stacked_masks).any()
    if decisive and period > 1:
        # Rounds are independent draws, not copies of round 0.
        assert (a.stacked_masks[0] != a.stacked_masks[1]).any()


def test_erdos_renyi_schedule_seed_and_round_independence_pinned():
    """Deterministic anchor for the contracts the property test can only
    assert on decisive configurations: a fixed (N, p, T) must differ
    across seeds and across rounds."""
    a = erdos_renyi_schedule(12, p=0.5, seed=0, period=3)
    c = erdos_renyi_schedule(12, p=0.5, seed=1, period=3)
    assert (a.stacked_masks != c.stacked_masks).any()
    assert (a.stacked_masks[0] != a.stacked_masks[1]).any()
    assert (a.stacked_masks[1] != a.stacked_masks[2]).any()


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(num_nodes=st.integers(3, 12), seed=st.integers(0, 2**16))
def test_cyclic_schedule_rotation(num_nodes, seed):
    ring = get_topology("ring", num_nodes)
    comp = get_topology("complete", num_nodes)
    sched = cyclic_schedule([ring, comp])
    assert sched.period == 2
    np.testing.assert_array_equal(np.asarray(sched.mask_at(0)),
                                  ring.neighbor_mask)
    np.testing.assert_array_equal(np.asarray(sched.mask_at(1)),
                                  comp.neighbor_mask)
    np.testing.assert_array_equal(np.asarray(sched.mask_at(2)),
                                  ring.neighbor_mask)
    # A cycle containing the complete graph contracts fully each period.
    np.testing.assert_allclose(sched.joint_spectral_gap(), 1.0, atol=1e-9)


def test_schedule_error_paths():
    with pytest.raises(ValueError, match="known"):
        get_schedule("wat", 8)
    with pytest.raises(ValueError, match="at least one"):
        cyclic_schedule([])
    with pytest.raises(ValueError, match="node"):
        cyclic_schedule([get_topology("ring", 4), get_topology("ring", 5)])
    with pytest.raises(ValueError, match="period"):
        erdos_renyi_schedule(8, period=0)
    with pytest.raises(TypeError, match="Topology or GraphSchedule"):
        as_schedule("ring")
    s = erdos_renyi_schedule(8, p=0.5, seed=0, period=3)
    with pytest.raises(ValueError, match="window"):
        s.is_connected_over_window(window=4)


def test_raw_erdos_renyi_draws_allowed_disconnected():
    """require_connected=False returns the FIRST draw even when it is
    disconnected -- the schedule relies on this to model lossy rounds."""
    t = graphs.erdos_renyi(24, p=0.02, seed=0, require_connected=False)
    assert not t.is_connected()
    _check_mixing(t.mixing, 24)
    _check_mask(t.neighbor_mask, 24)
    assert t.spectral_gap() == 0.0  # disconnected graphs report gap 0
