"""Data / optimizer / checkpoint substrate tests + repo-level invariants."""
import glob
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load, save
from repro.data import ijcnn1_like, covtype_like, mnist_like, partition, token_stream
from repro.optim import adam, apply_updates, cosine_schedule, get_optimizer, momentum, sgd


# ---------------- data ----------------

def test_dataset_shapes():
    d = ijcnn1_like(jax.random.PRNGKey(0), n=100)
    assert d.x.shape == (100, 22) and d.y.shape == (100,)
    assert set(np.unique(np.asarray(d.y))) <= {-1.0, 1.0}
    d2 = covtype_like(jax.random.PRNGKey(0), n=50)
    assert d2.x.shape == (50, 54)
    m = mnist_like(jax.random.PRNGKey(0), n=40)
    assert m.x.shape == (40, 784) and int(m.y.max()) <= 9


def test_partition_iid_disjoint():
    d = ijcnn1_like(jax.random.PRNGKey(0), n=120)
    wd = partition({"a": d.x, "b": d.y}, 4, seed=0)
    assert wd["a"].shape == (4, 30, 22)
    flat = np.asarray(wd["a"]).reshape(-1, 22)
    assert len(np.unique(flat, axis=0)) == 120  # disjoint samples


def test_partition_replicated():
    d = ijcnn1_like(jax.random.PRNGKey(0), n=60)
    wd = partition({"a": d.x}, 5, mode="replicated", samples_per_worker=20)
    a = np.asarray(wd["a"])
    assert a.shape == (5, 20, 22)
    for w in range(1, 5):
        np.testing.assert_array_equal(a[0], a[w])


def test_token_stream():
    b = token_stream(jax.random.PRNGKey(0), 2, 16, 100)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ---------------- optim ----------------

def _quad_loss(p):
    return 0.5 * jnp.sum((p["w"] - 3.0) ** 2)


@pytest.mark.parametrize("name,lr,steps", [("sgd", 0.3, 60), ("momentum", 0.1, 80),
                                           ("adam", 0.3, 120), ("adamw", 0.3, 200)])
def test_optimizers_converge_quadratic(name, lr, steps):
    opt = get_optimizer(name, lr)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    for i in range(steps):
        g = jax.grad(_quad_loss)(params)
        upd, state = opt.update(g, state, params, i)
        params = apply_updates(params, upd)
    tol = 0.4 if name == "adamw" else 0.05   # decoupled decay biases optimum
    assert float(jnp.max(jnp.abs(params["w"] - 3.0))) < tol


def test_cosine_schedule():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) <= 0.11


# ---------------- checkpoint ----------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.asarray(7, jnp.int32)}}
    p = os.path.join(tmp_path, "ck.npz")
    save(p, tree)
    got = load(p, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_manager_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    got = mgr.restore(4, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.zeros(2))


def test_checkpoint_missing_leaf_raises(tmp_path):
    p = os.path.join(tmp_path, "ck.npz")
    save(p, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        load(p, {"a": jnp.zeros(2), "b": jnp.zeros(3)})


# ---------------- repo invariants ----------------

def test_compat_layer_is_the_only_jax_version_gate():
    """Version-moving jax names must be touched only inside repro.compat
    (DESIGN.md Sec. 3): everything else goes through the compat surface so
    the repo keeps running on jax 0.4.x through current."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    banned = re.compile(
        r"jax\.shard_map|jax\.set_mesh|jax\.sharding\.AxisType"
        r"|from jax\.sharding import .*AxisType|jax\.experimental\.shard_map"
        r"|jax\.make_mesh|jax\.lax\.axis_size|jax\.profiler")
    offenders = []
    for sub in ("src", "tests", "examples", "benchmarks"):
        for path in glob.glob(os.path.join(repo, sub, "**", "*.py"), recursive=True):
            if os.sep + os.path.join("repro", "compat") + os.sep in path:
                continue
            with open(path) as f:
                for ln, line in enumerate(f, 1):
                    if banned.search(line):
                        offenders.append(f"{os.path.relpath(path, repo)}:{ln}: {line.strip()}")
    assert not offenders, "\n".join(offenders)
