"""Robust aggregation rules vs naive references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg

KEY = jax.random.PRNGKey(0)


def _tree(w=12):
    k1, k2 = jax.random.split(KEY)
    return {"x": jax.random.normal(k1, (w, 7)),
            "y": jax.random.normal(k2, (w, 3, 2))}


def test_mean():
    t = _tree()
    out = agg.mean_agg(t)
    np.testing.assert_allclose(np.asarray(out["x"]),
                               np.asarray(jnp.mean(t["x"], 0)), rtol=1e-6)


def test_median_odd_even():
    for w in (9, 10):
        t = _tree(w)
        out = agg.median_agg(t)
        np.testing.assert_allclose(np.asarray(out["x"]),
                                   np.median(np.asarray(t["x"]), axis=0), atol=1e-6)


def test_trimmed_mean():
    t = _tree(10)
    out = agg.trimmed_mean_agg(t, trim=2)
    ref = np.mean(np.sort(np.asarray(t["x"]), axis=0)[2:8], axis=0)
    np.testing.assert_allclose(np.asarray(out["x"]), ref, rtol=1e-5, atol=1e-6)


def test_trimmed_mean_rejects_overtrim():
    with pytest.raises(ValueError):
        agg.trimmed_mean_agg(_tree(4), trim=2)


def test_krum_selects_inlier():
    # 8 tight inliers + 3 far outliers; krum must return one of the inliers.
    k = jax.random.PRNGKey(1)
    inl = 0.01 * jax.random.normal(k, (8, 5))
    out = 100.0 + jnp.zeros((3, 5))
    t = {"x": jnp.concatenate([inl, out])}
    got = agg.krum_agg(t, num_byzantine=3)
    assert float(jnp.linalg.norm(got["x"])) < 1.0


def test_krum_returns_an_input_row():
    t = _tree(9)
    got = agg.krum_agg(t, num_byzantine=2)
    flat = np.asarray(t["x"])
    assert any(np.allclose(np.asarray(got["x"]), flat[i]) for i in range(9))


def test_geomed_groups_equals_geomed_of_means():
    t = _tree(12)
    got = agg.geomed_groups_agg(t, num_groups=4, max_iters=100, tol=1e-9)
    gm = jax.tree_util.tree_map(
        lambda z: jnp.mean(z.reshape((4, 3) + z.shape[1:]), axis=1), t)
    want = agg.geomed_agg(gm, max_iters=100, tol=1e-9)
    np.testing.assert_allclose(np.asarray(got["x"]), np.asarray(want["x"]), atol=1e-5)


def test_geomed_groups_uneven_w():
    t = _tree(11)   # 11 workers, 4 groups: sizes 3,3,3,2
    got = agg.geomed_groups_agg(t, num_groups=4, max_iters=50)
    assert got["x"].shape == (7,)
    assert bool(jnp.all(jnp.isfinite(got["x"])))


def test_registry_names():
    for name in agg.AGGREGATOR_NAMES:
        fn = agg.get_aggregator(name, num_groups=3, trim=1, num_byzantine=1)
        out = fn(_tree(9))
        assert out["x"].shape == (7,)
        assert bool(jnp.all(jnp.isfinite(out["x"])))


def test_centered_clip_robust_to_outliers():
    k = jax.random.PRNGKey(3)
    inl = jax.random.normal(k, (12, 6))
    out = 1e4 * jnp.ones((5, 6))
    t = {"x": jnp.concatenate([inl, out])}
    got = agg.centered_clip_agg(t, radius=2.0, iters=5)
    assert float(jnp.linalg.norm(got["x"] - jnp.mean(inl, 0))) < 3.0


def test_geomed_blockwise_per_leaf():
    t = _tree(10)
    got = agg.geomed_blockwise_agg(t, max_iters=100, tol=1e-9)
    # each leaf equals the leaf-local geomed
    want_x = agg.geomed_agg({"x": t["x"]}, max_iters=100, tol=1e-9)["x"]
    np.testing.assert_allclose(np.asarray(got["x"]), np.asarray(want_x), atol=1e-5)


def test_unknown_aggregator_error_lists_registry():
    with pytest.raises(ValueError) as ei:
        agg.get_aggregator("nope")
    # The error is derived from the registry, so every registered name is in
    # it and a new entry can never go stale.
    for name in agg.AGGREGATOR_NAMES:
        assert name in str(ei.value)
