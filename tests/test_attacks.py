"""Byzantine attack construction (paper Sec. V formulas)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks

KEY = jax.random.PRNGKey(0)


def _honest(wh=6, p=5):
    return {"g": jax.random.normal(KEY, (wh, p))}


def test_none_passthrough():
    h = _honest()
    cfg = attacks.AttackConfig(name="none", num_byzantine=3)
    out = attacks.apply_attack(cfg, h, KEY)
    assert out["g"].shape == (6, 5)


def test_sign_flip():
    h = _honest()
    cfg = attacks.AttackConfig(name="sign_flip", num_byzantine=2,
                               sign_flip_magnitude=-3.0)
    out = attacks.apply_attack(cfg, h, KEY)
    assert out["g"].shape == (8, 5)
    hm = np.asarray(jnp.mean(h["g"], 0))
    np.testing.assert_allclose(np.asarray(out["g"][6]), -3.0 * hm, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["g"][7]), -3.0 * hm, rtol=1e-5)


def test_zero_gradient_sums_to_zero():
    h = _honest()
    cfg = attacks.AttackConfig(name="zero_gradient", num_byzantine=3)
    out = attacks.apply_attack(cfg, h, KEY)
    np.testing.assert_allclose(np.asarray(jnp.sum(out["g"], 0)),
                               np.zeros(5), atol=1e-5)


def test_gaussian_statistics():
    h = {"g": jnp.zeros((50, 4))}
    cfg = attacks.AttackConfig(name="gaussian", num_byzantine=2000,
                               gaussian_variance=30.0)
    out = attacks.apply_attack(cfg, h, KEY)
    byz = np.asarray(out["g"][50:])
    assert abs(byz.mean()) < 0.5
    assert abs(byz.std() - np.sqrt(30.0)) < 0.5


def test_ipm_direction():
    h = _honest()
    cfg = attacks.AttackConfig(name="ipm", num_byzantine=1, ipm_eps=0.5)
    out = attacks.apply_attack(cfg, h, KEY)
    hm = np.asarray(jnp.mean(h["g"], 0))
    np.testing.assert_allclose(np.asarray(out["g"][6]), -0.5 * hm, rtol=1e-5)


def test_alie_within_cloud():
    h = _honest(wh=30)
    cfg = attacks.AttackConfig(name="alie", num_byzantine=2, alie_z=1.0)
    out = attacks.apply_attack(cfg, h, KEY)
    hm = np.asarray(jnp.mean(h["g"], 0))
    hs = np.asarray(jnp.std(h["g"], 0))
    np.testing.assert_allclose(np.asarray(out["g"][30]), hm + hs, rtol=1e-4)


def test_stacked_replaces_first_rows():
    w, b = 8, 3
    msgs = {"g": jax.random.normal(KEY, (w, 4))}
    cfg = attacks.AttackConfig(name="sign_flip", num_byzantine=b)
    out = attacks.apply_attack_stacked(cfg, msgs, KEY)
    assert out["g"].shape == (w, 4)
    # rows b.. unchanged (honest)
    np.testing.assert_allclose(np.asarray(out["g"][b:]), np.asarray(msgs["g"][b:]))
    hm = np.asarray(jnp.mean(msgs["g"][b:], 0))
    for i in range(b):
        np.testing.assert_allclose(np.asarray(out["g"][i]), -3.0 * hm, rtol=1e-5)


@pytest.mark.parametrize("name", ["sign_flip", "zero_gradient", "ipm", "alie"])
def test_stacked_matches_reference_attack(name):
    """The mask-select stacked variant must agree with the append-style
    reference ``apply_attack`` for every deterministic attack: honest rows
    untouched, Byzantine rows equal to the reference's appended rows."""
    w, b, p = 7, 2, 6
    msgs = {"g": jax.random.normal(KEY, (w, p)), "h": jax.random.normal(KEY, (w, 3, 2))}
    cfg = attacks.AttackConfig(name=name, num_byzantine=b)
    honest = jax.tree_util.tree_map(lambda z: z[b:], msgs)
    ref = attacks.apply_attack(cfg, honest, KEY)       # honest rows then B byz
    out = attacks.apply_attack_stacked(cfg, msgs, KEY)  # byz rows replace 0..B
    for k in msgs:
        np.testing.assert_allclose(np.asarray(out[k][b:]), np.asarray(msgs[k][b:]),
                                   rtol=1e-6, err_msg=f"{name} honest rows")
        for i in range(b):
            np.testing.assert_allclose(
                np.asarray(out[k][i]), np.asarray(ref[k][w - b + i]),
                rtol=1e-4, atol=1e-6, err_msg=f"{name} byz row {i}")


def test_stacked_gaussian_rows():
    """Gaussian draws differ by key handling between the two variants; check
    the structural contract instead: honest rows untouched, Byzantine rows
    finite and centered near the honest mean."""
    w, b, p = 50, 10, 4
    msgs = {"g": jax.random.normal(KEY, (w, p))}
    cfg = attacks.AttackConfig(name="gaussian", num_byzantine=b,
                               gaussian_variance=30.0)
    out = attacks.apply_attack_stacked(cfg, msgs, KEY)
    np.testing.assert_allclose(np.asarray(out["g"][b:]), np.asarray(msgs["g"][b:]))
    byz = np.asarray(out["g"][:b])
    assert np.isfinite(byz).all()
    hm = np.asarray(jnp.mean(msgs["g"][b:], 0))
    assert abs((byz - hm[None]).mean()) < 3.0  # mean-centered, sigma ~ 5.5


def test_unknown_attack_raises():
    with pytest.raises(ValueError, match="known"):
        attacks.apply_attack(
            attacks.AttackConfig(name="wat", num_byzantine=1), _honest(), KEY)
    with pytest.raises(ValueError, match="known"):
        attacks.apply_attack_stacked(
            attacks.AttackConfig(name="wat", num_byzantine=1),
            {"g": jnp.zeros((4, 2))}, KEY)


def test_attack_names_derive_from_registry():
    """_ATTACKS is the single source of truth: ATTACK_NAMES is exactly its
    key tuple (no hand-splicing), 'none' is a registered passthrough, and
    the unknown-name error enumerates the registry."""
    assert attacks.ATTACK_NAMES == tuple(attacks._ATTACKS)
    assert "none" in attacks._ATTACKS
    h = _honest()
    out = attacks.apply_attack(
        attacks.AttackConfig(name="none", num_byzantine=5), h, KEY)
    np.testing.assert_array_equal(np.asarray(out["g"]), np.asarray(h["g"]))
    with pytest.raises(ValueError) as e:
        attacks.apply_attack(
            attacks.AttackConfig(name="wat", num_byzantine=1), h, KEY)
    for name in attacks._ATTACKS:
        assert name in str(e.value)
