"""Byzantine attack construction (paper Sec. V formulas)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks

KEY = jax.random.PRNGKey(0)


def _honest(wh=6, p=5):
    return {"g": jax.random.normal(KEY, (wh, p))}


def test_none_passthrough():
    h = _honest()
    cfg = attacks.AttackConfig(name="none", num_byzantine=3)
    out = attacks.apply_attack(cfg, h, KEY)
    assert out["g"].shape == (6, 5)


def test_sign_flip():
    h = _honest()
    cfg = attacks.AttackConfig(name="sign_flip", num_byzantine=2,
                               sign_flip_magnitude=-3.0)
    out = attacks.apply_attack(cfg, h, KEY)
    assert out["g"].shape == (8, 5)
    hm = np.asarray(jnp.mean(h["g"], 0))
    np.testing.assert_allclose(np.asarray(out["g"][6]), -3.0 * hm, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["g"][7]), -3.0 * hm, rtol=1e-5)


def test_zero_gradient_sums_to_zero():
    h = _honest()
    cfg = attacks.AttackConfig(name="zero_gradient", num_byzantine=3)
    out = attacks.apply_attack(cfg, h, KEY)
    np.testing.assert_allclose(np.asarray(jnp.sum(out["g"], 0)),
                               np.zeros(5), atol=1e-5)


def test_gaussian_statistics():
    h = {"g": jnp.zeros((50, 4))}
    cfg = attacks.AttackConfig(name="gaussian", num_byzantine=2000,
                               gaussian_variance=30.0)
    out = attacks.apply_attack(cfg, h, KEY)
    byz = np.asarray(out["g"][50:])
    assert abs(byz.mean()) < 0.5
    assert abs(byz.std() - np.sqrt(30.0)) < 0.5


def test_ipm_direction():
    h = _honest()
    cfg = attacks.AttackConfig(name="ipm", num_byzantine=1, ipm_eps=0.5)
    out = attacks.apply_attack(cfg, h, KEY)
    hm = np.asarray(jnp.mean(h["g"], 0))
    np.testing.assert_allclose(np.asarray(out["g"][6]), -0.5 * hm, rtol=1e-5)


def test_alie_within_cloud():
    h = _honest(wh=30)
    cfg = attacks.AttackConfig(name="alie", num_byzantine=2, alie_z=1.0)
    out = attacks.apply_attack(cfg, h, KEY)
    hm = np.asarray(jnp.mean(h["g"], 0))
    hs = np.asarray(jnp.std(h["g"], 0))
    np.testing.assert_allclose(np.asarray(out["g"][30]), hm + hs, rtol=1e-4)


def test_stacked_replaces_first_rows():
    w, b = 8, 3
    msgs = {"g": jax.random.normal(KEY, (w, 4))}
    cfg = attacks.AttackConfig(name="sign_flip", num_byzantine=b)
    out = attacks.apply_attack_stacked(cfg, msgs, KEY)
    assert out["g"].shape == (w, 4)
    # rows b.. unchanged (honest)
    np.testing.assert_allclose(np.asarray(out["g"][b:]), np.asarray(msgs["g"][b:]))
    hm = np.asarray(jnp.mean(msgs["g"][b:], 0))
    for i in range(b):
        np.testing.assert_allclose(np.asarray(out["g"][i]), -3.0 * hm, rtol=1e-5)


def test_unknown_attack_raises():
    with pytest.raises(ValueError):
        attacks.apply_attack(
            attacks.AttackConfig(name="wat", num_byzantine=1), _honest(), KEY)
