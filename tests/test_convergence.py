"""Numerical validation of the paper's claims (Thms 1-2, Figs 3/5).

Scaled-down federation (W_h=12, B=5, J=80) on the l2-regularized logreg of
Sec. V-A; asserts *orderings and qualitative claims*, which is what the
theory predicts independent of dataset scale:

  C1 (Fig 3): under attacks, mean aggregation fails; geomed survives.
  C2 (Thm 1 vs 2): Byrd-SAGA's asymptotic gap < robust-SGD's under attack.
  C3 (linear rate): Byrd-SAGA's gap decays geometrically pre-plateau.
  C4 (Fig 5 / delta^2=0): with replicated data, Byrd-SAGA's error ~ 0
      while robust-SGD's stays sigma^2-limited.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_full_loss_and_opt, logreg_loss, partition
from repro.optim import get_optimizer

WH, B, STEPS = 12, 5, 700


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    data = ijcnn1_like(key, n=960)
    loss = logreg_loss(0.01)
    _, f_star = logreg_full_loss_and_opt(data, iters=4000, lr=0.5)
    batch = {"a": data.x, "b": data.y}
    wd = partition(batch, WH, seed=1)
    # delta^2 = 0 problem (paper Fig. 5): every worker holds the WHOLE
    # dataset, so the federated optimum equals f*.  Smaller n keeps the
    # SAGA table-refresh time (~J steps) within the test budget.
    data_rep = ijcnn1_like(jax.random.fold_in(key, 9), n=240)
    batch_rep = {"a": data_rep.x, "b": data_rep.y}
    _, f_star_rep = logreg_full_loss_and_opt(data_rep, iters=4000, lr=0.5)
    wd_rep = partition(batch_rep, WH, mode="replicated", seed=1)
    return loss, batch, f_star, wd, (wd_rep, batch_rep, f_star_rep)


def run(loss, wd, cfg, lr=0.02, steps=STEPS, track=False):
    opt = get_optimizer("sgd", lr)
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    p = jax.tree_util.tree_leaves(wd)[0].shape[-1]
    st = init_fn({"w": jnp.zeros((p,), jnp.float32)}, jax.random.PRNGKey(7))
    jstep = jax.jit(step_fn)
    gaps = []
    for i in range(steps):
        st, _ = jstep(st)
        if track and i % 50 == 0:
            gaps.append(st.params)
    return st.params, gaps


def gap(loss, batch, f_star, params):
    return float(loss(params, batch)) - f_star


@pytest.mark.parametrize("attack", ["gaussian", "sign_flip", "zero_gradient"])
def test_c1_mean_fails_geomed_survives(problem, attack):
    loss, batch, f_star, wd, _ = problem
    g_mean = gap(loss, batch, f_star, run(
        loss, wd, RobustConfig(aggregator="mean", vr="saga", attack=attack,
                               num_byzantine=B))[0])
    g_geo = gap(loss, batch, f_star, run(
        loss, wd, RobustConfig(aggregator="geomed", vr="saga", attack=attack,
                               num_byzantine=B))[0])
    assert g_geo < 0.1, f"Byrd-SAGA failed under {attack}: gap {g_geo}"
    assert g_mean > 3 * g_geo, f"mean unexpectedly robust under {attack}: {g_mean} vs {g_geo}"


def test_c2_saga_beats_sgd_under_attack(problem):
    loss, batch, f_star, wd, _ = problem
    g_saga = gap(loss, batch, f_star, run(
        loss, wd, RobustConfig(aggregator="geomed", vr="saga",
                               attack="sign_flip", num_byzantine=B))[0])
    g_sgd = gap(loss, batch, f_star, run(
        loss, wd, RobustConfig(aggregator="geomed", vr="sgd",
                               attack="sign_flip", num_byzantine=B))[0])
    assert g_saga < g_sgd, (g_saga, g_sgd)
    assert g_saga < 0.5 * g_sgd, f"variance reduction gain too small: {g_saga} vs {g_sgd}"


def test_c3_linear_convergence_attack_free(problem):
    loss, batch, f_star, wd, _ = problem
    cfg = RobustConfig(aggregator="geomed", vr="saga", attack="none",
                       num_byzantine=0)
    opt = get_optimizer("sgd", 0.02)
    init_fn, step_fn = make_federated_step(loss, wd, cfg, opt)
    st = init_fn({"w": jnp.zeros((22,), jnp.float32)}, jax.random.PRNGKey(7))
    jstep = jax.jit(step_fn)
    gaps = []
    for i in range(600):
        st, _ = jstep(st)
        if (i + 1) % 150 == 0:
            gaps.append(gap(loss, batch, f_star, st.params))
    # Geometric decay: each 150-step window shrinks the gap notably until
    # the noise floor.
    assert gaps[1] < 0.7 * gaps[0] or gaps[0] < 1e-3
    assert gaps[-1] < 0.05


def test_c4_zero_outer_variation(problem):
    """delta^2 = 0 (every worker holds the same data): Thm 1 predicts
    Byrd-SAGA's asymptotic error -> 0; Thm 2 leaves robust-SGD
    sigma^2-limited."""
    loss, _, _, _, (wd_rep, batch_rep, f_star_rep) = problem
    g_saga = gap(loss, batch_rep, f_star_rep, run(
        loss, wd_rep, RobustConfig(aggregator="geomed", vr="saga",
                                   attack="sign_flip", num_byzantine=B),
        lr=0.02, steps=900)[0])
    g_sgd = gap(loss, batch_rep, f_star_rep, run(
        loss, wd_rep, RobustConfig(aggregator="geomed", vr="sgd",
                                   attack="sign_flip", num_byzantine=B),
        lr=0.02, steps=900)[0])
    assert g_saga < 0.02, f"Byrd-SAGA should reach ~0 gap when delta=0, got {g_saga}"
    assert g_sgd > 2 * g_saga


def test_krum_and_median_also_robust(problem):
    loss, batch, f_star, wd, _ = problem
    for aggname in ("krum", "median", "trimmed_mean"):
        g = gap(loss, batch, f_star, run(
            loss, wd, RobustConfig(aggregator=aggname, vr="saga",
                                   attack="sign_flip", num_byzantine=B,
                                   num_groups=4, trim=B))[0])
        assert g < 0.2, f"{aggname} failed: {g}"


@pytest.mark.slow
@pytest.mark.parametrize("attack", ["sign_flip", "gaussian"])
def test_lsvrg_matches_saga_floor_and_beats_sgd(problem, attack):
    """ISSUE 6 tier-2 gate: loopless SVRG keeps the paper's qualitative
    claims with O(D) client state.  Under attack, lsvrg + geomed reaches an
    error floor within 2x of Byrd-SAGA's (both methods have vanishing
    gradient variance, Lemma 1) and clearly beats non-reduced robust SGD
    (which stays sigma^2-limited, Thm 2).  Snapshot probability ~ 1/J so
    the expected full-gradient work matches SAGA's table refresh cadence."""
    loss, batch, f_star, wd, _ = problem
    gaps = {}
    for vr in ("saga", "lsvrg", "sgd"):
        gaps[vr] = gap(loss, batch, f_star, run(
            loss, wd, RobustConfig(aggregator="geomed", vr=vr, attack=attack,
                                   num_byzantine=B, lsvrg_p=1 / 80))[0])
    assert gaps["lsvrg"] < 0.1, f"lsvrg failed under {attack}: {gaps}"
    assert gaps["lsvrg"] < 2 * max(gaps["saga"], 0.03), gaps
    # The sgd separation is starkest under sign_flip (cf. test_c2, which
    # pins the saga-vs-sgd claim there for the same reason); under gaussian
    # geomed filters the attack so well that BOTH floors are tiny and only
    # the sigma^2 ordering remains.
    factor = 0.5 if attack == "sign_flip" else 0.75
    assert gaps["lsvrg"] < factor * gaps["sgd"], (attack, gaps)


@pytest.mark.slow
def test_quantized_wire_keeps_convergence_floor(problem):
    """ISSUE 9 tier-2 gate (DESIGN.md Sec. 12): quantized wire formats
    keep Byrd-SAGA's error floor under sign_flip.  int8's per-block
    symmetric scales perturb each coordinate by at most amax/254, leaving
    the floor within 2x of full-precision; sign1 re-sends its much larger
    quantization error through the per-client error-feedback residual, so
    it still converges to a floor within 4x rather than stalling at the
    compressor's bias."""
    loss, batch, f_star, wd, _ = problem
    gaps = {}
    for dtype in ("float32", "int8", "sign1"):
        gaps[dtype] = gap(loss, batch, f_star, run(
            loss, wd, RobustConfig(aggregator="geomed", vr="saga",
                                   attack="sign_flip", num_byzantine=B,
                                   message_dtype=dtype))[0])
    assert gaps["int8"] < 2 * max(gaps["float32"], 0.03), gaps
    assert gaps["sign1"] < 4 * max(gaps["float32"], 0.03), gaps
    assert gaps["sign1"] < 0.2, f"sign1+EF failed outright: {gaps}"


def test_geomed_groups_low_byzantine(problem):
    """geomed_groups trades breakdown point for variance reduction: with G
    groups it tolerates < G/2 poisoned groups, so test it in its design
    regime (B=1 < G/2=2), where it converges like plain geomed."""
    loss, batch, f_star, wd, _ = problem
    g = gap(loss, batch, f_star, run(
        loss, wd, RobustConfig(aggregator="geomed_groups", vr="saga",
                               attack="sign_flip", num_byzantine=1,
                               num_groups=4))[0])
    assert g < 0.2, f"geomed_groups failed in-regime: {g}"


@pytest.mark.slow
def test_sampled_cohort_matches_full_participation_floor(problem):
    """ISSUE 7 tier-2 gate (DESIGN.md Sec. 10): client-scale
    virtualization keeps the paper's convergence story.  24 virtual
    clients feeding the 12-slot cohort under sign_flip reach an error
    floor within 2x of full participation's (each client's SAGA rows just
    refresh at half the cadence, so the variance still vanishes), and a
    dropout-only run -- Byzantine slots masked to weight exactly 0 --
    converges outright."""
    loss, batch, f_star, wd, _ = problem
    wd24 = partition(batch, 2 * WH, seed=1)
    g_full = gap(loss, batch, f_star, run(
        loss, wd, RobustConfig(aggregator="geomed", vr="saga",
                               attack="sign_flip", num_byzantine=B))[0])
    g_sampled = gap(loss, batch, f_star, run(
        loss, wd24, RobustConfig(aggregator="geomed", vr="saga",
                                 attack="sign_flip", num_byzantine=B,
                                 num_clients=2 * WH, cohort_size=WH),
        steps=2 * STEPS)[0])
    assert g_sampled < 0.1, f"sampled cohort failed under sign_flip: {g_sampled}"
    assert g_sampled < 2 * max(g_full, 0.03), (g_sampled, g_full)
    g_drop = gap(loss, batch, f_star, run(
        loss, wd24, RobustConfig(aggregator="geomed", vr="saga",
                                 attack="dropout", num_byzantine=B,
                                 num_clients=2 * WH, cohort_size=WH),
        steps=2 * STEPS)[0])
    assert g_drop < 0.1, f"dropout-only sampled run failed: {g_drop}"
