"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant (<=2 periods, d_model<=256, <=4 experts), runs one forward/
train step and one prefill+decode step on CPU; output shapes + finiteness
asserted.  The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model, input_specs

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _batch(cfg):
    specs = input_specs(cfg, SHAPE)
    batch = {}
    key = jax.random.PRNGKey(0)
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(key, v.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            batch[k] = 0.02 * jax.random.normal(key, v.shape, jnp.float32).astype(v.dtype)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            model = build_model(cfg, remat=False, q_chunk=16, kv_chunk=16,
                                loss_chunk=16)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step(built, name):
    cfg, model, params = built(name)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name} loss not finite"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in leaves), \
        f"{name} grads not finite"
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in leaves)
    assert total > 0, f"{name} zero gradients"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode(built, name):
    cfg, model, params = built(name)
    batch = _batch(cfg)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    pb["tokens"] = pb["tokens"][:, :8]
    logits, cache = model.prefill(params, pb)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name} prefill logits not finite"
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, jnp.asarray(7, jnp.int32))
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{name} decode logits not finite"
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_config_bounds(name):
    cfg = get_config(name).reduced()
    pat, periods = cfg.resolve_pattern()
    assert periods <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


def test_full_configs_match_assignment():
    """Pin the exact assigned hyperparameters."""
    c = get_config("mamba2-130m")
    assert (c.num_layers, c.d_model, c.vocab_size, c.ssm_state) == (24, 768, 50280, 128)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (24, 2048, 16, 16)
    assert (c.num_experts, c.top_k, c.num_shared_experts, c.moe_d_ff) == (60, 4, 4, 1408)
    assert c.vocab_size == 151936
    c = get_config("qwen2-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff) == (28, 3584, 28, 4, 18944)
    assert c.qkv_bias and c.vocab_size == 152064
    c = get_config("nemotron-4-340b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff) == (96, 18432, 96, 8, 73728)
    assert c.activation == "squared_relu" and c.vocab_size == 256000
    c = get_config("whisper-tiny")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == (4, 384, 6, 1536, 51865)
    assert c.encoder_seq == 1500
    c = get_config("mixtral-8x22b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (56, 6144, 48, 8)
    assert (c.num_experts, c.top_k, c.moe_d_ff, c.vocab_size) == (8, 2, 16384, 32768)
    assert c.sliding_window is not None
    c = get_config("jamba-v0.1-52b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff) == (32, 4096, 32, 8, 14336)
    assert (c.num_experts, c.top_k, c.vocab_size) == (16, 2, 65536)
    pat, _ = c.resolve_pattern()
    assert sum(1 for b in pat if b.kind == "attn") == 1 and len(pat) == 8
    c = get_config("mistral-large-123b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    c = get_config("command-r-plus-104b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (64, 12288, 96, 8, 33792, 256000)
    assert not c.qkv_bias
    c = get_config("paligemma-3b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (18, 2048, 8, 1, 16384, 257216)
    assert c.num_prefix_tokens == 256
