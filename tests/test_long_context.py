"""Sequence-sharded (long_500k-style) decode attention correctness:
the LSE-combined shard_map path must match the plain cached attention.
Subprocess inline programs go through repro.compat (see mesh_harness)."""
from mesh_harness import run_py


def test_sharded_decode_attention_matches_dense():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
        from repro.models.attention import decode_attention, attn_params
        from repro.models.common import init_maker

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        B, S, H, KV, hd, D = 1, 64, 4, 2, 16, 32
        params = attn_params(init_maker(jax.random.PRNGKey(0)), "a",
                             d_model=D, num_heads=H, num_kv_heads=KV,
                             head_dim=hd, qkv_bias=False)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, D))
        cache = {
            "k": jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd)),
            "v": jax.random.normal(jax.random.PRNGKey(3), (B, S, KV, hd)),
        }
        pos = jnp.asarray(S - 1, jnp.int32)
        kw = dict(num_heads=H, num_kv_heads=KV, head_dim=hd, rope_theta=1e4)

        # dense reference
        out_ref, cache_ref = decode_attention(params, x, cache, pos, **kw)

        # sequence-sharded path under jit with the cache sharded over 'data'
        kv_sh = NamedSharding(mesh, P(None, "data", None, None))
        cache_sh = jax.tree_util.tree_map(lambda c: jax.device_put(c, kv_sh), cache)
        with compat.use_mesh(mesh):
            out_s, cache_s = jax.jit(
                lambda p, xx, cc, pp: decode_attention(
                    p, xx, cc, pp, seq_shard_axis="data", **kw)
            )(params, x, cache_sh, pos)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(cache_s["k"]), np.asarray(cache_ref["k"]),
                                   rtol=1e-5, atol=1e-5)
        # windowed variant
        out_w, _ = decode_attention(params, x, cache, pos, window=16, **kw)
        with compat.use_mesh(mesh):
            out_ws, _ = jax.jit(
                lambda p, xx, cc, pp: decode_attention(
                    p, xx, cc, pp, seq_shard_axis="data", window=16, **kw)
            )(params, x, cache_sh, pos)
        np.testing.assert_allclose(np.asarray(out_ws), np.asarray(out_w),
                                   rtol=2e-4, atol=2e-4)
        print("SHARDED_DECODE_OK")
    """)
    assert "SHARDED_DECODE_OK" in out


def test_whisper_decode_matches_forward():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models import encdec, transformer as tfm

        cfg = get_config("whisper-tiny").reduced()
        model = build_model(cfg, remat=False, q_chunk=8, kv_chunk=8)
        params = model.init(jax.random.PRNGKey(0))
        b, t = 2, 10
        toks = jax.random.randint(jax.random.PRNGKey(5), (b, t + 1), 0, cfg.vocab_size)
        audio = 0.05 * jax.random.normal(jax.random.PRNGKey(6),
                                         (b, cfg.encoder_seq, cfg.d_model))
        logits_p, cache = model.prefill(params, {"tokens": toks[:, :t], "audio_emb": audio})
        cache = {pk: {k: (jnp.pad(v, ((0,0),(0,0),(0,1),(0,0),(0,0)))
                          if k in ("k", "v") else v) for k, v in sub.items()}
                 for pk, sub in cache.items()}
        logits_d, _ = model.decode_step(params, cache, toks[:, t:t+1],
                                        jnp.asarray(t, jnp.int32))
        enc = encdec.encode(params, cfg, audio, remat=False, q_chunk=8, kv_chunk=8)
        dcfg = encdec._decoder_cfg(cfg)
        h, _ = tfm.forward_hidden(params["decoder"], dcfg, toks, enc_out=enc,
                                  remat=False, q_chunk=8, kv_chunk=8)
        lf = h[:, -1].astype(jnp.float32) @ tfm._unembed(params["decoder"], dcfg).astype(jnp.float32).T
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(lf),
                                   rtol=2e-3, atol=2e-3)
        print("WHISPER_OK")
    """, devices=1)
    assert "WHISPER_OK" in out
