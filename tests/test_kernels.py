"""Per-kernel allclose sweeps vs the ref.py oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # keep the suite collectable without the dev extra
    from _hypothesis_fallback import hypothesis, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)

SHAPES = [(8, 512), (16, 1024), (50, 768), (7, 300), (33, 4096), (2, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("w,p", SHAPES)
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_weiszfeld_step(w, p, dt):
    z = jax.random.normal(KEY, (w, p)).astype(dt)
    y = jnp.mean(z.astype(jnp.float32), axis=0)
    got = np.asarray(ops.weiszfeld_step(z, y)).astype(np.float32)
    want = np.asarray(ref.weiszfeld_step(z, y)).astype(np.float32)
    np.testing.assert_allclose(got, want, **_tol(dt))


@pytest.mark.parametrize("w,p", SHAPES[:4])
@pytest.mark.parametrize("l", [1, 3, 7])
def test_partial_sqdist_segments(w, p, l):
    z = jax.random.normal(KEY, (w, p))
    y = jnp.mean(z, axis=0)
    # Uneven contiguous blocks, like flattened pytree leaves.
    bounds = np.linspace(0, p, l + 1).astype(int)
    seg = jnp.asarray(np.repeat(np.arange(l), np.diff(bounds)).astype(np.int32))
    got = np.asarray(ops.partial_sqdist_segments(z, y, seg, num_segments=l))
    want = np.asarray(ref.partial_sqdist_segments(z, y, seg, l))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    # The blocks partition the coordinates: rows sum to the full sqdist.
    np.testing.assert_allclose(got.sum(axis=1), np.asarray(ref.partial_sqdist(z, y)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("r,s,d", [(8, 8, 512), (5, 9, 300), (12, 12, 1024),
                                   (3, 16, 129)])
@pytest.mark.parametrize("trim", [0, 1, 2])
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_masked_neighbor_reduce(r, s, d, trim, dt):
    """Fused masked (trimmed) neighborhood reduction vs the sort-based
    oracle: random masks with guaranteed-feasible neighborhood sizes."""
    e = jax.random.normal(KEY, (r, s, d)).astype(dt)
    mask = (jax.random.uniform(jax.random.PRNGKey(1), (r, s)) > 0.3).astype(
        jnp.float32)
    mask = jnp.maximum(mask, jnp.eye(r, s, dtype=jnp.float32))
    if int(jnp.min(jnp.sum(mask, axis=1))) <= 2 * trim:
        pytest.skip("neighborhood smaller than trim budget")
    got = np.asarray(ops.masked_neighbor_reduce(e, mask, trim=trim))
    want = np.asarray(ref.masked_neighbor_reduce(e, mask, trim))
    np.testing.assert_allclose(got, want, **_tol(dt))


def test_masked_neighbor_reduce_ring_mask_matches_masked_mean():
    """trim=0 on a real topology mask equals the jnp masked mean the
    decentralized step uses (repro.topology.masked)."""
    from repro.topology import graphs, masked
    topo = graphs.ring(8)
    mask = jnp.asarray(topo.neighbor_mask)
    e = jax.random.normal(KEY, (8, 8, 640))
    got = np.asarray(ops.masked_neighbor_reduce(e, mask, trim=0))
    want = np.asarray(masked.masked_mean({"g": e}, mask)["g"])
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("w,p", SHAPES[:4])
def test_geomed_kernel(w, p):
    z = jax.random.normal(KEY, (w, p))
    got = np.asarray(ops.geomed(z, iters=25))
    want = np.asarray(ref.geomed(z, iters=25))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("w,p", SHAPES)
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_coordinate_median(w, p, dt):
    z = jax.random.normal(KEY, (w, p)).astype(dt)
    got = np.asarray(ops.coordinate_median(z)).astype(np.float32)
    want = np.asarray(ref.coordinate_median(z)).astype(np.float32)
    np.testing.assert_allclose(got, want, **_tol(dt))


@pytest.mark.parametrize("w,p", [(9, 512), (16, 700), (50, 2048)])
@pytest.mark.parametrize("trim", [1, 3])
def test_trimmed_mean(w, p, trim):
    z = jax.random.normal(KEY, (w, p))
    got = np.asarray(ops.trimmed_mean(z, trim=trim))
    want = np.asarray(ref.trimmed_mean(z, trim))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("j,p", [(4, 512), (10, 777), (32, 2048), (2, 100)])
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_saga_correct(j, p, dt):
    ks = jax.random.split(KEY, 3)
    grad = jax.random.normal(ks[0], (p,)).astype(dt)
    table = jax.random.normal(ks[1], (j, p)).astype(dt)
    avg = jnp.mean(table.astype(jnp.float32), axis=0).astype(dt)
    for idx in (0, j // 2, j - 1):
        got = ops.saga_correct(grad, table, avg, jnp.asarray(idx, jnp.int32))
        want = ref.saga_correct(grad, table, avg, jnp.asarray(idx))
        for g, w_ in zip(got, want):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w_, np.float32), **_tol(dt))


@pytest.mark.parametrize("b,s,h,kv,hd,causal,qb,kb", [
    (2, 64, 4, 2, 16, True, 16, 16),
    (1, 100, 2, 2, 32, True, 32, 16),    # ragged S vs blocks
    (2, 37, 4, 4, 8, False, 8, 8),       # bidirectional
    (1, 192, 2, 1, 64, True, 128, 64),   # MQA
])
def test_flash_attention(b, s, h, kv, hd, causal, qb, kb):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    got = ops.flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=kb)
    rep = h // kv
    kk, vv = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
    tb = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    want = ref.flash_attention(tb(q), tb(kk), tb(vv), causal).reshape(
        b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_flash_attention_dtypes(dt):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 48, 2, 16)).astype(dt)
    k = jax.random.normal(ks[1], (1, 48, 2, 16)).astype(dt)
    v = jax.random.normal(ks[2], (1, 48, 2, 16)).astype(dt)
    got = ops.flash_attention(q, k, v, q_block=16, kv_block=16)
    assert got.dtype == dt
    tb = lambda x: x.transpose(0, 2, 1, 3).reshape(2, 48, 16)
    want = ref.flash_attention(tb(q), tb(k), tb(v), True).reshape(
        1, 2, 48, 16).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@hypothesis.given(
    w=st.integers(2, 40), p=st.integers(1, 600), seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_weiszfeld_step_property(w, p, seed):
    z = jax.random.normal(jax.random.PRNGKey(seed), (w, p))
    y = jnp.mean(z, axis=0)
    got = np.asarray(ops.weiszfeld_step(z, y))
    want = np.asarray(ref.weiszfeld_step(z, y))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@hypothesis.given(
    j=st.integers(2, 16), p=st.integers(1, 400),
    idx_frac=st.floats(0, 0.999), seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_saga_property(j, p, idx_frac, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    grad = jax.random.normal(ks[0], (p,))
    table = jax.random.normal(ks[1], (j, p))
    avg = jnp.mean(table, axis=0)
    idx = jnp.asarray(int(idx_frac * j), jnp.int32)
    got = ops.saga_correct(grad, table, avg, idx)
    want = ref.saga_correct(grad, table, avg, idx)
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("j,p", [(4, 512), (7, 300)])
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_saga_kernel_vs_core_scatter_cross_check(j, p, dt):
    """ops.saga_correct (fused Pallas) against core/saga.saga_correct_scatter
    (the production scatter path) DIRECTLY -- both are verified against
    ref.py elsewhere, but nothing pinned them against each other.  Includes
    the aliased table-row contract: msg/new_avg must read the OLD row of
    the very row the update overwrites, the overwritten row must be the
    fresh gradient bit-exactly, and every other row must be untouched."""
    from repro.core import saga as core_saga
    ks = jax.random.split(KEY, 3)
    grad = jax.random.normal(ks[0], (p,)).astype(dt)
    table = jax.random.normal(ks[1], (j, p)).astype(dt)
    avg = jnp.mean(table.astype(jnp.float32), axis=0).astype(dt)
    tol = _tol(dt)
    for idx in (0, j // 2, j - 1):
        k_msg, k_avg, k_tab = ops.saga_correct(grad, table, avg,
                                               jnp.asarray(idx, jnp.int32))
        st = core_saga.SagaState(table={"p": table[None]}, avg={"p": avg[None]})
        msgs, new_st = core_saga.saga_correct_scatter(
            st, {"p": grad[None]}, jnp.asarray([idx], jnp.int32))
        np.testing.assert_allclose(np.asarray(k_msg, np.float32),
                                   np.asarray(msgs["p"][0], np.float32),
                                   **tol, err_msg=f"msg idx={idx}")
        np.testing.assert_allclose(np.asarray(k_avg, np.float32),
                                   np.asarray(new_st.avg["p"][0], np.float32),
                                   **tol, err_msg=f"avg idx={idx}")
        # Table updates agree BITWISE between the two implementations: the
        # overwritten row is the cast gradient, the rest pass through.
        np.testing.assert_array_equal(
            np.asarray(k_tab, np.float32),
            np.asarray(new_st.table["p"][0], np.float32),
            err_msg=f"table idx={idx}")
        np.testing.assert_array_equal(np.asarray(k_tab[idx], np.float32),
                                      np.asarray(grad.astype(dt), np.float32))
        keep = [r for r in range(j) if r != idx]
        np.testing.assert_array_equal(
            np.asarray(k_tab, np.float32)[keep],
            np.asarray(table, np.float32)[keep])
        # Aliasing: the message must be built from the OLD row (g - old +
        # avg), not the row the kernel just overwrote (g - g + avg = avg).
        old_based = (grad.astype(jnp.float32)
                     - table[idx].astype(jnp.float32)
                     + avg.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(k_msg, np.float32),
                                   np.asarray(old_based), **tol)
        assert not np.allclose(np.asarray(k_msg, np.float32),
                               np.asarray(avg, np.float32), atol=1e-2)


@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_saga_kernel_vs_core_scatter_multiworker(dt):
    """Stacked-worker agreement: vmapping the fused kernel over W workers
    (each drawing its own table row) matches one saga_correct_scatter call
    on the (W, J, p) state."""
    from repro.core import saga as core_saga
    w, j, p = 3, 5, 256
    ks = jax.random.split(KEY, 3)
    grads = jax.random.normal(ks[0], (w, p)).astype(dt)
    tables = jax.random.normal(ks[1], (w, j, p)).astype(dt)
    avgs = jnp.mean(tables.astype(jnp.float32), axis=1).astype(dt)
    idx = jnp.asarray([0, 3, 4], jnp.int32)
    k_msg, k_avg, k_tab = jax.vmap(
        lambda g, t, a, i: ops.saga_correct(g, t, a, i))(grads, tables,
                                                         avgs, idx)
    st = core_saga.SagaState(table={"p": tables}, avg={"p": avgs})
    msgs, new_st = core_saga.saga_correct_scatter(st, {"p": grads}, idx)
    tol = _tol(dt)
    np.testing.assert_allclose(np.asarray(k_msg, np.float32),
                               np.asarray(msgs["p"], np.float32), **tol)
    np.testing.assert_allclose(np.asarray(k_avg, np.float32),
                               np.asarray(new_st.avg["p"], np.float32), **tol)
    np.testing.assert_array_equal(np.asarray(k_tab, np.float32),
                                  np.asarray(new_st.table["p"], np.float32))
