"""Weiszfeld geometric-median unit + property tests (paper eq. (6), Lemma 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:  # keep the suite collectable without the dev extra
    from _hypothesis_fallback import hnp, hypothesis, st

from repro.core.geomed import geomed_objective, weiszfeld, weiszfeld_pytree

jax.config.update("jax_platform_name", "cpu")


def test_collinear_median():
    # For points on a line, the geometric median is the 1-D median.
    pts = jnp.array([[0.0], [1.0], [10.0]])
    y = weiszfeld(pts, max_iters=200, tol=1e-10)
    assert abs(float(y[0]) - 1.0) < 1e-3


def test_symmetric_center():
    pts = jnp.array([[1.0, 0.0], [-1.0, 0.0], [0.0, 1.0], [0.0, -1.0]])
    y = weiszfeld(pts, max_iters=100)
    np.testing.assert_allclose(np.asarray(y), [0.0, 0.0], atol=1e-5)


def test_objective_beats_mean():
    key = jax.random.PRNGKey(0)
    pts = jax.random.normal(key, (20, 5)) ** 3  # skewed
    y = weiszfeld(pts, max_iters=200, tol=1e-9)
    assert float(geomed_objective(pts, y)) <= float(
        geomed_objective(pts, jnp.mean(pts, axis=0))) + 1e-5


def test_epsilon_stationarity():
    """At the geomed, the sum of unit residual vectors ~ 0 (first-order
    optimality of eq. (6))."""
    key = jax.random.PRNGKey(1)
    pts = jax.random.normal(key, (15, 8))
    y = weiszfeld(pts, max_iters=500, tol=1e-12)
    r = pts - y[None]
    units = r / jnp.linalg.norm(r, axis=1, keepdims=True)
    assert float(jnp.linalg.norm(jnp.sum(units, axis=0))) < 1e-2


def test_breakdown_under_half():
    """With B < W/2 arbitrarily-far outliers the median stays near the
    inliers (robustness behind Lemma 1); the mean does not."""
    key = jax.random.PRNGKey(2)
    inliers = jax.random.normal(key, (11, 4))
    outliers = 1e6 * jnp.ones((5, 4))
    pts = jnp.concatenate([inliers, outliers])
    y = weiszfeld(pts, max_iters=300, tol=1e-9)
    assert float(jnp.linalg.norm(y - jnp.mean(inliers, axis=0))) < 5.0
    assert float(jnp.linalg.norm(jnp.mean(pts, axis=0))) > 1e5


@hypothesis.given(
    pts=hnp.arrays(np.float32, (9, 6),
                   elements=st.floats(-100, 100, width=32)),
    shift=hnp.arrays(np.float32, (6,),
                     elements=st.floats(-50, 50, width=32)),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_translation_equivariance(pts, shift):
    hypothesis.assume(np.std(pts) > 1e-3)
    y1 = np.asarray(weiszfeld(jnp.asarray(pts), max_iters=80))
    y2 = np.asarray(weiszfeld(jnp.asarray(pts + shift), max_iters=80))
    np.testing.assert_allclose(y1 + shift, y2, atol=2e-2)


@hypothesis.given(
    pts=hnp.arrays(np.float32, (8, 5), elements=st.floats(-10, 10, width=32)),
    perm_seed=st.integers(0, 1000),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_permutation_invariance(pts, perm_seed):
    hypothesis.assume(np.std(pts) > 1e-3)
    rng = np.random.default_rng(perm_seed)
    perm = rng.permutation(pts.shape[0])
    y1 = np.asarray(weiszfeld(jnp.asarray(pts), max_iters=100))
    y2 = np.asarray(weiszfeld(jnp.asarray(pts[perm]), max_iters=100))
    np.testing.assert_allclose(y1, y2, atol=1e-4)


def test_pytree_matches_flat():
    key = jax.random.PRNGKey(3)
    z = jax.random.normal(key, (10, 12))
    tree = {"a": z[:, :5], "b": z[:, 5:].reshape(10, 7, 1)}
    yt = weiszfeld_pytree(tree, max_iters=100, tol=1e-9)
    yf = weiszfeld(z, max_iters=100, tol=1e-9)
    np.testing.assert_allclose(np.asarray(yt["a"]), np.asarray(yf[:5]), rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yt["b"]).reshape(7), np.asarray(yf[5:]), rtol=2e-5, atol=1e-5)


def test_jit_and_grad_safe():
    pts = jax.random.normal(jax.random.PRNGKey(4), (6, 3))
    y = jax.jit(lambda p: weiszfeld(p, max_iters=50))(pts)
    assert y.shape == (3,)
    assert bool(jnp.all(jnp.isfinite(y)))
