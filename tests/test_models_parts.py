"""Model-component correctness: SSD vs recurrence, decode-vs-forward
consistency, chunked attention vs naive, chunked xent vs naive, MoE/rope."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import BlockSpec, ModelConfig
from repro.models import build_model
from repro.models.attention import _flash, _sliding
from repro.models.common import chunked_xent
from repro.models.mamba2 import _ssd_chunked, ssd_reference
from repro.models.transformer import forward_hidden, _unembed

KEY = jax.random.PRNGKey(0)


def test_ssd_chunked_matches_recurrence():
    B, L, H, P, N = 2, 47, 3, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    b = jax.random.normal(ks[1], (B, L, N))
    c = jax.random.normal(ks[2], (B, L, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[4], (H,)))
    yr, hr = ssd_reference(x, b, c, dt, a)
    for chunk in (8, 16, 64):
        y, h = _ssd_chunked(x, b, c, dt, a, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-3, atol=1e-4)


def _naive_attn(q, k, v, causal=True, window=None):
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, d)
    sc = jnp.einsum("bqgrd,bkgd->bqgrk", qg, k) / jnp.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool)) if causal else jnp.ones((s, s), bool)
    if window is not None:
        idx = jnp.arange(s)
        mask &= (idx[None, :] > idx[:, None] - window)
    sc = jnp.where(mask[None, :, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqgrk,bkgd->bqgrd", p, v).reshape(b, s, h, d)


@pytest.mark.parametrize("s,qc,kc", [(37, 8, 8), (64, 16, 32), (16, 64, 64)])
def test_flash_matches_naive(s, qc, kc):
    b, h, kv, d = 2, 4, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    got = _flash(q, k, v, causal=True, prefix_len=0, q_chunk=qc, kv_chunk=kc)
    want = _naive_attn(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_flash_prefix_lm():
    b, s, h, d, pfx = 1, 24, 2, 8, 7
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    got = _flash(q, k, v, causal=True, prefix_len=pfx, q_chunk=8, kv_chunk=8)
    # naive with prefix: position j visible to i if j<=i or j<prefix
    sc = jnp.einsum("bqhd,bkhd->bqhk", q, k) / jnp.sqrt(d)
    idx = jnp.arange(s)
    mask = (idx[None, :] <= idx[:, None]) | (idx[None, :] < pfx)
    sc = jnp.where(mask[None, :, None, :], sc, -1e30)
    want = jnp.einsum("bqhk,bkhd->bqhd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window", [4, 16])
def test_sliding_matches_naive(window):
    b, s, h, kv, d = 2, 40, 4, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    got = _sliding(q, k, v, window=window, q_chunk=8)
    want = _naive_attn(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_chunked_xent_matches_naive():
    b, s, d, v = 2, 19, 8, 37
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (b, s, d))
    emb = jax.random.normal(ks[1], (v, d))
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    got = chunked_xent(h, emb, labels, chunk=4)
    logits = h @ emb.T
    lse = jax.nn.logsumexp(logits, -1)
    tgt = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(lse - tgt)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-130m", "jamba-v0.1-52b",
                                  "mixtral-8x22b", "paligemma-3b"])
def test_decode_matches_forward(arch):
    """Prefill t tokens then decode token t; logits must match the full
    forward at position t (cache correctness across attn/ssm/moe/vlm).

    MoE archs use a high capacity factor here: at the training default the
    *forward* may legitimately drop assignments under capacity pressure,
    while the decode path is no-drop by design — the equality being tested
    is cache correctness, not drop behaviour."""
    cfg = dataclasses.replace(get_config(arch).reduced(), capacity_factor=8.0)
    model = build_model(cfg, remat=False, q_chunk=8, kv_chunk=8)
    params = model.init(KEY)
    b, t = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, t + 1), 0, cfg.vocab_size)
    pb = {"tokens": toks[:, :t]}
    prefix = 0
    if cfg.family == "vlm":
        pb["image_emb"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(6), (b, cfg.num_prefix_tokens, cfg.d_model))
        prefix = cfg.num_prefix_tokens
    logits_p, cache = model.prefill(params, pb)
    # grow self-attn cache capacity by 1 slot for the decode write
    def grow(c):
        out = {}
        for pk, sub in c.items():
            out[pk] = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
                           if k in ("k", "v") else v)
                       for k, v in sub.items()}
        return out
    cache = grow(cache)
    logits_d, _ = model.decode_step(params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t + prefix, jnp.int32))
    # full forward over t+1 tokens
    fb = {"tokens": toks}
    kw = {}
    if cfg.family == "vlm":
        kw["prefix_emb"] = pb["image_emb"]
    h, _ = forward_hidden(params, cfg, toks, remat=False, q_chunk=8, kv_chunk=8, **kw)
    logits_f = h[:, -1].astype(jnp.float32) @ _unembed(params, cfg).astype(jnp.float32).T
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_f),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_all_tokens_with_capacity_slack():
    from repro.models.moe import moe, moe_params
    from repro.models.common import init_maker
    d, e, k, ff = 16, 4, 2, 32
    params = moe_params(init_maker(KEY), "m", d_model=d, moe_d_ff=ff,
                        num_experts=e, num_shared_experts=0, activation="swiglu")
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 24, d))
    y, aux = moe(params, x, num_experts=e, top_k=k, activation="swiglu",
                 capacity_factor=4.0, group_size=48)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 1.0 - 1e-3  # switch aux loss lower bound at uniformity
