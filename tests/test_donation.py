"""State donation in the compiled train steps (DESIGN.md Sec. 8).

``launch/steps.compile_train_step`` jits a step with ``donate_argnums=(0,)``
so XLA reuses the train-state input buffers (params, optimizer moments,
the SAGA table -- the largest buffer in the federation) for the outputs.
Pinned contracts:

* correctness: a donated run is BIT-exact with an undonated run (donation
  is an aliasing hint, never a semantics change), and the standard
  training-loop pattern (thread the returned state) survives donation;
* the aliasing hazard is REAL and visible: on backends that honour
  donation (CPU included on current jax) the passed-in state's buffers
  are deleted, so re-using a donated state object raises instead of
  silently reading freed memory -- the re-use-after-donation regression;
* non-donated operands (the batch, the PRNG key) stay alive and reusable
  across steps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RobustConfig, make_federated_step
from repro.data import ijcnn1_like, logreg_loss, partition
from repro.launch import steps as steps_lib
from repro.optim import get_optimizer


@pytest.fixture(scope="module")
def problem():
    data = ijcnn1_like(jax.random.PRNGKey(0), n=200)
    wd = partition({"a": data.x, "b": data.y}, 6, seed=1)
    cfg = RobustConfig(aggregator="geomed", vr="saga", attack="sign_flip",
                       num_byzantine=2, weiszfeld_iters=16)
    loss = logreg_loss(0.01)
    return make_federated_step(loss, wd, cfg, get_optimizer("momentum", 0.02))


def _fresh_state(init_fn):
    return init_fn({"w": jnp.zeros((22,), jnp.float32)},
                   jax.random.PRNGKey(3))


def _buffers_deleted(state) -> bool:
    leaf = jax.tree_util.tree_leaves(state)[0]
    return getattr(leaf, "is_deleted", lambda: False)()


def test_donated_step_is_bit_exact_with_undonated(problem):
    """Donation changes buffer lifetime, never values: 6 steps with the
    donating compiler == 6 steps with plain jit, on every state leaf
    (params + momentum + SAGA table/avg + key)."""
    init_fn, step_fn = problem
    outs = {}
    for donate in (True, False):
        st = _fresh_state(init_fn)
        jstep = steps_lib.compile_train_step(step_fn, donate_state=donate)
        for _ in range(6):
            st, metrics = jstep(st)
        outs[donate] = st
        assert np.isfinite(float(metrics["honest_variance"]))
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]._asdict()),
                    jax.tree_util.tree_leaves(outs[False]._asdict())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reuse_after_donation_raises_not_aliases(problem):
    """The no-accidental-aliasing regression: once a state is donated, its
    buffers are dead -- a second call with the SAME state object must
    raise (jax refuses deleted buffers) rather than read reused memory.
    Skipped (not failed) if this backend ignores donation: then the old
    state is still alive by construction and there is nothing to alias."""
    init_fn, step_fn = problem
    jstep = steps_lib.compile_train_step(step_fn)
    st0 = _fresh_state(init_fn)
    st1, _ = jstep(st0)
    jax.block_until_ready(st1.params["w"])
    if not _buffers_deleted(st0):
        pytest.skip("backend does not honour buffer donation")
    with pytest.raises((RuntimeError, ValueError)):
        _ = jstep(st0)  # noqa: F841 -- must not silently produce values
    # ...while the threaded-state pattern keeps working after the error.
    st2, _ = jstep(st1)
    assert int(st2.step) == 2
    assert np.isfinite(np.asarray(st2.params["w"])).all()


def test_non_donated_operands_survive(problem):
    """Batches and keys are NOT donated by compile_train_step: the
    distributed loop reuses them across steps.  Exercised on the
    3-argument dict-state step convention via a toy step."""
    def toy_step(state, batch, key):
        del key
        g = jnp.mean(batch)
        return {"params": state["params"] - 0.1 * g,
                "step": state["step"] + 1}, {"g": g}

    jstep = steps_lib.compile_train_step(toy_step)
    batch = jnp.arange(8, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    state = {"params": jnp.ones((4,)), "step": jnp.zeros((), jnp.int32)}
    for i in range(3):
        state, _ = jstep(state, batch, key)  # same batch/key objects reused
    assert int(state["step"]) == 3
    np.testing.assert_array_equal(np.asarray(batch),
                                  np.arange(8, dtype=np.float32))