"""Version-portability layer over jax's mesh / shard_map surface.

The distributed federation path (shard_map aggregation, ambient-mesh
contexts, axis types) sits on APIs that moved between jax releases:

================  ======================================  =========================
canonical export  jax >= 0.6 surface                      jax 0.4.x fallback
================  ======================================  =========================
``shard_map``     ``jax.shard_map`` (axis_names=,         ``jax.experimental.shard_map
                  check_vma=)                             .shard_map`` (auto=, check_rep=)
``make_mesh``     ``jax.make_mesh(..., axis_types=)``     ``jax.make_mesh`` (no axis
                                                          types) / ``jax.sharding.Mesh``
``use_mesh``      ``jax.set_mesh`` / ``jax.sharding       thread-local mesh stack
                  .use_mesh`` context                     (see :func:`active_mesh`)
``AxisType``      ``jax.sharding.AxisType``               enum stub (Auto/Explicit/
                                                          Manual)
================  ======================================  =========================

Every capability is probed with ``hasattr`` ONCE at import; call sites in
core/, launch/, models/, examples/ and the test harness import from here and
never touch the moving jax names directly (enforced by
tests/test_substrates.py::test_compat_layer_is_the_only_jax_version_gate).
See DESIGN.md Sec. 3 for the policy.
"""
from __future__ import annotations

import contextlib
import enum
import inspect
import threading
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

__all__ = [
    "JAX_VERSION", "AxisType", "HAS_AXIS_TYPE", "HAS_SHARD_MAP",
    "HAS_AMBIENT_MESH", "make_mesh", "use_mesh", "active_mesh", "shard_map",
    "axis_size", "axis_group", "axis_index", "all_gather", "all_to_all",
    "psum", "pmax", "cost_analysis", "profiler_trace", "require_distributed",
]

JAX_VERSION: tuple[int, ...] = tuple(
    int(x) for x in jax.__version__.split(".")[:3] if x.isdigit())

# ---------------------------------------------------------------------------
# Capability probes -- run exactly once, at import.
# ---------------------------------------------------------------------------

_HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_USE_MESH = hasattr(jax.sharding, "use_mesh")
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_MAKE_MESH = hasattr(jax, "make_mesh")
_MAKE_MESH_KWARGS = (
    frozenset(inspect.signature(jax.make_mesh).parameters)
    if _HAS_MAKE_MESH else frozenset())

_legacy_shard_map = None
if not _HAS_TOPLEVEL_SHARD_MAP:
    try:
        from jax.experimental.shard_map import shard_map as _legacy_shard_map
    except ImportError:  # pragma: no cover - ancient jax
        _legacy_shard_map = None

HAS_SHARD_MAP = _HAS_TOPLEVEL_SHARD_MAP or _legacy_shard_map is not None
HAS_AMBIENT_MESH = _HAS_SET_MESH or _HAS_USE_MESH


if HAS_AXIS_TYPE:
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stub of ``jax.sharding.AxisType`` for jax < 0.5: mesh axes are
        implicitly Auto there, so the values only serve call-site symmetry."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence[Any]] = None,
              axis_types: Optional[Sequence[Any]] = None):
    """Version-stable ``jax.make_mesh``.

    Slices ``devices`` (default: all) to the mesh size with a clear error
    when there are too few; passes ``axis_types`` (default: Auto everywhere)
    only where the running jax understands it.
    """
    shape = tuple(axis_shapes)
    names = tuple(axis_names)
    n = 1
    for s in shape:
        n *= s
    devs = list(jax.devices() if devices is None else devices)
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} -- set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "importing jax (launch/dryrun.py does this)")
    devs = devs[:n]
    if _HAS_MAKE_MESH:
        kwargs: dict[str, Any] = {"devices": devs}
        if "axis_types" in _MAKE_MESH_KWARGS:
            kwargs["axis_types"] = (tuple(axis_types) if axis_types is not None
                                    else (AxisType.Auto,) * len(shape))
        return jax.make_mesh(shape, names, **kwargs)
    return jax.sharding.Mesh(np.asarray(devs).reshape(shape), names)


# ---------------------------------------------------------------------------
# Ambient mesh context
# ---------------------------------------------------------------------------

_tls = threading.local()


def active_mesh():
    """The innermost mesh entered via :func:`use_mesh` (this thread), or the
    jax-native ambient mesh where one exists, else None."""
    stack = getattr(_tls, "mesh_stack", None)
    if stack:
        return stack[-1]
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        if mesh is not None and getattr(mesh, "axis_names", ()):
            return mesh
    return None


@contextlib.contextmanager
def use_mesh(mesh):
    """``with use_mesh(mesh):`` -- the version-stable spelling of
    ``with jax.set_mesh(mesh):``.

    On jax with ambient-mesh support the native context is entered too, so
    bare-PartitionSpec APIs keep working; on jax 0.4.x the mesh is tracked in
    a thread-local stack that :func:`shard_map` and :func:`active_mesh`
    resolve against (all repo call sites pass explicit NamedShardings, so
    nothing else needs the ambient mesh there).
    """
    stack = getattr(_tls, "mesh_stack", None)
    if stack is None:
        stack = _tls.mesh_stack = []
    stack.append(mesh)
    try:
        if _HAS_SET_MESH:
            cm = jax.set_mesh(mesh)
            if hasattr(cm, "__enter__"):
                with cm:
                    yield mesh
            else:  # pragma: no cover - set_mesh variants that only set globally
                yield mesh
        elif _HAS_USE_MESH:
            with jax.sharding.use_mesh(mesh):
                yield mesh
        else:
            yield mesh
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def shard_map(f: Callable, *, mesh=None, in_specs, out_specs,
              axis_names: Optional[Any] = None, check_vma: bool = True):
    """Version-stable ``jax.shard_map``.

    ``mesh``: defaults to :func:`active_mesh` (enter :func:`use_mesh` first).
    ``axis_names``: the MANUAL mesh axes (new-jax convention); None means all
    axes.  On jax 0.4.x this is translated to the complementary ``auto=`` set
    and ``check_vma`` to ``check_rep``.
    """
    if mesh is None:
        mesh = active_mesh()
        if mesh is None:
            raise RuntimeError(
                "compat.shard_map: no mesh -- pass mesh= explicitly or enter "
                "a `with repro.compat.use_mesh(mesh):` context first")
    all_names = frozenset(mesh.axis_names)
    manual = all_names if axis_names is None else frozenset(axis_names)
    unknown = manual - all_names
    if unknown:
        raise ValueError(f"axis_names {sorted(unknown)} not in mesh axes "
                         f"{sorted(all_names)}")
    if _HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual),
                             check_vma=check_vma)
    if _legacy_shard_map is None:
        raise RuntimeError(_NO_SHARD_MAP_MSG)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=bool(check_vma),
                             auto=frozenset(all_names - manual))


@contextlib.contextmanager
def profiler_trace(log_dir: str):
    """``with profiler_trace(dir):`` -- version-stable ``jax.profiler.trace``.

    The context-manager spelling exists on every jax this repo supports, but
    guard it anyway (some stripped builds ship only start_trace/stop_trace)
    so the telemetry layer (``launch/train.py --profile-steps``) degrades to
    the explicit pair instead of crashing mid-run.  Remember to
    ``block_until_ready`` inside the window: dispatch returns early, and an
    empty trace is the classic symptom.
    """
    prof = jax.profiler
    if hasattr(prof, "trace"):
        with prof.trace(log_dir):
            yield
        return
    prof.start_trace(log_dir)  # pragma: no cover - stripped-profiler builds
    try:
        yield
    finally:
        prof.stop_trace()


def cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: 0.4.x
    returns a one-element list of dicts (per executable), newer jax the dict
    itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


_HAS_LAX_AXIS_SIZE = hasattr(jax.lax, "axis_size")


def axis_size(axis_name):
    """``jax.lax.axis_size`` (jax >= 0.6); on older jax the size is recovered
    as ``psum(1, axis)``, which the tracer folds to a static int."""
    if _HAS_LAX_AXIS_SIZE:
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# Multi-axis collectives over the federation's worker axes
#
# The worker dimension of the Byzantine-robust federation may span SEVERAL
# mesh axes (("pod", "data") on multi-pod meshes, launch/mesh.py).  jax's
# collectives accept a tuple of axis names and treat it as one collapsed
# axis whose index is row-major over the tuple (pod-major): verified on
# jax 0.4.37 and the current releases for all_gather / all_to_all /
# axis_index inside fully-manual shard_map.  These wrappers are the single
# guard point for that surface -- if a future jax moves the multi-axis
# collective API (as shard_map/make_mesh did), only this module changes.
# ---------------------------------------------------------------------------

def axis_group(axis_names):
    """Normalize a worker-axis spec -- one name or a sequence of names -- to
    the form jax collectives accept: the bare name for a single axis, a
    tuple for several (treated as one collapsed axis, row-major order)."""
    if isinstance(axis_names, str):
        return axis_names
    names = tuple(axis_names)
    return names[0] if len(names) == 1 else names


def axis_index(axis_names):
    """Linear index along (possibly several) mesh axes, row-major.  Only use
    inside FULLY-manual shard_map: partial-manual shard_map on jax 0.4.x
    cannot lower axis_index (DESIGN.md Sec. 2 -- use a sharded iota there)."""
    return jax.lax.axis_index(axis_group(axis_names))


def all_gather(x, axis_names, *, axis: int = 0, tiled: bool = False):
    """``jax.lax.all_gather`` over one or several worker axes.  With several
    names the gathered dimension arrives as ONE collapsed axis of size
    prod(sizes) in row-major worker order -- not one nested axis per name."""
    return jax.lax.all_gather(x, axis_group(axis_names), axis=axis, tiled=tiled)


def all_to_all(x, axis_names, *, split_axis: int, concat_axis: int,
               tiled: bool = False):
    """``jax.lax.all_to_all`` over one or several worker axes, splitting
    ``split_axis`` into prod(sizes) blocks in row-major worker order."""
    return jax.lax.all_to_all(x, axis_group(axis_names), split_axis,
                              concat_axis, tiled=tiled)


def psum(x, axis_names):
    """``jax.lax.psum`` over one or several mesh axes."""
    return jax.lax.psum(x, axis_group(axis_names))


def pmax(x, axis_names):
    """``jax.lax.pmax`` over one or several mesh axes."""
    return jax.lax.pmax(x, axis_group(axis_names))


_NO_SHARD_MAP_MSG = (
    f"jax {jax.__version__} provides neither jax.shard_map nor "
    "jax.experimental.shard_map.shard_map; the distributed federation path "
    "cannot run.  Upgrade jax (tested: 0.4.37 and >= 0.6) or use the "
    "single-host simulation (repro.core.robust_step.make_federated_step).")


def require_distributed(*, min_devices: int = 0, what: str = "distributed path") -> None:
    """Capability probe for the multi-device federation path.

    Raises a RuntimeError up front -- with the version/flag fix spelled out --
    instead of letting an AttributeError (missing shard_map) or a mesh-size
    error surface from deep inside jit tracing.
    """
    if not HAS_SHARD_MAP:
        raise RuntimeError(f"{what}: {_NO_SHARD_MAP_MSG}")
    if min_devices and len(jax.devices()) < min_devices:
        raise RuntimeError(
            f"{what} needs >= {min_devices} devices, found "
            f"{len(jax.devices())} -- on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={min_devices} "
            "before importing jax")
