"""Time-varying communication graphs for decentralized training.

PR-3's :class:`repro.topology.Topology` is FIXED: one graph for the whole
run.  Real gossip networks are not -- links drop, radios hop, clusters are
rescheduled -- and the decentralized-SGD literature (Peng/Li/Ling 2023,
arXiv:2308.05292; Nedic/Olshevsky on time-varying consensus) only needs the
union graph over a bounded WINDOW of rounds to be connected, not any single
round.  A :class:`GraphSchedule` is the compile-time object carrying that
relaxation:

* ``topologies`` -- a finite period of T graphs on the same node set, the
  schedule repeating with round ``t`` using graph ``t % T``;
* stacked ``(T, N, N)`` neighbor masks / mixing matrices, built ONCE in
  numpy and entering jit as constants: per-round selection is a single
  ``lax.dynamic_index_in_dim`` by the traced round counter, so a schedule
  never rebuilds or retraces anything per round (the whole training step
  stays one compiled program regardless of T);
* connectivity over a WINDOW (:meth:`is_connected_over_window`): the union
  of every length-``window`` run of consecutive rounds must be connected.
  Individual rounds MAY be disconnected -- that is the point of the
  abstraction (a per-round ``erdos_renyi`` draw with small p usually is);
* a JOINT spectral gap (:meth:`joint_spectral_gap`): consensus over one
  period contracts by the second-largest singular value of the product
  ``W_{T-1} ... W_0`` of the per-round mixing matrices (the product is
  doubly stochastic but no longer symmetric, hence singular values, not
  eigenvalues).  For T = 1 this reduces exactly to
  ``Topology.spectral_gap``.

Constructors (registry-driven like the graph constructors):

* ``static(topology)``                      -- T = 1, the PR-3 behaviour;
* ``cyclic([topo_a, topo_b, ...])``         -- deterministic rotation over
  an explicit list (e.g. alternate a cheap ring with an occasional
  denser graph);
* ``erdos_renyi_schedule(n, p, seed, T)``   -- T independent seeded
  ``G(n, p)`` draws, the random-gossip model: each round is a fresh sparse
  graph and only the window union has to be connected.

``get_schedule(name, num_nodes, ...)`` builds by name ("static", "cyclic",
"erdos_renyi"); for "cyclic" the ``topology`` argument is a comma-separated
list of graph names (``"ring,complete"``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.topology.graphs import Topology, _connected, erdos_renyi, get_topology


@dataclasses.dataclass(frozen=True)
class GraphSchedule:
    """A periodic sequence of topologies on one node set."""

    name: str
    topologies: tuple[Topology, ...]

    def __post_init__(self):
        if not self.topologies:
            raise ValueError("GraphSchedule needs at least one topology")
        ns = {t.num_nodes for t in self.topologies}
        if len(ns) != 1:
            raise ValueError(
                f"every topology in a schedule must share the node set; "
                f"got node counts {sorted(ns)}")
        object.__setattr__(self, "topologies", tuple(self.topologies))

    @property
    def num_nodes(self) -> int:
        return self.topologies[0].num_nodes

    @property
    def period(self) -> int:
        return len(self.topologies)

    @property
    def is_static(self) -> bool:
        return self.period == 1

    @property
    def min_neighborhood(self) -> int:
        """Smallest neighborhood (incl. self) over EVERY round: the bound
        the per-round feasibility checks (trimmed_mean) must hold against."""
        return min(t.min_neighborhood for t in self.topologies)

    # -- stacked compile-time constants ------------------------------------

    @functools.cached_property
    def stacked_masks(self) -> np.ndarray:
        """(T, N, N) float32 neighbor masks (with self-loops), plain numpy."""
        return np.stack([t.neighbor_mask for t in self.topologies])

    @functools.cached_property
    def stacked_mixing(self) -> np.ndarray:
        """(T, N, N) float64 Metropolis-Hastings mixing matrices."""
        return np.stack([t.mixing for t in self.topologies])

    def mask_at(self, t) -> jnp.ndarray:
        """(N, N) neighbor mask of round ``t`` (``t`` may be traced): the
        stacked constant indexed with one ``dynamic_index_in_dim`` -- never
        a per-round rebuild/retrace."""
        stack = jnp.asarray(self.stacked_masks, jnp.float32)
        if self.is_static:
            return stack[0]
        idx = jnp.asarray(t, jnp.int32) % self.period
        return jax.lax.dynamic_index_in_dim(stack, idx, axis=0,
                                            keepdims=False)

    def mixing_at(self, t) -> jnp.ndarray:
        """(N, N) float32 mixing matrix of round ``t`` (``t`` may be traced)."""
        stack = jnp.asarray(self.stacked_mixing, jnp.float32)
        if self.is_static:
            return stack[0]
        idx = jnp.asarray(t, jnp.int32) % self.period
        return jax.lax.dynamic_index_in_dim(stack, idx, axis=0,
                                            keepdims=False)

    # -- validation / reporting -------------------------------------------

    def union_adjacency(self, start: int = 0,
                        window: Optional[int] = None) -> np.ndarray:
        """(N, N) bool union of the adjacencies of rounds ``start`` ..
        ``start + window - 1`` (mod the period; default window = period)."""
        w = self.period if window is None else window
        adj = np.zeros((self.num_nodes, self.num_nodes), bool)
        for k in range(w):
            adj |= self.topologies[(start + k) % self.period].adjacency
        return adj

    def is_connected_over_window(self, window: Optional[int] = None) -> bool:
        """True iff the union graph of EVERY length-``window`` run of
        consecutive rounds is connected (default window = the full period).
        This is the standard B-connectivity condition under which
        time-varying gossip still reaches consensus; single rounds may be
        disconnected."""
        w = self.period if window is None else window
        if not 1 <= w <= self.period:
            raise ValueError(
                f"window must be in [1, {self.period}], got {w}")
        if w == self.period:
            # Every start offset unions the same full topology set.
            return _connected(self.union_adjacency(0, w))
        return all(_connected(self.union_adjacency(s, w))
                   for s in range(self.period))

    def joint_spectral_gap(self) -> float:
        """``1 - sigma_2(W_{T-1} ... W_0)``: one minus the second-largest
        singular value of the period product of mixing matrices (the
        disagreement contraction per period).  Equals
        ``Topology.spectral_gap`` when T = 1; 0 for a window-disconnected
        schedule."""
        n = self.num_nodes
        if n == 1:
            return 1.0
        prod = np.eye(n)
        for t in self.topologies:
            prod = t.mixing @ prod
        # Remove the consensus direction (the all-ones left/right singular
        # pair of any doubly stochastic product), then the top remaining
        # singular value is the disagreement contraction factor.
        disagree = prod - np.full((n, n), 1.0 / n)
        sig = np.linalg.svd(disagree, compute_uv=False)
        # A window-disconnected schedule has an exact singular value of 1;
        # clamp the O(eps) SVD overshoot so the gap stays in [0, 1].
        return float(max(0.0, 1.0 - sig[0]))

    def describe(self) -> dict:
        """The schedule-level report (demo / benchmark / log line): the
        joint gap plus per-round summaries."""
        return {
            "name": self.name,
            "num_nodes": self.num_nodes,
            "period": self.period,
            "window_connected": self.is_connected_over_window(),
            "joint_spectral_gap": self.joint_spectral_gap(),
            "min_neighborhood": self.min_neighborhood,
            "rounds": [t.describe() for t in self.topologies],
        }


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def static(topology: Topology) -> GraphSchedule:
    """The fixed-graph schedule: round-independent, T = 1.  Training through
    a static schedule is BIT-exact with the PR-3 fixed-topology path (the
    mask/mixing constants are identical arrays and no round indexing is
    emitted)."""
    return GraphSchedule(f"static:{topology.name}", (topology,))


def cyclic(topologies: Sequence[Topology], *,
           name: Optional[str] = None) -> GraphSchedule:
    """Deterministic rotation over an explicit topology list: round ``t``
    uses ``topologies[t % len(topologies)]``."""
    topos = tuple(topologies)
    if name is None:
        name = "cyclic:" + ",".join(t.name for t in topos)
    return GraphSchedule(name, topos)


def erdos_renyi_schedule(num_nodes: int, *, p: float = 0.5, seed: int = 0,
                         period: int = 4) -> GraphSchedule:
    """``period`` independent seeded G(N, p) draws, cycled: the random-gossip
    model.  Per-round draws are NOT redrawn to connectivity -- a sparse
    round is legitimate as long as the window union connects (checked by
    ``validate_schedule`` at trace time; raise ``p`` or ``period`` if it
    does not).  Deterministic in (N, p, seed, period)."""
    if period < 1:
        raise ValueError(f"erdos_renyi schedule needs period >= 1, got {period}")
    rng = np.random.default_rng(np.random.SeedSequence([num_nodes, seed, period]))
    round_seeds = rng.integers(0, 2**31 - 1, size=period)
    topos = tuple(
        erdos_renyi(num_nodes, p=p, seed=int(s), require_connected=False)
        for s in round_seeds)
    return GraphSchedule(f"erdos_renyi(p={p},seed={seed},T={period})", topos)


def _build_static(num_nodes, topology, period, seed, p):
    topo = (topology if isinstance(topology, Topology)
            else get_topology(topology, num_nodes, seed=seed, p=p))
    return static(topo)


def _build_cyclic(num_nodes, topology, period, seed, p):
    if isinstance(topology, Topology):
        names = [topology]
    elif isinstance(topology, str):
        names = [n.strip() for n in topology.split(",") if n.strip()]
    else:
        names = list(topology)
    topos = [t if isinstance(t, Topology)
             else get_topology(t, num_nodes, seed=seed, p=p) for t in names]
    return cyclic(topos)


def _build_er(num_nodes, topology, period, seed, p):
    return erdos_renyi_schedule(num_nodes, p=p, seed=seed, period=period)


# name -> builder(num_nodes, topology, period, seed, p).  SCHEDULE_NAMES and
# the unknown-name error derive from this dict (same pattern as the
# topology/aggregator/attack registries).
_SCHEDULES: dict[str, Callable[..., GraphSchedule]] = {
    "static": _build_static,
    "cyclic": _build_cyclic,
    "erdos_renyi": _build_er,
}

SCHEDULE_NAMES = tuple(_SCHEDULES)


def get_schedule(name: str, num_nodes: int, *,
                 topology: Union[str, Topology, Sequence] = "ring",
                 period: int = 4, seed: int = 0,
                 p: float = 0.5) -> GraphSchedule:
    """Build a schedule by name.  ``topology`` feeds "static" (one graph
    name or object) and "cyclic" (comma-separated names, a list, or
    objects); ``period``/``seed``/``p`` feed the "erdos_renyi" resampler."""
    try:
        build = _SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; known: "
            f"{', '.join(sorted(_SCHEDULES))}") from None
    return build(num_nodes, topology, period, seed, p)


def as_schedule(obj: Union[Topology, GraphSchedule]) -> GraphSchedule:
    """Normalize a fixed :class:`Topology` to its static schedule; pass
    schedules through.  The shim that lets every aggregation path speak
    schedules while the PR-3 entry points keep accepting plain graphs."""
    if isinstance(obj, GraphSchedule):
        return obj
    if isinstance(obj, Topology):
        return static(obj)
    raise TypeError(f"expected Topology or GraphSchedule, got {type(obj)!r}")


def validate_schedule(cfg: Any, sched: GraphSchedule, num_nodes: int) -> None:
    """Trace-time feasibility checks of a schedule against the federation
    (the schedule counterpart of ``validate_topology``): node count, window
    connectivity (the union over one period must connect even when single
    rounds do not), and the per-round aggregator bounds."""
    if sched.num_nodes != num_nodes:
        raise ValueError(
            f"schedule {sched.name!r} has {sched.num_nodes} nodes but the "
            f"federation has {num_nodes}")
    if not sched.is_connected_over_window():
        raise ValueError(
            f"schedule {sched.name!r} is disconnected over its window of "
            f"{sched.period} rounds -- gossip cannot reach consensus; raise "
            "p / the period, or add a connected round to the cycle")
    if cfg.aggregator == "trimmed_mean" and sched.min_neighborhood <= 2 * cfg.trim:
        raise ValueError(
            f"trimmed_mean(trim={cfg.trim}) needs every neighborhood in "
            f"every round to have > {2 * cfg.trim} members; schedule "
            f"{sched.name!r} has a round with a neighborhood of "
            f"{sched.min_neighborhood}")
