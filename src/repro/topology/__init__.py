"""Decentralized topologies: network graphs, masked neighborhood
aggregation, and the server-free training step (DESIGN.md Sec. 6)."""
from repro.topology.graphs import (
    TOPOLOGY_NAMES,
    Topology,
    complete,
    erdos_renyi,
    get_topology,
    ring,
    star,
    torus2d,
)
from repro.topology.masked import (
    MASKED_AGGREGATOR_NAMES,
    masked_aggregate,
    masked_centered_clip,
    masked_geomed_blockwise,
    masked_geomed_groups,
    masked_krum,
    masked_mean,
    masked_median,
    masked_trimmed_mean,
    masked_weiszfeld,
    masked_weiszfeld_segments,
)
from repro.topology.decentralized_step import (
    build_exchange,
    decentralized_aggregate,
    make_decentralized_step,
    validate_topology,
)
