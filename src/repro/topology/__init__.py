"""Decentralized topologies: network graphs, time-varying graph schedules,
masked neighborhood aggregation, and the server-free training step with
gradient or parameter gossip (DESIGN.md Secs. 6-7)."""
from repro.topology.graphs import (
    TOPOLOGY_NAMES,
    Topology,
    complete,
    erdos_renyi,
    get_topology,
    ring,
    star,
    torus2d,
)
from repro.topology.masked import (
    MASKED_AGGREGATOR_NAMES,
    masked_aggregate,
    masked_aggregate_flat,
    masked_centered_clip,
    masked_geomed_blockwise,
    masked_geomed_groups,
    masked_krum,
    masked_mean,
    masked_median,
    masked_trimmed_mean,
    masked_weiszfeld,
    masked_weiszfeld_segments,
)
from repro.topology.schedule import (
    SCHEDULE_NAMES,
    GraphSchedule,
    as_schedule,
    erdos_renyi_schedule,
    get_schedule,
    validate_schedule,
)
from repro.topology.schedule import cyclic as cyclic_schedule
from repro.topology.schedule import static as static_schedule
from repro.topology.decentralized_step import (
    GOSSIP_MODES,
    build_exchange,
    decentralized_aggregate,
    make_decentralized_step,
    validate_topology,
)
