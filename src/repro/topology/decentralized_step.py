"""Decentralized Byzantine-robust training over an explicit network graph.

Server-free counterpart of :mod:`repro.core.robust_step` (DESIGN.md Sec. 6):
there is no master -- every node keeps ITS OWN parameters, computes its own
(SAGA-corrected) stochastic gradient, exchanges gradient messages only with
its graph neighbors, and robustly aggregates its masked neighborhood with
any registry aggregator (:mod:`repro.topology.masked`).  Byzantine nodes
attack PER EDGE: the message a Byzantine sender injects toward receiver i
is crafted from receiver i's own honest-neighborhood statistics, so two
receivers see different poison (strictly stronger than the master-path
attacks, which send one identical vector to the single aggregation point).

Three execution paths share the math, mirroring the master layout:

* :func:`make_decentralized_step` -- single-host simulation (dense
  (N, N, ...) exchange tensor), the path behind
  ``make_federated_step(..., topology=...)``;
* :func:`decentralized_aggregate` with ``comm="gather"`` -- inside
  ``shard_map``: all_gather the worker axes, pick this node's mask row at
  its linear worker index, aggregate its own neighborhood (per-iteration
  psums over the model axes, worker-axis pmax keeping the Weiszfeld loops
  in collective lockstep);
* ``comm="sharded"`` -- the coordinate-resharded path: the Sec. 2
  all_to_all gives every device a p/W slice of ALL messages, per-edge
  attacks and ALL receivers' masked aggregations run slice-locally with
  (R, S)-shaped psums restoring global geometry, and a second all_to_all
  routes each receiver its own aggregate's slices.

``topology="star"`` is deliberately NOT routed here: the training entry
points special-case it onto the existing master implementations so the
default path stays bit-exact with the paper reproduction.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import attacks as attack_lib
from repro.core import saga as saga_lib
from repro.core.robust_step import (FederatedState, _flatten_concat,
                                    _local_leaf_ids)
from repro.optim import optimizers as optim_lib
from repro.topology.graphs import Topology
from repro.topology.masked import masked_aggregate, masked_weiszfeld_segments

Pytree = Any


def _bcast_rows(tree: Pytree, r: int) -> Pytree:
    """Leaves (S, ...) -> (R, S, ...) by broadcast (honest senders say the
    same thing to every receiver)."""
    return jax.tree_util.tree_map(
        lambda z: jnp.broadcast_to(z[None], (r,) + z.shape), tree)


def build_exchange(
    msgs: Pytree,
    cfg: attack_lib.AttackConfig,
    mask: jnp.ndarray,
    is_byz: jnp.ndarray,
    key: Optional[jax.Array] = None,
) -> Pytree:
    """Materialize the per-edge message exchange.

    ``msgs``: leaves (S, ...) -- the honestly computed messages (rows of
    Byzantine senders are ignored).  ``mask``: (R, S) neighbor-mask rows of
    the receivers being built.  ``is_byz``: (S,) marks Byzantine senders.
    Returns leaves (R, S, ...): row r is receiver r's view, with every
    Byzantine sender's entry replaced by an attack vector crafted from
    receiver r's masked HONEST statistics (mask-select; the omniscient
    threat model of DESIGN.md Sec. 1 already grants attackers these stats).

    All rules are coordinate-separable, so the same construction runs on
    full messages (simulation), model shards (gather) and coordinate slices
    (sharded) with no communication; only the ``gaussian`` attack's draws
    are layout-dependent (same caveat as the master-path attack variants).
    """
    r = mask.shape[0]
    if cfg.name not in attack_lib.ATTACK_NAMES:
        raise ValueError(f"unknown attack {cfg.name!r}; known: "
                         f"{', '.join(sorted(attack_lib.ATTACK_NAMES))}")
    if cfg.name == "none" or cfg.num_byzantine == 0:
        return _bcast_rows(msgs, r)

    byz_f = is_byz.astype(jnp.float32)                    # (S,)
    hon_w = mask * (1.0 - byz_f)[None, :]                 # (R, S)
    h_cnt = jnp.maximum(jnp.sum(hon_w, axis=1), 1.0)      # (R,)
    b_cnt = jnp.maximum(jnp.sum(mask * byz_f[None, :], axis=1), 1.0)

    def nbr_mean(fn):
        def leaf(z):
            w = hon_w.reshape(hon_w.shape + (1,) * (z.ndim - 1))
            acc = jnp.sum(w * fn(z.astype(jnp.float32))[None], axis=1)
            return acc / h_cnt.reshape((-1,) + (1,) * (z.ndim - 1))
        return jax.tree_util.tree_map(leaf, msgs)

    mean = nbr_mean(lambda z: z)                          # leaves (R, ...)

    name = cfg.name
    if name == "sign_flip":
        byz = jax.tree_util.tree_map(
            lambda m: cfg.sign_flip_magnitude * m, mean)
    elif name == "zero_gradient":
        # Each receiver's masked neighborhood mean becomes exactly zero.
        ratio = h_cnt / b_cnt
        byz = jax.tree_util.tree_map(
            lambda m: -ratio.reshape((-1,) + (1,) * (m.ndim - 1)) * m, mean)
    elif name == "ipm":
        byz = jax.tree_util.tree_map(lambda m: -cfg.ipm_eps * m, mean)
    elif name == "alie":
        sq = nbr_mean(jnp.square)
        byz = jax.tree_util.tree_map(
            lambda m, s: m + cfg.alie_z * jnp.sqrt(
                jnp.maximum(s - m * m, 0.0)), mean, sq)
    elif name == "gaussian":
        if key is None:
            raise ValueError("gaussian attack needs a key")
        std = jnp.sqrt(cfg.gaussian_variance)
        leaves, treedef = jax.tree_util.tree_flatten(mean)
        keys = jax.random.split(key, len(leaves))
        s = mask.shape[1]
        byz = jax.tree_util.tree_unflatten(treedef, [
            m[:, None] + std * jax.random.normal(
                k, (r, s) + m.shape[1:], jnp.float32)
            for m, k in zip(leaves, keys)])
    else:
        # Reachable for a name that IS in the registry: every attack needs
        # an explicit per-edge generalization here (receiver-local stats),
        # so a newly registered master-path attack fails loudly with the
        # gap named instead of silently passing through unattacked.
        raise NotImplementedError(
            f"attack {name!r} is registered in core.attacks but has no "
            "per-edge decentralized form in topology.build_exchange -- add "
            "its receiver-neighborhood construction here")

    def select(z, bz):
        zb = jnp.broadcast_to(z[None].astype(jnp.float32),
                              (r,) + z.shape)
        # Per-receiver attack values broadcast over senders unless the
        # attack already drew per-edge values (gaussian).
        bz_rows = bz[:, None] if bz.ndim == z.ndim else bz
        sel = is_byz.reshape((1, -1) + (1,) * (z.ndim - 1))
        return jnp.where(sel, bz_rows, zb).astype(z.dtype)

    return jax.tree_util.tree_map(select, msgs, byz)


def _agg_opts(cfg, topo: Topology, mixing, axis_names=(), sync_axes=()):
    return dict(
        max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
        num_groups=cfg.num_groups, trim=cfg.trim,
        num_byzantine=cfg.num_byzantine, clip_radius=cfg.clip_radius,
        mixing=mixing, axis_names=tuple(axis_names),
        sync_axes=tuple(sync_axes))


def validate_topology(cfg, topo: Topology, num_nodes: int) -> None:
    """Static feasibility checks against the graph (trace-time, so they
    raise with context instead of producing NaN aggregates)."""
    if topo.num_nodes != num_nodes:
        raise ValueError(
            f"topology {topo.name!r} has {topo.num_nodes} nodes but the "
            f"federation has {num_nodes}")
    if not topo.is_connected():
        raise ValueError(f"topology {topo.name!r} is disconnected")
    if cfg.aggregator == "trimmed_mean" and topo.min_neighborhood <= 2 * cfg.trim:
        raise ValueError(
            f"trimmed_mean(trim={cfg.trim}) needs every neighborhood to "
            f"have > {2 * cfg.trim} members; topology {topo.name!r} has a "
            f"neighborhood of {topo.min_neighborhood}")


# ---------------------------------------------------------------------------
# Simulation path (single host, dense exchange)
# ---------------------------------------------------------------------------

def make_decentralized_step(
    loss_fn: Callable[[Pytree, Pytree], jnp.ndarray],
    worker_data: Pytree,
    cfg,
    optimizer: optim_lib.Optimizer,
    topology: Topology,
):
    """Build ``(init_fn, step_fn)`` for the simulated decentralized
    federation; drop-in shaped like
    :func:`repro.core.robust_step.make_federated_step` but with PER-NODE
    parameters.

    Graph nodes are ``N = W_h + B``: the first W_h ids are the honest
    workers (rows of ``worker_data``), the LAST B are Byzantine (matching
    the simulation convention of ``attacks.apply_attack``, which appends
    Byzantine rows; the distributed path replaces the FIRST B workers,
    matching ``apply_attack_stacked``).  State leaves carry a leading node
    axis: every node owns its own parameter/optimizer copy, and
    ``consensus_dist`` in the metrics tracks how far the honest copies have
    drifted apart.
    """
    wh = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    j = jax.tree_util.tree_leaves(worker_data)[0].shape[1]
    b = cfg.num_byzantine if cfg.attack != "none" else 0
    n = wh + b
    validate_topology(cfg, topology, n)
    grad_fn = jax.grad(loss_fn)
    attack_cfg = cfg.attack_config()
    mask = jnp.asarray(topology.neighbor_mask, jnp.float32)
    mixing = jnp.asarray(topology.mixing, jnp.float32)
    is_byz = jnp.arange(n) >= wh

    def sample_batch(data_w, idx):
        return jax.tree_util.tree_map(lambda d: d[idx], data_w)

    def per_worker_grad(params_w, data_w, idx):
        return grad_fn(params_w, sample_batch(data_w, idx))

    def init_fn(params, key):
        nodes = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (n,) + p.shape) + 0, params)
        opt_state = optimizer.init(nodes)
        saga_state = None
        if cfg.vr == "saga":
            def worker_tab(data_w):
                return jax.vmap(
                    lambda jj: grad_fn(params, sample_batch(data_w, jj[None]))
                )(jnp.arange(j))
            per_sample = jax.vmap(worker_tab)(worker_data)
            saga_state = saga_lib.saga_init(per_sample)
        return FederatedState(nodes, opt_state, saga_state,
                              jnp.zeros((), jnp.int32), key)

    def step_fn(state):
        key, k_idx, k_attack = jax.random.split(state.key, 3)
        honest_params = jax.tree_util.tree_map(lambda x: x[:wh], state.params)

        if cfg.vr == "minibatch":
            idx = jax.random.randint(k_idx, (wh, cfg.minibatch_size), 0, j)
            honest = jax.vmap(per_worker_grad)(honest_params, worker_data, idx)
            saga_state = state.saga
        else:
            idx = jax.random.randint(k_idx, (wh,), 0, j)
            honest = jax.vmap(
                lambda p, d, i: per_worker_grad(p, d, i[None])
            )(honest_params, worker_data, idx)
            if cfg.vr == "saga":
                honest, saga_state = saga_lib.saga_correct_scatter(
                    state.saga, honest, idx)
            else:
                saga_state = state.saga

        # Honest-message variance (same metric as the master path).
        hm = jax.tree_util.tree_map(lambda z: jnp.mean(z, axis=0), honest)
        var = sum(
            jnp.sum((z.astype(jnp.float32) - m.astype(jnp.float32)[None]) ** 2)
            for z, m in zip(jax.tree_util.tree_leaves(honest),
                            jax.tree_util.tree_leaves(hm))
        ) / wh

        # Byzantine node rows carry zeros until the attack replaces them.
        msgs = jax.tree_util.tree_map(
            lambda g: jnp.zeros((n,) + g.shape[1:], g.dtype).at[:wh].set(g),
            honest)
        exchange = build_exchange(msgs, attack_cfg, mask, is_byz, k_attack)
        agg = masked_aggregate(
            cfg.aggregator, exchange, mask,
            **_agg_opts(cfg, topology, mixing * mask))

        updates, opt_state = optimizer.update(
            agg, state.opt_state, state.params, state.step)
        params = optim_lib.apply_updates(state.params, updates)

        xh = jax.tree_util.tree_map(lambda x: x[:wh], params)
        cons = sum(
            jnp.sum((x.astype(jnp.float32)
                     - jnp.mean(x.astype(jnp.float32), axis=0)[None]) ** 2)
            for x in jax.tree_util.tree_leaves(xh)
        ) / wh
        new_state = FederatedState(params, opt_state, saga_state,
                                   state.step + 1, key)
        return new_state, {"honest_variance": var, "consensus_dist": cons}

    return init_fn, step_fn


# ---------------------------------------------------------------------------
# Distributed path (inside shard_map; one node per worker-axis index)
# ---------------------------------------------------------------------------

def decentralized_aggregate(
    grads: Pytree,
    cfg,
    topology: Topology,
    *,
    comm: str = "gather",
    worker_axes: tuple[str, ...] = ("data",),
    model_axes: tuple[str, ...] = ("model",),
    num_workers: int,
    key: Optional[jax.Array] = None,
) -> Pytree:
    """Per-node robust neighborhood aggregation inside ``shard_map``.

    ``grads``: this node's message (leaves are local model shards).  Nodes
    are the linear worker-axis indices (row-major over ``worker_axes``,
    the Sec. 2 convention); the FIRST ``cfg.num_byzantine`` nodes attack
    per edge.  Returns THIS node's aggregate (same local-shard geometry as
    the input) -- per-node results, unlike the master paths which return
    one shared aggregate.
    """
    if comm not in ("gather", "sharded"):
        raise ValueError(f"comm must be 'gather' or 'sharded', got {comm!r}")
    w = num_workers
    validate_topology(cfg, topology, w)
    attack_cfg = cfg.attack_config()
    mask_all = jnp.asarray(topology.neighbor_mask, jnp.float32)
    mixing_all = jnp.asarray(topology.mixing, jnp.float32)
    is_byz = jnp.arange(w) < cfg.num_byzantine
    wid = compat.axis_index(worker_axes)

    if comm == "gather":
        stacked = jax.tree_util.tree_map(
            lambda g: compat.all_gather(g, worker_axes, axis=0, tiled=False),
            grads)
        mask_row = jnp.take(mask_all, wid, axis=0)[None]      # (1, S)
        mix_row = jnp.take(mixing_all, wid, axis=0)[None]
        k = jax.random.fold_in(key, wid) if key is not None else None
        exchange = build_exchange(stacked, attack_cfg, mask_row, is_byz, k)
        agg = masked_aggregate(
            cfg.aggregator, exchange, mask_row,
            **_agg_opts(cfg, topology, mix_row * mask_row,
                        axis_names=model_axes, sync_axes=worker_axes))
        return jax.tree_util.tree_map(lambda a: a[0], agg)

    # comm == "sharded": reuse the coordinate-resharding plumbing of
    # robust_step.sharded_aggregate, but aggregate ALL receivers' masked
    # neighborhoods on this device's slice and route each receiver its own
    # result with a second all_to_all (DESIGN.md Sec. 6).
    flat, unflatten, leaf_sizes = _flatten_concat(grads)
    p = flat.shape[0]
    pad = (-p) % w
    flat = jnp.pad(flat, (0, pad))
    z_local = compat.all_to_all(flat.reshape(w, -1), worker_axes,
                                split_axis=0, concat_axis=0, tiled=False)
    z_local = z_local.reshape(w, -1)                          # (S, chunk)
    comm_axes = tuple(worker_axes) + tuple(model_axes)
    k = jax.random.fold_in(key, wid) if key is not None else None
    exchange = build_exchange({"flat": z_local}, attack_cfg, mask_all,
                              is_byz, k)
    if cfg.aggregator == "geomed_blockwise":
        seg = _local_leaf_ids(leaf_sizes, pad, w, worker_axes)
        agg = masked_weiszfeld_segments(
            exchange["flat"], mask_all, seg, len(leaf_sizes) + 1,
            axis_names=comm_axes, max_iters=cfg.weiszfeld_iters,
            tol=cfg.weiszfeld_tol)
    else:
        agg = masked_aggregate(
            cfg.aggregator, exchange, mask_all,
            **_agg_opts(cfg, topology, mixing_all * mask_all,
                        axis_names=comm_axes))["flat"]
    agg = agg.astype(jnp.float32)                             # (R, chunk)
    mine = compat.all_to_all(agg, worker_axes, split_axis=0,
                             concat_axis=0, tiled=False).reshape(-1)
    return unflatten(mine[:p])
