"""Decentralized Byzantine-robust training over an explicit network graph.

Server-free counterpart of :mod:`repro.core.robust_step` (DESIGN.md
Secs. 6-7): there is no master -- every node keeps ITS OWN parameters,
computes its own (variance-reduced) stochastic gradient, exchanges messages
only with its graph neighbors, and robustly aggregates its masked
neighborhood with any registry aggregator (:mod:`repro.topology.masked`).
The message channel is configurable (``cfg.gossip``): GRADIENTS (aggregate
then apply the optimizer, PR-3 behaviour) or PARAMETERS (apply the
optimizer locally, then robust-aggregate the neighbors' half-stepped
models -- arXiv:2308.05292).  The graph itself may be time-varying: every
path accepts a :class:`repro.topology.GraphSchedule` whose per-round
mask/mixing constants are selected by the traced round counter
(``topology/schedule.py``).  Byzantine nodes attack PER EDGE: the message
a Byzantine sender injects toward receiver i is crafted from receiver i's
own honest-neighborhood statistics, so two receivers see different poison
(strictly stronger than the master-path attacks, which send one identical
vector to the single aggregation point).

Three execution paths share the math, mirroring the master layout:

* :func:`make_decentralized_step` -- single-host simulation (dense
  (N, N, ...) exchange tensor), the path behind
  ``make_federated_step(..., topology=..., schedule=...)``;
* :func:`decentralized_aggregate` with ``comm="gather"`` -- inside
  ``shard_map``: all_gather the worker axes, pick this node's mask row at
  its linear worker index, aggregate its own neighborhood (per-iteration
  psums over the model axes, worker-axis pmax keeping the Weiszfeld loops
  in collective lockstep);
* ``comm="sharded"`` -- the coordinate-resharded path: the Sec. 2
  all_to_all gives every device a p/W slice of ALL messages, per-edge
  attacks and ALL receivers' masked aggregations run slice-locally with
  (R, S)-shaped psums restoring global geometry, and a second all_to_all
  routes each receiver its own aggregate's slices.

``topology="star"`` (with a static schedule) is deliberately NOT routed
here: the training entry points special-case it onto the existing master
implementations so the default path stays bit-exact with the paper
reproduction.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro import telemetry
from repro.core import attacks as attack_lib
from repro.core import guards as guards_lib
from repro.core import packing
from repro.core.robust_step import (FederatedState, _flatten_concat,
                                    _local_leaf_ids)
from repro.optim import optimizers as optim_lib
from repro.topology.graphs import Topology
from repro.topology.masked import (masked_aggregate, masked_aggregate_flat,
                                   masked_weiszfeld_segments)
from repro.topology.schedule import as_schedule, validate_schedule

Pytree = Any

GOSSIP_MODES = ("gradient", "params")


def _check_gossip(cfg) -> str:
    gossip = getattr(cfg, "gossip", "gradient")
    if gossip not in GOSSIP_MODES:
        raise ValueError(f"RobustConfig.gossip must be one of {GOSSIP_MODES}, "
                         f"got {gossip!r}")
    return gossip


def _bcast_rows(tree: Pytree, r: int) -> Pytree:
    """Leaves (S, ...) -> (R, S, ...) by broadcast (honest senders say the
    same thing to every receiver)."""
    return jax.tree_util.tree_map(
        lambda z: jnp.broadcast_to(z[None], (r,) + z.shape), tree)


def build_exchange(
    msgs: Pytree,
    cfg: attack_lib.AttackConfig,
    mask: jnp.ndarray,
    is_byz: jnp.ndarray,
    key: Optional[jax.Array] = None,
    *,
    spec: Optional[packing.PackSpec] = None,
) -> Pytree:
    """Materialize the per-edge message exchange.

    ``msgs``: leaves (S, ...) -- the honestly computed messages (rows of
    Byzantine senders are ignored).  ``mask``: (R, S) neighbor-mask rows of
    the receivers being built.  ``is_byz``: (S,) marks Byzantine senders.
    Returns leaves (R, S, ...): row r is receiver r's view, with every
    Byzantine sender's entry replaced by an attack vector crafted from
    receiver r's masked HONEST statistics (mask-select; the omniscient
    threat model of DESIGN.md Sec. 1 already grants attackers these stats).

    All rules are coordinate-separable, so the same construction runs on
    full messages (simulation), model shards (gather), coordinate slices
    (sharded) AND the packed (S, D) message buffer of DESIGN.md Sec. 8
    with no communication; only the ``gaussian`` attack's draws are
    layout-dependent -- pass the buffer's PackSpec as ``spec=`` and they
    mirror the per-leaf draws bit-for-bit (same caveat/fix as the
    master-path attack variants).
    """
    r = mask.shape[0]
    if cfg.name not in attack_lib.ATTACK_NAMES:
        raise ValueError(f"unknown attack {cfg.name!r}; known: "
                         f"{', '.join(sorted(attack_lib.ATTACK_NAMES))}")
    if cfg.name == "none" or cfg.num_byzantine == 0:
        return _bcast_rows(msgs, r)

    byz_f = is_byz.astype(jnp.float32)                    # (S,)
    hon_w = mask * (1.0 - byz_f)[None, :]                 # (R, S)
    h_cnt = jnp.maximum(jnp.sum(hon_w, axis=1), 1.0)      # (R,)
    b_cnt = jnp.maximum(jnp.sum(mask * byz_f[None, :], axis=1), 1.0)

    def nbr_mean(fn):
        def leaf(z):
            w = hon_w.reshape(hon_w.shape + (1,) * (z.ndim - 1))
            acc = jnp.sum(w * fn(z.astype(jnp.float32))[None], axis=1)
            return acc / h_cnt.reshape((-1,) + (1,) * (z.ndim - 1))
        return jax.tree_util.tree_map(leaf, msgs)

    mean = nbr_mean(lambda z: z)                          # leaves (R, ...)

    name = cfg.name
    if name == "sign_flip":
        byz = jax.tree_util.tree_map(
            lambda m: cfg.sign_flip_magnitude * m, mean)
    elif name == "zero_gradient":
        # Each receiver's masked neighborhood mean becomes exactly zero.
        ratio = h_cnt / b_cnt
        byz = jax.tree_util.tree_map(
            lambda m: -ratio.reshape((-1,) + (1,) * (m.ndim - 1)) * m, mean)
    elif name == "ipm":
        byz = jax.tree_util.tree_map(lambda m: -cfg.ipm_eps * m, mean)
    elif name == "alie":
        sq = nbr_mean(jnp.square)
        byz = jax.tree_util.tree_map(
            lambda m, s: m + cfg.alie_z * jnp.sqrt(
                jnp.maximum(s - m * m, 0.0)), mean, sq)
    elif name == "straggler":
        # Stale-by-k report, per receiver: a scaled copy of receiver r's own
        # honest-neighborhood mean stands in for a message computed
        # ``straggler_k`` rounds ago (the same deterministic proxy as the
        # master-path attack, receiver-localized).
        byz = jax.tree_util.tree_map(
            lambda m: (1.0 + 0.25 * cfg.straggler_k) * m, mean)
    elif name == "dropout":
        # Absent sender: its edges carry zero payload toward every receiver;
        # the bounded-staleness weights (sender staleness = max_staleness ->
        # weight exactly 0 on its mask COLUMN) remove it from each masked
        # aggregation without slicing the sender axis.
        byz = jax.tree_util.tree_map(jnp.zeros_like, mean)
    elif name == "nan":
        # Fault injection (DESIGN.md Sec. 13): every real coordinate of the
        # Byzantine edges is NaN; packed padding stays 0 (the trajectory
        # pin rationale of attacks._fault_fill).
        byz = attack_lib._fault_fill(
            lambda m: jnp.full_like(m, jnp.nan), mean, spec)
    elif name == "inf_overflow":
        byz = attack_lib._fault_fill(
            lambda m: jnp.where(m < 0, -attack_lib.OVERFLOW_MAGNITUDE,
                                attack_lib.OVERFLOW_MAGNITUDE
                                ).astype(m.dtype), mean, spec)
    elif name == "bitflip":
        # Seeded coordinate corruption, hashed per SENDER: (R, S, ...)
        # payloads built from each receiver's neighborhood mean.
        byz = attack_lib.bitflip_edges(
            mean, jnp.arange(mask.shape[1], dtype=jnp.int32),
            prob=cfg.bitflip_prob, seed=cfg.bitflip_seed, spec=spec)
    elif name == "gaussian":
        if key is None:
            raise ValueError("gaussian attack needs a key")
        std = jnp.sqrt(cfg.gaussian_variance)
        s = mask.shape[1]
        if spec is not None:
            byz = jax.tree_util.tree_map(
                lambda m: m[:, None] + attack_lib.packed_gaussian_noise(
                    spec, key, (r, s), std), mean)
        else:
            leaves, treedef = jax.tree_util.tree_flatten(mean)
            keys = jax.random.split(key, len(leaves))
            byz = jax.tree_util.tree_unflatten(treedef, [
                m[:, None] + std * jax.random.normal(
                    k, (r, s) + m.shape[1:], jnp.float32)
                for m, k in zip(leaves, keys)])
    else:
        # Reachable for a name that IS in the registry: every attack needs
        # an explicit per-edge generalization here (receiver-local stats),
        # so a newly registered master-path attack fails loudly with the
        # gap named instead of silently passing through unattacked.
        raise NotImplementedError(
            f"attack {name!r} is registered in core.attacks but has no "
            "per-edge decentralized form in topology.build_exchange -- add "
            "its receiver-neighborhood construction here")

    def select(z, bz):
        zb = jnp.broadcast_to(z[None].astype(jnp.float32),
                              (r,) + z.shape)
        # Per-receiver attack values broadcast over senders unless the
        # attack already drew per-edge values (gaussian).
        bz_rows = bz[:, None] if bz.ndim == z.ndim else bz
        sel = is_byz.reshape((1, -1) + (1,) * (z.ndim - 1))
        return jnp.where(sel, bz_rows, zb).astype(z.dtype)

    return jax.tree_util.tree_map(select, msgs, byz)


def _agg_opts(cfg, mixing, axis_names=(), sync_axes=()):
    return dict(
        max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
        num_groups=cfg.num_groups, trim=cfg.trim,
        num_byzantine=cfg.num_byzantine, clip_radius=cfg.clip_radius,
        mixing=mixing, axis_names=tuple(axis_names),
        sync_axes=tuple(sync_axes))


def validate_topology(cfg, topo: Topology, num_nodes: int) -> None:
    """Static feasibility checks against a FIXED graph (trace-time, so they
    raise with context instead of producing NaN aggregates).  Delegates to
    ``validate_schedule`` on the graph's static schedule, so the fixed and
    time-varying validation paths cannot drift apart."""
    validate_schedule(cfg, as_schedule(topo), num_nodes)


# ---------------------------------------------------------------------------
# Simulation path (single host, dense exchange)
# ---------------------------------------------------------------------------

def make_decentralized_step(
    loss_fn: Callable[[Pytree, Pytree], jnp.ndarray],
    worker_data: Pytree,
    cfg,
    optimizer: optim_lib.Optimizer,
    topology,
):
    """Build ``(init_fn, step_fn)`` for the simulated decentralized
    federation; drop-in shaped like
    :func:`repro.core.robust_step.make_federated_step` but with PER-NODE
    parameters.

    ``topology``: a fixed :class:`Topology` or a time-varying
    :class:`GraphSchedule` (DESIGN.md Sec. 7) -- round ``t`` uses the
    schedule's ``t % period`` graph, selected from stacked compile-time
    mask/mixing constants by the traced step counter.

    Gossip modes (``cfg.gossip``):

    * ``"gradient"`` (PR-3 behaviour) -- nodes exchange (variance-reduced)
      GRADIENT messages, robust-aggregate the masked neighborhood, and
      apply the optimizer to the aggregate;
    * ``"params"`` (arXiv:2308.05292's setting) -- each node first takes a
      LOCAL optimizer step with its own corrected gradient, then the
      half-stepped PARAMETERS are exchanged and each node's new iterate is
      the robust aggregate of its neighborhood's models.  Byzantine nodes
      poison the parameter channel per edge with the same receiver-local
      constructions (``build_exchange`` is message-agnostic).

    Graph nodes are ``N = W_h + B``: the first W_h ids are the honest
    workers (rows of ``worker_data``), the LAST B are Byzantine (matching
    the simulation convention of ``attacks.apply_attack``, which appends
    Byzantine rows; the distributed path replaces the FIRST B workers,
    matching ``apply_attack_stacked``).  State leaves carry a leading node
    axis: every node owns its own parameter/optimizer copy, and
    ``consensus_dist`` in the metrics tracks how far the honest copies have
    drifted apart.

    With ``cfg.num_clients > 0`` (DESIGN.md Sec. 10) ``worker_data`` holds
    one shard per VIRTUAL CLIENT -- (num_clients, J, ...) -- and each round
    a seeded cohort of ``cfg.cohort_size`` clients mans the W_h honest node
    slots: the cohort's data + VR-state rows are gathered into the round
    view, scattered back after, and the cohort's staleness counters weight
    the sender COLUMNS of the neighbor mask (exact down-weighting for the
    weight-based rules; with the default ``staleness_decay=1.0`` weights
    are 0/1 so the count-based rules' neighbor counts stay integral).
    Node parameters stay per-SLOT (the physical gossip network); clients
    contribute data and variance-reduction memory.
    """
    sched = as_schedule(topology)
    num_rows = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    if cfg.num_clients:
        if cfg.num_clients != num_rows:
            raise ValueError(
                f"num_clients={cfg.num_clients} but worker_data has "
                f"{num_rows} client shards")
        if not cfg.cohort_size:
            raise ValueError(
                "partial participation in the decentralized simulation "
                "needs an explicit cohort_size")
    from repro.core import participation as participation_lib
    plan = participation_lib.resolve_participation(
        cfg, cfg.cohort_size if cfg.num_clients else num_rows)
    wh = plan.cohort_size if plan is not None else num_rows
    num_clients = plan.num_clients if plan is not None else num_rows
    weighted = participation_lib.uses_staleness(cfg, plan)
    j = jax.tree_util.tree_leaves(worker_data)[0].shape[1]
    b = cfg.num_byzantine if cfg.attack != "none" else 0
    n = wh + b
    validate_schedule(cfg, sched, n)
    gossip = _check_gossip(cfg)
    grad_fn = jax.grad(loss_fn)
    attack_cfg = cfg.attack_config()
    reducer = cfg.reducer()
    wire_fmt = cfg.wire_format()
    if wire_fmt.quantized and not cfg.packed:
        raise ValueError(
            f"message_dtype={cfg.message_dtype!r} is a quantized wire "
            "format and needs the packed path (cfg.packed=True)")
    is_byz = jnp.arange(n) >= wh

    def sample_batch(data_w, idx):
        return jax.tree_util.tree_map(lambda d: d[idx], data_w)

    def per_worker_grad(params_w, data_w, idx):
        return grad_fn(params_w, sample_batch(data_w, idx))

    def full_local_grads(params_per_worker, data):
        """(W, ...) full local gradients at per-NODE honest params (the
        lsvrg anchor oracle); ``data`` rows pair with the param rows."""
        return jax.vmap(grad_fn)(params_per_worker, data)

    pack_fn = None
    if cfg.packed:
        def pack_fn(tree, batch_ndim):
            spec = cfg.message_spec(tree, batch_ndim=batch_ndim)
            return spec.pack(tree, batch_ndim=batch_ndim)

    def init_fn(params, key):
        nodes = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (n,) + p.shape) + 0, params)
        opt_state = optimizer.init(nodes)

        def per_sample_table():
            def worker_tab(data_w):
                return jax.vmap(
                    lambda jj: grad_fn(params, sample_batch(data_w, jj[None]))
                )(jnp.arange(j))
            return jax.vmap(worker_tab)(worker_data)

        # VR state covers the HONEST workers only (the first wh node ids;
        # Byzantine nodes fabricate messages, they keep no tables), in the
        # message layout -- same convention as the master path (Sec. 8).
        # Under partial participation the tables are resident PER CLIENT.
        vr_state = reducer.init_sim(
            params,
            per_sample_grads_fn=per_sample_table,
            full_grads_fn=lambda p: full_local_grads(
                jax.tree_util.tree_map(
                    lambda q: jnp.broadcast_to(
                        q[None], (num_clients,) + q.shape), p),
                worker_data),
            num_workers=num_clients, pack_fn=pack_fn)
        staleness = (participation_lib.init_staleness(num_clients)
                     if plan is not None else None)
        ef = None
        if wire_fmt.error_feedback:
            d = cfg.message_spec(params, batch_ndim=0).padded_dim
            ef = jnp.zeros((num_clients, d), jnp.float32)
        health = guards_lib.init_health() if cfg.guards else None
        return FederatedState(nodes, opt_state, vr_state,
                              jnp.zeros((), jnp.int32), key, staleness, ef,
                              health)

    def round_inputs(state):
        """The round's (data, vr rows, honest staleness, cohort) -- the
        participation layer's single gather (see robust_step)."""
        if plan is None:
            stal = jnp.zeros((wh,), jnp.int32) if weighted else None
            return worker_data, state.vr, stal, None
        cohort = plan.cohort_at(state.step)
        data = participation_lib.gather_rows(worker_data, cohort)
        vr_rows = (participation_lib.gather_rows(state.vr, cohort)
                   if reducer.stateful else state.vr)
        return data, vr_rows, jnp.take(state.staleness, cohort, axis=0), cohort

    def finish_round(state, cohort, vr_rows):
        if plan is None:
            return vr_rows, state.staleness
        vr_state = (participation_lib.scatter_rows(state.vr, cohort, vr_rows)
                    if reducer.stateful else vr_rows)
        return vr_state, participation_lib.tick_staleness(state.staleness,
                                                          cohort)

    def sender_weights(honest_stal):
        """(N,) staleness weights over the node/sender axis (honest slots
        first, Byzantine LAST -- the sim node-id convention), or None on the
        unweighted bit-exact path."""
        if not weighted:
            return None, None
        slot_stal = participation_lib.slot_staleness(
            honest_stal, cfg.attack, b, straggler_k=cfg.straggler_k,
            max_staleness=cfg.max_staleness)
        return participation_lib.staleness_weights(
            slot_stal, decay=cfg.staleness_decay,
            max_staleness=cfg.max_staleness), slot_stal

    def honest_grads(state, k_idx, data):
        honest_params = jax.tree_util.tree_map(lambda x: x[:wh], state.params)
        idx = reducer.draw_indices(k_idx, wh, j)
        if idx.ndim == 2:       # minibatch layout: (W, B) sample draws
            honest = jax.vmap(per_worker_grad)(honest_params, data, idx)
            return honest, idx
        honest = jax.vmap(
            lambda p, d, i: per_worker_grad(p, d, i[None])
        )(honest_params, data, idx)
        return honest, idx

    def correct(state, vr, honest, idx, k_idx, *, data, spec=None):
        """Route the honest nodes' raw gradients through the reducer (the
        snapshot oracles evaluate against each node's OWN params)."""
        if not reducer.stateful:
            return honest, vr, {}
        k_vr = jax.random.fold_in(k_idx, 1)   # DCE'd unless the reducer draws
        honest_params = jax.tree_util.tree_map(lambda x: x[:wh], state.params)

        def as_tree(x):
            return spec.unpack(x) if spec is not None else x

        def as_msgs(tree):
            return spec.pack(tree, batch_ndim=1) if spec is not None else tree

        def grads_at(snapshot):
            snap = as_tree(snapshot)
            return as_msgs(jax.vmap(
                lambda p, d, i: per_worker_grad(p, d, i[None])
            )(snap, data, idx))

        def full_grads_at(p):
            return as_msgs(full_local_grads(as_tree(p), data))

        return reducer.correct(
            vr, honest, idx, k_vr,
            params=as_msgs(honest_params),
            grads_at=grads_at, full_grads_at=full_grads_at)

    def consensus(params):
        # Consensus drift IS the honest-variance formula applied to the
        # honest nodes' parameter copies (telemetry helper, Sec. 11).
        xh = jax.tree_util.tree_map(lambda x: x[:wh], params)
        return telemetry.honest_variance(xh, wh)

    def step_fn_perleaf(state):
        """Pre-refactor per-leaf pipeline (cfg.packed=False): the bench
        baseline.  When staleness weights are active they multiply the
        sender COLUMNS of the round's mask (mask-as-weight: exact for the
        weight-based rules, exact mask-out for dropped senders) before both
        the per-edge attack statistics and the masked aggregation."""
        key, k_idx, k_attack = jax.random.split(state.key, 3)
        mask = sched.mask_at(state.step)
        mixing = sched.mixing_at(state.step)
        data, vr_rows, honest_stal, cohort = round_inputs(state)
        honest, idx = honest_grads(state, k_idx, data)
        honest, vr_rows, vr_metrics = correct(state, vr_rows, honest, idx,
                                              k_idx, data=data)
        vr_state, staleness = finish_round(state, cohort, vr_rows)
        sw, slot_stal = sender_weights(honest_stal)
        wmask = mask if sw is None else mask * sw[None, :]

        # Honest-message variance (same metric as the master path).
        var = telemetry.honest_variance(honest, wh)

        # Byzantine node rows carry zeros until the attack replaces them.
        msgs = jax.tree_util.tree_map(
            lambda g: jnp.zeros((n,) + g.shape[1:], g.dtype).at[:wh].set(g),
            honest)

        guard_info = {}

        def gossip_agg(wire):
            exchange = build_exchange(wire, attack_cfg, wmask, is_byz,
                                      k_attack)
            gw = wmask
            if cfg.guards:
                # Per-edge containment (DESIGN.md Sec. 13): each receiver
                # quarantines its non-finite / over-magnitude in-edges; the
                # (R, S) validity mask folds into the neighbor mask (weight
                # exactly 0, clean rounds keep wmask bitwise).
                emask = guards_lib.pairwise_guard_mask(
                    exchange, wmask, multiplier=cfg.guard_multiplier)
                exchange = guards_lib.sanitize_rows(exchange, emask)
                gw = wmask * emask
                guard_info["quarantined_edges"] = jnp.sum(
                    (wmask > 0) * (1.0 - emask))
            out = masked_aggregate(
                cfg.aggregator, exchange, gw, perleaf=True,
                diagnostics=cfg.diagnostics,
                **_agg_opts(cfg, mixing * gw))
            return out if cfg.diagnostics else (out, None)

        if gossip == "params":
            # Local step first, then robust PARAMETER gossip: the messages
            # on the wire are each node's half-stepped model.
            updates, opt_state = optimizer.update(
                msgs, state.opt_state, state.params, state.step)
            half = optim_lib.apply_updates(state.params, updates)
            params, diag = gossip_agg(half)
            watch = params
        else:
            agg, diag = gossip_agg(msgs)
            updates, opt_state = optimizer.update(
                agg, state.opt_state, state.params, state.step)
            params = optim_lib.apply_updates(state.params, updates)
            watch = agg

        health = state.health
        if cfg.guards:
            # Round verdict on the gossip output's global norm; a rejected
            # round holds every node's params/opt/VR (same hold semantics
            # as the master step).
            accept, health = guards_lib.round_verdict(
                guards_lib.tree_norm(watch), state.health,
                decay=cfg.reject_ema, zmax=cfg.reject_zmax,
                warmup=cfg.reject_warmup)
            params, opt_state, vr_state = guards_lib.select_tree(
                accept, (params, opt_state, vr_state),
                (state.params, state.opt_state, state.vr))
            guard_info.update(telemetry.health_metrics(health, accept))

        new_state = FederatedState(params, opt_state, vr_state,
                                   state.step + 1, key, staleness, state.ef,
                                   health)
        metrics = {"honest_variance": var,
                   "consensus_dist": consensus(params), **vr_metrics,
                   **telemetry.staleness_metrics(slot_stal), **guard_info}
        if diag is not None:
            metrics.update(telemetry.diagnostics_metrics(
                telemetry.reduce_masked_diagnostics(diag, wmask)))
        return new_state, metrics

    def step_fn_packed(state):
        """Flat-packed pipeline (DESIGN.md Sec. 8): one (N, D) message
        buffer feeds the per-edge attack and the masked flat engine; the
        dense (N, N, D) exchange replaces the per-leaf exchange tensors.
        Staleness weights multiply the mask's sender columns, as in the
        per-leaf step."""
        key, k_idx, k_attack = jax.random.split(state.key, 3)
        mask = sched.mask_at(state.step)
        mixing = sched.mixing_at(state.step)
        data, vr_rows, honest_stal, cohort = round_inputs(state)
        honest_tree, idx = honest_grads(state, k_idx, data)
        spec = cfg.message_spec(honest_tree, batch_ndim=1)
        honest = spec.pack(honest_tree)                        # (W_h, D)
        honest, vr_rows, vr_metrics = correct(state, vr_rows, honest, idx,
                                              k_idx, data=data, spec=spec)
        vr_state, staleness = finish_round(state, cohort, vr_rows)
        sw, slot_stal = sender_weights(honest_stal)
        wmask = mask if sw is None else mask * sw[None, :]

        ef_state = state.ef

        def wire_transmit(rows):
            """Honest senders' wire step (DESIGN.md Sec. 12): fold in / bank
            the error-feedback residual and return the dequantized wire rows
            the neighbors would see.  Identity for the float formats.  The
            per-edge Byzantine payloads stay f32 -- build_exchange replaces
            Byzantine sender entries wholesale, so there is no honest wire
            to constrain them to (the master paths DO re-quantize their
            single shared attack vector)."""
            nonlocal ef_state
            if not wire_fmt.quantized:
                return rows
            ef_rows = state.ef
            if wire_fmt.error_feedback and plan is not None:
                ef_rows = participation_lib.gather_rows(state.ef, cohort)
            rows, ef_rows = spec.transmit(rows, ef_rows)
            if wire_fmt.error_feedback:
                ef_state = (participation_lib.scatter_rows(
                    state.ef, cohort, ef_rows)
                    if plan is not None else ef_rows)
            return rows

        if gossip == "gradient":
            # Gradient gossip transmits the VR-corrected gradients; params
            # gossip keeps them local and transmits the half-stepped models
            # (below), so only ONE of the two channels pays the wire.
            honest = wire_transmit(honest)

        var = telemetry.honest_variance(honest, wh)

        # Byzantine node rows carry zeros until the attack replaces them.
        msgs = jnp.zeros((n,) + honest.shape[1:], honest.dtype).at[:wh].set(honest)

        guard_info = {}

        def flat_gossip(wire_buf):
            exchange = build_exchange(wire_buf, attack_cfg, wmask, is_byz,
                                      k_attack, spec=spec)     # (N, N, D)
            gw = wmask
            if cfg.guards:
                # Per-edge containment on the dequantized wire (same fold
                # as the per-leaf step; guard AFTER the wire roundtrip so
                # the mask judges what the rules consume).
                emask = guards_lib.pairwise_guard_mask(
                    exchange, wmask, multiplier=cfg.guard_multiplier)
                exchange = guards_lib.sanitize_rows(exchange, emask)
                gw = wmask * emask
                guard_info["quarantined_edges"] = jnp.sum(
                    (wmask > 0) * (1.0 - emask))
            out = masked_aggregate_flat(
                cfg.aggregator, exchange, gw, spec=spec,
                diagnostics=cfg.diagnostics,
                **_agg_opts(cfg, mixing * gw))                 # (N, D) f32
            out, diag = out if cfg.diagnostics else (out, None)
            return spec.unpack(out, batch_ndim=1), diag

        if gossip == "params":
            updates, opt_state = optimizer.update(
                spec.unpack(msgs, batch_ndim=1), state.opt_state,
                state.params, state.step)
            half = optim_lib.apply_updates(state.params, updates)
            wire = spec.pack(half)                             # (N, D)
            # Honest nodes transmit their half-stepped model over the
            # quantized wire (EF residuals track the PARAM channel here);
            # sim node arrays are not mesh-sharded, so the row slice is
            # safe (the old-XLA hazard only bites sharded worker axes).
            wire = wire.at[:wh].set(wire_transmit(wire[:wh]))
            params, diag = flat_gossip(wire)
            watch = params
        else:
            agg, diag = flat_gossip(msgs)
            updates, opt_state = optimizer.update(
                agg, state.opt_state, state.params, state.step)
            params = optim_lib.apply_updates(state.params, updates)
            watch = agg

        health = state.health
        if cfg.guards:
            # Round verdict + hold (same semantics as the per-leaf step).
            accept, health = guards_lib.round_verdict(
                guards_lib.tree_norm(watch), state.health,
                decay=cfg.reject_ema, zmax=cfg.reject_zmax,
                warmup=cfg.reject_warmup)
            params, opt_state, vr_state, ef_state = guards_lib.select_tree(
                accept, (params, opt_state, vr_state, ef_state),
                (state.params, state.opt_state, state.vr, state.ef))
            guard_info.update(telemetry.health_metrics(health, accept))

        new_state = FederatedState(params, opt_state, vr_state,
                                   state.step + 1, key, staleness, ef_state,
                                   health)
        metrics = {"honest_variance": var,
                   "consensus_dist": consensus(params), **vr_metrics,
                   **telemetry.staleness_metrics(slot_stal), **guard_info}
        if diag is not None:
            metrics.update(telemetry.diagnostics_metrics(
                telemetry.reduce_masked_diagnostics(diag, wmask)))
        return new_state, metrics

    return init_fn, (step_fn_packed if cfg.packed else step_fn_perleaf)


# ---------------------------------------------------------------------------
# Distributed path (inside shard_map; one node per worker-axis index)
# ---------------------------------------------------------------------------

def decentralized_aggregate(
    grads: Pytree,
    cfg,
    topology,
    *,
    comm: str = "gather",
    worker_axes: tuple[str, ...] = ("data",),
    model_axes: tuple[str, ...] = ("model",),
    num_workers: int,
    key: Optional[jax.Array] = None,
    round_index: Optional[jax.Array] = None,
    use_topology_kernel: Optional[bool] = None,
    row_weights: Optional[jnp.ndarray] = None,
    diagnostics: Optional[bool] = None,
) -> Pytree:
    """Per-node robust neighborhood aggregation inside ``shard_map``.

    ``grads``: this node's message (leaves are local model shards) -- a
    gradient in gradient-gossip mode, the half-stepped parameters in
    params-gossip mode (the aggregation itself is message-agnostic).
    ``topology``: a fixed :class:`Topology` or a :class:`GraphSchedule`; a
    time-varying schedule needs the traced ``round_index`` to select the
    round's stacked mask/mixing constants (``lax.dynamic_index_in_dim``, no
    per-round retrace).  Nodes are the linear worker-axis indices
    (row-major over ``worker_axes``, the Sec. 2 convention); the FIRST
    ``cfg.num_byzantine`` nodes attack per edge.  Returns THIS node's
    aggregate (same local-shard geometry as the input) -- per-node results,
    unlike the master paths which return one shared aggregate.

    ``cfg.packed`` (default) packs the local shard once so the gather mode
    runs ONE collective + the flat masked engine on the (S, D) buffer; the
    sharded mode operates on coordinate slices either way (DESIGN.md
    Sec. 8).  ``use_topology_kernel`` routes the coordinate-separable
    masked trimmed-mean reduction of the SHARDED branch through the fused
    Pallas kernel ``kernels/topology.py`` (one HBM sweep, no sort; the
    mixing-weighted mean keeps the jnp path since the kernel reduces
    uniformly); default: on for TPU backends only, off elsewhere -- on
    CPU the interpret-mode kernel is slower than the jnp rules (it still
    runs under ``interpret=True`` when the flag is forced, for tests).

    ``diagnostics`` (default ``cfg.diagnostics``): when on, additionally
    returns the REPLICATED per-sender :class:`repro.telemetry.AggDiagnostics`
    summary (``reduce_masked_diagnostics`` folds the per-receiver fields
    with the psums matching each comm mode), so every node reports the
    same sender-suspicion trace.
    """
    if comm not in ("gather", "sharded"):
        raise ValueError(f"comm must be 'gather' or 'sharded', got {comm!r}")
    diag_on = (getattr(cfg, "diagnostics", False) if diagnostics is None
               else diagnostics)
    w = num_workers
    sched = as_schedule(topology)
    validate_schedule(cfg, sched, w)
    if not sched.is_static and round_index is None:
        raise ValueError(
            f"schedule {sched.name!r} is time-varying (period "
            f"{sched.period}); decentralized_aggregate needs round_index=")
    t = 0 if round_index is None else round_index
    attack_cfg = cfg.attack_config()
    mask_all = sched.mask_at(t)                               # (S, S)
    mixing_all = sched.mixing_at(t)
    if row_weights is not None:
        # Bounded-staleness weighting (DESIGN.md Sec. 10): the replicated
        # (S,) per-sender weights multiply the mask's sender COLUMNS, so
        # every receiver's masked rule down-weighs the same stale senders
        # and masks out the absent ones (mask-as-weight -- no sender-axis
        # slicing).
        mask_all = mask_all * row_weights.astype(jnp.float32)[None, :]
    is_byz = jnp.arange(w) < cfg.num_byzantine
    wid = compat.axis_index(worker_axes)
    packed = getattr(cfg, "packed", True)
    wire_fmt = packing.resolve_wire_format(
        getattr(cfg, "message_dtype", "float32"))
    if wire_fmt.quantized and not packed:
        raise ValueError(
            f"message_dtype={cfg.message_dtype!r} is a quantized wire "
            "format and needs the packed path (cfg.packed=True)")

    if comm == "gather":
        mask_row = jnp.take(mask_all, wid, axis=0)[None]      # (1, S)
        mix_row = jnp.take(mixing_all, wid, axis=0)[None]
        k = jax.random.fold_in(key, wid) if key is not None else None
        if packed:
            # One collective: pack the local shard, gather the (S, D_shard)
            # buffer, run the flat masked engine on this node's row.
            spec = cfg.message_spec(grads, batch_ndim=0)
            buf = spec.pack(grads, batch_ndim=0)
            if spec.quantized:
                # The quantized buffer crosses the wire; the receiver
                # dequantizes BEFORE building the exchange, so the per-edge
                # attacks observe the dequantized honest messages -- the
                # same view the sim path's build_exchange gets.
                codes, scales = spec.encode(buf, axis_names=model_axes)
                stacked = spec.decode(
                    compat.all_gather(codes, worker_axes, axis=0,
                                      tiled=False),
                    compat.all_gather(scales, worker_axes, axis=0,
                                      tiled=False))
            else:
                stacked = compat.all_gather(buf, worker_axes, axis=0,
                                            tiled=False)
            exchange = build_exchange(stacked, attack_cfg, mask_row, is_byz,
                                      k, spec=spec)           # (1, S, D)
            gm_row = mask_row
            if getattr(cfg, "guards", False):
                # Per-edge containment (DESIGN.md Sec. 13): this node's
                # (1, S) validity mask -- coordinate partials psum over the
                # MODEL axes (the gathered rows are model shards).
                emask = guards_lib.pairwise_guard_mask(
                    exchange, mask_row, multiplier=cfg.guard_multiplier,
                    axis_names=model_axes)
                exchange = guards_lib.sanitize_rows(exchange, emask)
                gm_row = mask_row * emask
            agg = masked_aggregate_flat(
                cfg.aggregator, exchange, gm_row, spec=spec,
                diagnostics=diag_on,
                **_agg_opts(cfg, mix_row * gm_row,
                            axis_names=model_axes, sync_axes=worker_axes))
            if diag_on:
                agg, diag = agg
                # Each device holds ONE receiver row; the cross-receiver
                # folds psum over the worker axes.
                return (spec.unpack(agg[0], batch_ndim=0),
                        telemetry.reduce_masked_diagnostics(
                            diag, mask_row, axis_names=worker_axes))
            return spec.unpack(agg[0], batch_ndim=0)
        stacked = jax.tree_util.tree_map(
            lambda g: compat.all_gather(g, worker_axes, axis=0, tiled=False),
            grads)
        exchange = build_exchange(stacked, attack_cfg, mask_row, is_byz, k)
        gm_row = mask_row
        if getattr(cfg, "guards", False):
            emask = guards_lib.pairwise_guard_mask(
                exchange, mask_row, multiplier=cfg.guard_multiplier,
                axis_names=model_axes)
            exchange = guards_lib.sanitize_rows(exchange, emask)
            gm_row = mask_row * emask
        agg = masked_aggregate(
            cfg.aggregator, exchange, gm_row, perleaf=True,
            diagnostics=diag_on,
            **_agg_opts(cfg, mix_row * gm_row,
                        axis_names=model_axes, sync_axes=worker_axes))
        if diag_on:
            agg, diag = agg
            return (jax.tree_util.tree_map(lambda a: a[0], agg),
                    telemetry.reduce_masked_diagnostics(
                        diag, mask_row, axis_names=worker_axes))
        return jax.tree_util.tree_map(lambda a: a[0], agg)

    # comm == "sharded": reuse the coordinate-resharding plumbing of
    # robust_step.sharded_aggregate, but aggregate ALL receivers' masked
    # neighborhoods on this device's slice and route each receiver its own
    # result with a second all_to_all (DESIGN.md Sec. 6).
    flat, unflatten, leaf_sizes = _flatten_concat(grads)
    p = flat.shape[0]
    pad = (-p) % w
    if wire_fmt.quantized:
        # Quantized coordinates through the first all_to_all (the comm
        # volume win): encode the full local message (block stats over the
        # model axes), ship int8 slices + the (S, num_leaves) scales, and
        # dequantize this device's slice per-coordinate BEFORE the per-edge
        # attack -- so attacks observe the dequantized honest wire.  The
        # second all_to_all (each receiver collecting its own aggregate)
        # routes f32 results, unchanged.
        wspec = packing.pack_spec(grads, batch_ndim=0, wire=wire_fmt)
        codes, scales = wspec.encode(flat, axis_names=model_axes)
        z_codes = compat.all_to_all(
            jnp.pad(codes, (0, pad)).reshape(w, -1), worker_axes,
            split_axis=0, concat_axis=0, tiled=False).reshape(w, -1)
        z_local = packing.dequantize_slice(
            z_codes,
            compat.all_gather(scales, worker_axes, axis=0, tiled=False),
            _local_leaf_ids(leaf_sizes, pad, w, worker_axes))
    else:
        flat = jnp.pad(flat, (0, pad))
        z_local = compat.all_to_all(flat.reshape(w, -1), worker_axes,
                                    split_axis=0, concat_axis=0, tiled=False)
        z_local = z_local.reshape(w, -1)                      # (S, chunk)
    comm_axes = tuple(worker_axes) + tuple(model_axes)
    k = jax.random.fold_in(key, wid) if key is not None else None
    exchange = build_exchange(z_local, attack_cfg, mask_all,
                              is_byz, k)                      # (S, S, chunk)
    gm_all = mask_all
    if getattr(cfg, "guards", False):
        # All receivers' (S, S) validity mask at once: the slice-local
        # partial stats psum over worker+model axes, so every device holds
        # the same replicated mask and the per-receiver folds agree.
        emask = guards_lib.pairwise_guard_mask(
            exchange, mask_all, multiplier=cfg.guard_multiplier,
            axis_names=comm_axes)
        exchange = guards_lib.sanitize_rows(exchange, emask)
        gm_all = mask_all * emask
    diag = None
    if cfg.aggregator == "geomed_blockwise":
        seg = _local_leaf_ids(leaf_sizes, pad, w, worker_axes)
        agg = masked_weiszfeld_segments(
            exchange, gm_all, seg, len(leaf_sizes) + 1,
            axis_names=comm_axes, max_iters=cfg.weiszfeld_iters,
            tol=cfg.weiszfeld_tol)
        if diag_on:
            # Generic distance/weight diagnostics against the segmented
            # aggregate (the per-block loop exposes no iteration info;
            # the neutral residual/iters defaults apply).
            diag = telemetry.masked_diagnostics(
                exchange, agg, gm_all, axis_names=comm_axes)
    elif diag_on:
        out = masked_aggregate_flat(
            cfg.aggregator, exchange, gm_all, diagnostics=True,
            **_agg_opts(cfg, mixing_all * gm_all,
                        axis_names=comm_axes))
        agg, diag = out
    elif _use_topology_kernel(use_topology_kernel) and (
            cfg.aggregator == "trimmed_mean") and row_weights is None:
        # (The fused kernel reduces by 0/1 mask counts, so fractional
        # staleness weights route to the jnp masked engine instead; the
        # guard mask stays 0/1, so guarded rounds keep the kernel.)
        # PR-3 leftover closed: the fused Pallas masked-neighborhood
        # reduction runs the coordinate-separable trimmed mean on the
        # (R, S, chunk) exchange slab in ONE HBM sweep -- no sort, no mask
        # broadcast materialization.  Slice-local (no psums needed:
        # coordinate-separable), so it drops straight into shard_map.
        from repro.kernels import ops as kernel_ops
        agg = kernel_ops.masked_neighbor_reduce(
            exchange, gm_all, trim=cfg.trim)
    else:
        agg = masked_aggregate_flat(
            cfg.aggregator, exchange, gm_all,
            **_agg_opts(cfg, mixing_all * gm_all,
                        axis_names=comm_axes))
    agg = agg.astype(jnp.float32)                             # (R, chunk)
    mine = compat.all_to_all(agg, worker_axes, split_axis=0,
                             concat_axis=0, tiled=False).reshape(-1)
    if diag_on:
        # The (R, S) fields already carry full-vector geometry (their sq
        # partials psum'd over comm_axes) and every device holds ALL
        # receiver rows, so the fold needs no further psum.
        return unflatten(mine[:p]), telemetry.reduce_masked_diagnostics(
            diag, mask_all)
    return unflatten(mine[:p])


def _use_topology_kernel(flag: Optional[bool]) -> bool:
    """Resolve the fused-kernel routing default: explicit flag wins; else
    on TPU only -- the Mosaic backend the kernel is shaped for.  On CPU
    the interpret-mode kernel is a correctness harness, not a speedup,
    and other backends (GPU/Triton) have never lowered it."""
    if flag is not None:
        return flag
    return jax.default_backend() == "tpu"
