"""Network topologies for decentralized Byzantine-robust training.

Byrd-SAGA's federation is an implicit STAR: one master aggregates every
worker's message.  This module makes the communication graph explicit so the
same robust-aggregation machinery runs server-free (Peng/Li/Ling 2023,
arXiv:2308.05292): a :class:`Topology` carries

* ``adjacency``     -- (N, N) bool, symmetric, zero diagonal;
* ``mixing``        -- (N, N) float64 Metropolis-Hastings weights
                       ``W_ij = 1 / (1 + max(deg_i, deg_j))`` for edges,
                       ``W_ii = 1 - sum_j W_ij``: symmetric and DOUBLY
                       stochastic by construction, so plain-mean gossip
                       preserves the honest average;
* ``neighbor_mask`` -- (N, N) float32 with self-loops,
                       ``mask[i, j] = 1  iff  j in N(i) or j == i``:
                       the per-node restriction every masked aggregator in
                       :mod:`repro.topology.masked` consumes (mask-select,
                       never slice+concat -- DESIGN.md Sec. 1).

Everything is plain numpy, computed once at trace time: masks and mixing
rows enter jit as compile-time constants.

Constructors (registry-driven like the aggregators/attacks):
``ring``, ``torus2d``, ``complete``, ``erdos_renyi(p, seed)``, and ``star``
for backward compatibility (node 0 is the hub; routing a star topology
through the training entry points reproduces the master path bit-exactly --
DESIGN.md Sec. 6).

The spectral gap ``1 - |lambda_2(mixing)|`` (reported by
:func:`Topology.describe`) governs the consensus rate: complete > torus2d >
ring at equal N.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """An undirected communication graph plus its gossip matrices."""

    name: str
    num_nodes: int
    adjacency: np.ndarray  # (N, N) bool, symmetric, zero diagonal

    def __post_init__(self):
        adj = np.asarray(self.adjacency, bool)
        n = self.num_nodes
        if adj.shape != (n, n):
            raise ValueError(f"adjacency must be ({n}, {n}), got {adj.shape}")
        if adj.diagonal().any():
            raise ValueError("adjacency must have a zero diagonal "
                             "(self-loops live in neighbor_mask)")
        if not (adj == adj.T).all():
            raise ValueError("adjacency must be symmetric (undirected graph)")
        object.__setattr__(self, "adjacency", adj)

    @property
    def degrees(self) -> np.ndarray:
        """(N,) neighbor counts, self excluded."""
        return self.adjacency.sum(axis=1).astype(np.int64)

    @property
    def neighbor_mask(self) -> np.ndarray:
        """(N, N) float32 mask with self-loops: row i selects N(i) + {i}."""
        return (self.adjacency | np.eye(self.num_nodes, dtype=bool)).astype(
            np.float32)

    @property
    def mixing(self) -> np.ndarray:
        """(N, N) float64 Metropolis-Hastings weights (symmetric, doubly
        stochastic): ``1 / (1 + max(deg_i, deg_j))`` on edges, the residual
        mass on the diagonal."""
        n = self.num_nodes
        deg = self.degrees
        w = np.where(self.adjacency,
                     1.0 / (1.0 + np.maximum(deg[:, None], deg[None, :])),
                     0.0)
        w[np.arange(n), np.arange(n)] = 1.0 - w.sum(axis=1)
        return w

    @property
    def min_neighborhood(self) -> int:
        """Smallest neighborhood size INCLUDING self (= min degree + 1):
        the bound per-node trimmed_mean / krum feasibility checks use."""
        return int(self.degrees.min()) + 1

    def is_connected(self) -> bool:
        return _connected(self.adjacency)

    def spectral_gap(self) -> float:
        """``1 - |lambda_2|`` of the mixing matrix (symmetric, so eigvalsh);
        larger gap = faster consensus.  A disconnected graph reports 0."""
        lam = np.linalg.eigvalsh(self.mixing)
        mags = np.sort(np.abs(lam))
        return float(1.0 - mags[-2]) if self.num_nodes > 1 else 1.0

    def describe(self) -> dict:
        """The spectral-gap report (demo / benchmark / log line)."""
        deg = self.degrees
        return {
            "name": self.name,
            "num_nodes": self.num_nodes,
            "num_edges": int(self.adjacency.sum()) // 2,
            "degree_min": int(deg.min()),
            "degree_max": int(deg.max()),
            "degree_mean": float(deg.mean()),
            "connected": self.is_connected(),
            "spectral_gap": self.spectral_gap(),
        }


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    seen[0] = True
    frontier = adj[0].copy()
    while frontier.any():
        seen |= frontier
        frontier = (adj[frontier].any(axis=0)) & ~seen
    return bool(seen.all())


def _check_n(name: str, n: int, minimum: int = 2) -> None:
    if n < minimum:
        raise ValueError(f"{name} topology needs >= {minimum} nodes, got {n}")


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def ring(num_nodes: int) -> Topology:
    """Cycle graph: node i talks to i +- 1 (mod N)."""
    _check_n("ring", num_nodes)
    adj = np.zeros((num_nodes, num_nodes), bool)
    idx = np.arange(num_nodes)
    adj[idx, (idx + 1) % num_nodes] = True
    adj[(idx + 1) % num_nodes, idx] = True
    np.fill_diagonal(adj, False)  # num_nodes == 2: the two edges coincide
    return Topology("ring", num_nodes, adj)


def torus2d(num_nodes: int, *, rows: Optional[int] = None) -> Topology:
    """2-D torus (wrap-around grid, degree <= 4).  ``rows`` defaults to the
    largest divisor of N at most sqrt(N); a prime N has no non-trivial grid,
    so it is rejected (use ``ring``)."""
    _check_n("torus2d", num_nodes, 4)
    if rows is None:
        rows = max(d for d in range(1, int(math.isqrt(num_nodes)) + 1)
                   if num_nodes % d == 0)
    if num_nodes % rows != 0:
        raise ValueError(f"torus2d: rows={rows} does not divide N={num_nodes}")
    cols = num_nodes // rows
    if rows == 1 or cols == 1:
        raise ValueError(
            f"torus2d: N={num_nodes} only factors as a 1-wide grid "
            "(prime N?); use the ring topology instead")
    adj = np.zeros((num_nodes, num_nodes), bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for j in (((r + 1) % rows) * cols + c,
                      r * cols + (c + 1) % cols):
                if i != j:
                    adj[i, j] = adj[j, i] = True
    return Topology("torus2d", num_nodes, adj)


def complete(num_nodes: int) -> Topology:
    """Fully connected: every node sees every message (the decentralized
    graph closest to the master's view)."""
    _check_n("complete", num_nodes)
    adj = ~np.eye(num_nodes, dtype=bool)
    return Topology("complete", num_nodes, adj)


def erdos_renyi(num_nodes: int, *, p: float = 0.5, seed: int = 0,
                max_tries: int = 64, require_connected: bool = True) -> Topology:
    """G(N, p) with each edge drawn i.i.d. Bernoulli(p) from a seeded numpy
    Generator.  Deterministic in (N, p, seed).  A disconnected draw is
    rejected and redrawn (fresh substream, same seed) up to ``max_tries``
    times; persistent disconnection (tiny p) raises with the fix spelled
    out rather than silently densifying the graph.  With
    ``require_connected=False`` the FIRST draw is returned as-is -- the
    time-varying schedules (``topology/schedule.py``) legitimately use
    disconnected rounds and validate connectivity over a window instead."""
    _check_n("erdos_renyi", num_nodes)
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"erdos_renyi: p must be in [0, 1], got {p}")
    rng = np.random.default_rng(np.random.SeedSequence([num_nodes, seed]))
    for _ in range(max_tries):
        upper = rng.random((num_nodes, num_nodes)) < p
        adj = np.triu(upper, k=1)
        adj = adj | adj.T
        if not require_connected or _connected(adj):
            return Topology("erdos_renyi", num_nodes, adj)
    raise ValueError(
        f"erdos_renyi(N={num_nodes}, p={p}, seed={seed}): no connected draw "
        f"in {max_tries} tries -- raise p (connectivity threshold ~ ln(N)/N) "
        "or pick another seed")


def star(num_nodes: int) -> Topology:
    """Hub-and-spokes, node 0 the hub: the paper's master federation as a
    graph.  Training entry points special-case this name onto the existing
    master path so ``topology='star'`` is bit-exact with the status quo
    (DESIGN.md Sec. 6)."""
    _check_n("star", num_nodes)
    adj = np.zeros((num_nodes, num_nodes), bool)
    adj[0, 1:] = True
    adj[1:, 0] = True
    return Topology("star", num_nodes, adj)


# name -> builder(num_nodes, **opts).  TOPOLOGY_NAMES and the unknown-name
# error derive from this dict (same pattern as the aggregator and attack
# registries): registering here is the ONE place a topology is added.
_TOPOLOGIES: dict[str, Callable[..., Topology]] = {
    "ring": ring,
    "torus2d": torus2d,
    "complete": complete,
    "erdos_renyi": erdos_renyi,
    "star": star,
}

TOPOLOGY_NAMES = tuple(_TOPOLOGIES)


def get_topology(name: str, num_nodes: int, *, seed: int = 0,
                 p: float = 0.5) -> Topology:
    """Build a topology by name.  ``seed``/``p`` only reach the constructors
    that take them (``erdos_renyi``)."""
    try:
        build = _TOPOLOGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r}; known: "
            f"{', '.join(sorted(_TOPOLOGIES))}") from None
    if name == "erdos_renyi":
        return build(num_nodes, p=p, seed=seed)
    return build(num_nodes)
