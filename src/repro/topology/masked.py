"""Masked-neighborhood counterparts of every registry aggregator.

Decentralized training has no master: node i robustly aggregates only the
messages of its graph neighborhood N(i) + {i}.  Every rule here consumes

* ``exchange`` -- pytree whose leaves are ``(R, S, *shape)``: row r is what
  RECEIVER r sees from each of the S senders (per-edge Byzantine attacks
  make the sender axis receiver-dependent, hence the dense layout);
* ``mask``     -- ``(R, S)`` float32 neighbor mask (``Topology.neighbor_mask``
  rows): ``mask[r, s] = 0`` senders must not influence receiver r's result;

and returns the aggregated pytree with leaves ``(R, *shape)`` in the input
dtypes.  Restriction is MASK-SELECT everywhere -- non-neighbors are weighted
to zero, +-inf-filled out of sorts, or masked out of pairwise distances --
never a slice+concat of the sender axis, which both breaks under vmap/SPMD
sharding and has miscompiled on old XLA partitioners (DESIGN.md Sec. 1).

With a full mask (and no mixing weights) every rule reduces exactly to its
:mod:`repro.core.aggregators` counterpart -- pinned by
``tests/test_topology.py``.

Distributed execution (DESIGN.md Sec. 6): leaves may be coordinate shards
inside a ``shard_map``.  ``axis_names`` restores full-vector geometry by
psum-ing the per-(receiver, sender) squared-distance partials over those
mesh axes (the decentralized analogue of the Sec. 2 comm layouts), and
``sync_axes`` pmax-synchronizes the Weiszfeld stopping statistic so every
device's ``while_loop`` stays in collective lockstep (gather mode, where
each device iterates its own receiver's masked Weiszfeld).

Flat-packed execution (DESIGN.md Sec. 8): every rule here is generic over
the exchange "pytree", so passing the packed ``(R, S, D)`` buffer of
:mod:`repro.core.packing` runs the SAME code with ONE fused reduction per
step instead of one per leaf -- that is the flat masked engine behind
:func:`masked_aggregate_flat`, and the pytree :func:`masked_aggregate` is
a thin pack -> flat -> unpack shim over it.  The only rule that needs the
leaf layout is ``geomed_blockwise`` (per-leaf norms), which slices the
buffer at the spec's static block boundaries.  ``masked_aggregate(...,
perleaf=True)`` keeps the pre-refactor leaf-by-leaf dispatch (the bench
baseline).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import packing
from repro.core.geomed import WeiszfeldInfo
from repro.telemetry.diagnostics import masked_diagnostics

Pytree = Any

_DIST_FLOOR = 1e-8  # same smoothing floor as core/geomed.py


def _leaves32(exchange: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda z: z.astype(jnp.float32), exchange)


def _restore_dtypes(y: Pytree, exchange: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda yl, z: yl.astype(z.dtype), y, exchange)


def _weighted_mean(ex32: Pytree, weights: jnp.ndarray) -> Pytree:
    """Per-receiver weighted mean over the sender axis: weights (R, S)."""
    denom = jnp.maximum(jnp.sum(weights, axis=1), _DIST_FLOOR)  # (R,)

    def leaf(z):
        w = weights.reshape(weights.shape + (1,) * (z.ndim - 2))
        return jnp.sum(w * z, axis=1) / denom.reshape(
            (-1,) + (1,) * (z.ndim - 2))

    return jax.tree_util.tree_map(leaf, ex32)


def masked_mean(exchange: Pytree, mask: jnp.ndarray, *,
                mixing: Optional[jnp.ndarray] = None) -> Pytree:
    """Masked neighborhood mean.  With ``mixing`` (rows of the
    doubly-stochastic matrix) this is exactly one DGD gossip step; without,
    the uniform mean over the masked senders."""
    weights = mask if mixing is None else mixing * mask
    return _restore_dtypes(_weighted_mean(_leaves32(exchange), weights),
                           exchange)


def _masked_sorted(z: jnp.ndarray, mask: jnp.ndarray, fill: float):
    """Sort the sender axis with non-neighbors pushed to ``fill`` ends."""
    m = mask.reshape(mask.shape + (1,) * (z.ndim - 2))
    return jnp.sort(jnp.where(m > 0, z, fill), axis=1)


def masked_median(exchange: Pytree, mask: jnp.ndarray) -> Pytree:
    """Coordinate-wise median over each masked neighborhood (non-neighbors
    sorted out to +inf; the median index comes from the neighbor count)."""
    n = jnp.sum(mask, axis=1).astype(jnp.int32)  # (R,)

    def leaf(z):
        s = _masked_sorted(z.astype(jnp.float32), mask, jnp.inf)
        sel = lambda i: jnp.take_along_axis(
            s, i.reshape((-1, 1) + (1,) * (z.ndim - 2)), axis=1)[:, 0]
        return 0.5 * (sel((n - 1) // 2) + sel(n // 2))

    return _restore_dtypes(jax.tree_util.tree_map(leaf, exchange), exchange)


def masked_trimmed_mean(exchange: Pytree, mask: jnp.ndarray, *,
                        trim: int) -> Pytree:
    """Coordinate-wise trimmed mean per neighborhood: drop the ``trim``
    largest and smallest masked entries per coordinate, average the rest.
    Callers must guarantee every neighborhood has > 2*trim members
    (``decentralized_step`` validates against the static topology)."""
    n = jnp.sum(mask, axis=1).astype(jnp.int32)  # (R,)

    def leaf(z):
        s = _masked_sorted(z.astype(jnp.float32), mask, jnp.inf)
        ranks = jnp.arange(s.shape[1]).reshape((1, -1) + (1,) * (z.ndim - 2))
        hi = (n - trim).reshape((-1, 1) + (1,) * (z.ndim - 2))
        keep = (ranks >= trim) & (ranks < hi)
        kept = jnp.where(keep, s, 0.0)
        denom = jnp.maximum(n - 2 * trim, 1).reshape((-1,) + (1,) * (z.ndim - 2))
        return jnp.sum(kept, axis=1) / denom

    return _restore_dtypes(jax.tree_util.tree_map(leaf, exchange), exchange)


def _sqdist_partials(ex32: Pytree, y: Pytree) -> jnp.ndarray:
    """Per-(receiver, sender) squared distances summed over leaves -> (R, S)
    (a PARTIAL over the local coordinate shard when inside shard_map)."""
    total = None
    for z, yl in zip(jax.tree_util.tree_leaves(ex32),
                     jax.tree_util.tree_leaves(y)):
        r, s = z.shape[:2]
        part = jnp.sum(
            (z.reshape(r, s, -1) - yl.reshape(r, 1, -1)) ** 2, axis=-1)
        total = part if total is None else total + part
    return total


def _global_delta(move: jnp.ndarray, axis_names: Sequence[str],
                  sync_axes: Sequence[str]) -> jnp.ndarray:
    """(R,) squared iterate moves -> replicated scalar stopping statistic."""
    if axis_names:
        move = compat.psum(move, tuple(axis_names))
    delta = jnp.sqrt(jnp.max(move))
    for ax in sync_axes:
        delta = jax.lax.pmax(delta, ax)
    return delta


def masked_weiszfeld(
    exchange: Pytree,
    mask: jnp.ndarray,
    *,
    max_iters: int = 64,
    tol: float = 1e-6,
    axis_names: Sequence[str] = (),
    sync_axes: Sequence[str] = (),
    return_info: bool = False,
) -> Pytree:
    """Per-receiver geometric median of the masked neighborhood, all
    receivers iterating in lockstep (one fused (R, S) distance psum per
    iteration when sharded).  Non-neighbors get zero Weiszfeld weight, so
    the restriction is exact, not approximate.  ``return_info=True``
    additionally returns the loop's :class:`...geomed.WeiszfeldInfo`
    (already in the while carry; the default return is unchanged)."""
    ex32 = _leaves32(exchange)
    y0 = _weighted_mean(ex32, mask)

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(it < max_iters, delta > tol)

    def body(state):
        y, _, it = state
        sq = _sqdist_partials(ex32, y)
        if axis_names:
            sq = compat.psum(sq, tuple(axis_names))
        inv = mask / jnp.maximum(jnp.sqrt(sq), _DIST_FLOOR)
        y_new = _weighted_mean(ex32, inv)
        move = None
        for a, b in zip(jax.tree_util.tree_leaves(y_new),
                        jax.tree_util.tree_leaves(y)):
            part = jnp.sum((a - b).reshape(a.shape[0], -1) ** 2, axis=-1)
            move = part if move is None else move + part
        return y_new, _global_delta(move, axis_names, sync_axes), it + 1

    y, delta, it = jax.lax.while_loop(
        cond, body, (y0, jnp.asarray(jnp.inf, jnp.float32), 0))
    out = _restore_dtypes(y, exchange)
    if return_info:
        return out, WeiszfeldInfo(residual=delta,
                                  iters=jnp.asarray(it, jnp.int32),
                                  converged=delta <= tol)
    return out


def masked_geomed_groups(
    exchange: Pytree, mask: jnp.ndarray, *, num_groups: int,
    max_iters: int = 64, tol: float = 1e-6,
    axis_names: Sequence[str] = (), sync_axes: Sequence[str] = (),
) -> Pytree:
    """Geomed of masked group means: senders keep their GLOBAL contiguous
    group ids (same ``(s * G) // S`` partition as ``aggregators.group_means``),
    each receiver mean-reduces the group members inside its neighborhood,
    and groups with no member there drop out via the group mask."""
    s_tot = mask.shape[1]
    gids = (np.arange(s_tot) * num_groups) // s_tot
    onehot = jnp.asarray(gids[None, :] == np.arange(num_groups)[:, None],
                         jnp.float32)                       # (G, S)
    counts = jnp.einsum("rs,gs->rg", mask, onehot)          # (R, G)
    gmask = (counts > 0).astype(jnp.float32)
    denom = jnp.maximum(counts, 1.0)

    def leaf(z):
        w = mask[:, None, :] * onehot[None, :, :]          # (R, G, S)
        flat = z.reshape(z.shape[0], z.shape[1], -1)
        grouped = jnp.einsum("rgs,rsc->rgc", w, flat) / denom[..., None]
        return grouped.reshape((z.shape[0], num_groups) + z.shape[2:])

    grouped = jax.tree_util.tree_map(leaf, _leaves32(exchange))
    y = masked_weiszfeld(grouped, gmask, max_iters=max_iters, tol=tol,
                         axis_names=axis_names, sync_axes=sync_axes)
    return _restore_dtypes(y, exchange)


def masked_geomed_blockwise(
    exchange: Pytree, mask: jnp.ndarray, *, max_iters: int = 64,
    tol: float = 1e-6, axis_names: Sequence[str] = (),
    sync_axes: Sequence[str] = (),
) -> Pytree:
    """Per-leaf masked geometric median (each parameter block aggregates its
    neighborhood independently; the leaves run their lockstep Weiszfeld
    loops one after another, each synchronized like ``masked_weiszfeld``)."""
    return jax.tree_util.tree_map(
        lambda z: masked_weiszfeld(
            [z], mask, max_iters=max_iters, tol=tol,
            axis_names=axis_names, sync_axes=sync_axes)[0],
        exchange)


def masked_krum(
    exchange: Pytree, mask: jnp.ndarray, *, num_byzantine: int,
    axis_names: Sequence[str] = (),
    return_scores: bool = False,
) -> Pytree:
    """Per-receiver Krum over the masked neighborhood: candidate scores sum
    the ``m_r - B - 2`` smallest pairwise distances BETWEEN neighborhood
    members (m_r = neighborhood size incl. self, a traced per-receiver
    count), and the winning sender's message is selected.  Sharded: the
    (R, S, S) Gram partials psum over ``axis_names``, so the selection index
    is replicated and each device keeps its own slice of the winner.

    Like the master path's ``aggregators.krum_scores``, the score width is
    clipped to >= 1 when a neighborhood is smaller than Krum's B + 3
    feasibility bound -- the rule still runs but its guarantee is VOID
    there: a node whose neighbors are mostly colluding Byzantine senders
    can be steered to select their (mutually close) poison.  Krum's
    breakdown condition is per-NEIGHBORHOOD on sparse graphs, so pick
    graphs with min degree >= B + 2 when using it (DESIGN.md Sec. 6)."""
    leaves = [z.reshape(z.shape[0], z.shape[1], -1).astype(jnp.float32)
              for z in jax.tree_util.tree_leaves(exchange)]
    flat = jnp.concatenate(leaves, axis=-1)                 # (R, S, C)
    sq = jnp.sum(flat ** 2, axis=-1)                        # (R, S)
    d2 = (sq[:, :, None] + sq[:, None, :]
          - 2.0 * jnp.einsum("rsc,rtc->rst", flat, flat))
    if axis_names:
        d2 = compat.psum(d2, tuple(axis_names))
    s_tot = mask.shape[1]
    pair = (mask[:, :, None] * mask[:, None, :]
            * (1.0 - jnp.eye(s_tot)[None]))
    d2 = jnp.where(pair > 0, jnp.maximum(d2, 0.0), jnp.inf)
    m_r = jnp.sum(mask, axis=1).astype(jnp.int32)           # (R,)
    n_near = jnp.clip(m_r - num_byzantine - 2, 1, jnp.maximum(m_r - 1, 1))
    ranks = jnp.arange(s_tot)[None, None, :]
    contrib = jnp.where(ranks < n_near[:, None, None],
                        jnp.sort(d2, axis=2), 0.0)
    scores = jnp.where(mask > 0, jnp.sum(contrib, axis=2), jnp.inf)
    best = jnp.argmin(scores, axis=1)                       # (R,)

    def leaf(z):
        idx = best.reshape((-1, 1) + (1,) * (z.ndim - 2))
        return jnp.take_along_axis(z, idx, axis=1)[:, 0]

    out = jax.tree_util.tree_map(leaf, exchange)
    if return_scores:
        return out, scores, best
    return out


def masked_centered_clip(
    exchange: Pytree, mask: jnp.ndarray, *, radius: float = 1.0,
    iters: int = 3, axis_names: Sequence[str] = (),
) -> Pytree:
    """Centered clipping per neighborhood: iterate from the masked median,
    each sender's influence clipped to ``radius`` by its full-vector
    residual norm ((R, S) psum over ``axis_names`` when sharded)."""
    ex32 = _leaves32(exchange)
    v = _leaves32(masked_median(exchange, mask))

    def one_iter(_, v):
        diffs = jax.tree_util.tree_map(
            lambda z, vl: z - vl[:, None], ex32, v)
        sq = None
        for dl in jax.tree_util.tree_leaves(diffs):
            part = jnp.sum(dl.reshape(dl.shape[0], dl.shape[1], -1) ** 2,
                           axis=-1)
            sq = part if sq is None else sq + part
        if axis_names:
            sq = compat.psum(sq, tuple(axis_names))
        scale = jnp.minimum(1.0, radius / jnp.maximum(jnp.sqrt(sq), 1e-12))
        # Influence-clipped masked mean: sum_s mask*scale*diff / sum_s mask.
        denom = jnp.maximum(jnp.sum(mask, axis=1), _DIST_FLOOR)
        w = mask * scale

        def leaf(vl, dl):
            ww = w.reshape(w.shape + (1,) * (dl.ndim - 2))
            return vl + jnp.sum(ww * dl, axis=1) / denom.reshape(
                (-1,) + (1,) * (dl.ndim - 2))

        return jax.tree_util.tree_map(leaf, v, diffs)

    v = jax.lax.fori_loop(0, iters, one_iter, v)
    return _restore_dtypes(v, exchange)


def masked_weiszfeld_segments(
    ex: jnp.ndarray,
    mask: jnp.ndarray,
    seg_ids: jnp.ndarray,
    num_segments: int,
    *,
    axis_names: Sequence[str],
    max_iters: int = 64,
    tol: float = 1e-6,
) -> jnp.ndarray:
    """Per-block masked Weiszfeld on coordinate slices: the decentralized
    counterpart of ``core/geomed.weiszfeld_blockwise_sharded``.

    ``ex``: (R, S, c) -- each receiver's view of every sender's slice on
    this device's coordinate range; ``seg_ids``: (c,) block id per local
    coordinate (padding coordinates carry the dummy id ``num_segments-1``).
    One fused (R, S, L) psum of per-(receiver, sender, block) distance
    partials per iteration over ``axis_names``.  Returns the (R, c) f32
    slice of every receiver's per-block medians.
    """
    ex32 = ex.astype(jnp.float32)

    def seg_psum(part):
        p = jax.ops.segment_sum(jnp.moveaxis(part, -1, 0), seg_ids,
                                num_segments=num_segments)
        p = jnp.moveaxis(p, 0, -1)
        return compat.psum(p, tuple(axis_names)) if axis_names else p

    denom0 = jnp.maximum(jnp.sum(mask, axis=1), _DIST_FLOOR)
    y0 = jnp.sum(mask[:, :, None] * ex32, axis=1) / denom0[:, None]

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(it < max_iters, delta > tol)

    def body(state):
        y, _, it = state
        diff = ex32 - y[:, None]                          # (R, S, c)
        sq = seg_psum(diff * diff)                        # (R, S, L)
        inv = mask[:, :, None] / jnp.maximum(jnp.sqrt(sq), _DIST_FLOOR)
        w_coord = inv[:, :, seg_ids]                      # (R, S, c)
        denom = jnp.sum(inv, axis=1)[:, seg_ids]          # (R, c)
        y_new = (jnp.sum(w_coord * ex32, axis=1)
                 / jnp.maximum(denom, _DIST_FLOOR))
        move = seg_psum((y_new - y) ** 2)                 # (R, L)
        return y_new, jnp.sqrt(jnp.max(move)), it + 1

    y, _, _ = jax.lax.while_loop(
        cond, body, (y0, jnp.asarray(jnp.inf, jnp.float32), 0))
    return y


# name -> masked rule.  Kept in bijection with the aggregator registry
# (tests/test_topology.py pins the key sets against each other), so a new
# registry aggregator fails loudly until its masked counterpart exists.
# Every rule is generic over the exchange pytree, so the same entry serves
# the per-leaf dispatch (pytree exchange) and the flat engine (packed
# (R, S, D) buffer) -- except geomed_blockwise, whose flat form needs the
# block boundaries (see masked_aggregate_flat).
_MASKED: dict[str, Any] = {
    "mean": lambda ex, m, o: masked_mean(ex, m, mixing=o.get("mixing")),
    "median": lambda ex, m, o: masked_median(ex, m),
    "trimmed_mean": lambda ex, m, o: masked_trimmed_mean(
        ex, m, trim=o.get("trim", 1)),
    "geomed": lambda ex, m, o: masked_weiszfeld(
        ex, m, max_iters=o.get("max_iters", 64), tol=o.get("tol", 1e-6),
        axis_names=o.get("axis_names", ()), sync_axes=o.get("sync_axes", ())),
    "geomed_groups": lambda ex, m, o: masked_geomed_groups(
        ex, m, num_groups=o["num_groups"],
        max_iters=o.get("max_iters", 64), tol=o.get("tol", 1e-6),
        axis_names=o.get("axis_names", ()), sync_axes=o.get("sync_axes", ())),
    "geomed_blockwise": lambda ex, m, o: masked_geomed_blockwise(
        ex, m, max_iters=o.get("max_iters", 64), tol=o.get("tol", 1e-6),
        axis_names=o.get("axis_names", ()), sync_axes=o.get("sync_axes", ())),
    "krum": lambda ex, m, o: masked_krum(
        ex, m, num_byzantine=o.get("num_byzantine", 0),
        axis_names=o.get("axis_names", ())),
    "centered_clip": lambda ex, m, o: masked_centered_clip(
        ex, m, radius=o.get("clip_radius", 1.0),
        axis_names=o.get("axis_names", ())),
}

MASKED_AGGREGATOR_NAMES = tuple(_MASKED)


def _check_masked_name(name: str) -> None:
    if name not in _MASKED:
        raise ValueError(
            f"unknown masked aggregator {name!r}; known: "
            f"{', '.join(sorted(_MASKED))}")


def masked_aggregate_flat(name: str, buf: jnp.ndarray, mask: jnp.ndarray,
                          *, spec: Optional[packing.PackSpec] = None,
                          diagnostics: bool = False,
                          **opts) -> jnp.ndarray:
    """Flat masked engine: packed ``(R, S, D)`` exchange buffer -> ``(R,
    D)`` float32 per-receiver aggregates.  One fused sender-axis reduction
    (and, sharded, one psum) per step instead of one per leaf.

    ``spec`` is required only by ``geomed_blockwise``: its per-leaf norms
    come from slicing the buffer at the spec's static block boundaries,
    each block running its own lockstep masked Weiszfeld like the per-leaf
    dispatch did.  Padding coordinates aggregate to zero.

    ``diagnostics=True`` returns ``(out, AggDiagnostics)`` with (R, S)
    receiver-by-sender ``dist``/``weight``/``score`` fields (DESIGN.md
    Sec. 11); False keeps every rule byte-identical.
    """
    _check_masked_name(name)
    b32 = buf.astype(jnp.float32)
    if name == "geomed_blockwise":
        if spec is None:
            raise ValueError(
                "masked_aggregate_flat('geomed_blockwise') needs spec= for "
                "the block boundaries (or use masked_weiszfeld_segments on "
                "coordinate slices)")
        parts, infos = [], []
        for a, b in spec.boundaries:
            part = masked_weiszfeld(
                b32[:, :, a:b], mask,
                max_iters=opts.get("max_iters", 64), tol=opts.get("tol", 1e-6),
                axis_names=opts.get("axis_names", ()),
                sync_axes=opts.get("sync_axes", ()),
                return_info=diagnostics)
            if diagnostics:
                part, info = part
                infos.append(info)
            parts.append(part)
        out = packing.assemble(parts, pad=spec.pad, batch_shape=buf.shape[:1])
        if diagnostics:
            return out, masked_diagnostics(
                b32, out, mask, axis_names=opts.get("axis_names", ()),
                residual=jnp.max(jnp.stack([i.residual for i in infos])),
                iters=jnp.max(jnp.stack([i.iters for i in infos])),
                converged=jnp.all(jnp.stack([i.converged for i in infos])))
        return out
    if not diagnostics:
        return _MASKED[name](b32, mask, opts)
    extras = {}
    if name == "geomed":
        out, info = masked_weiszfeld(
            b32, mask, max_iters=opts.get("max_iters", 64),
            tol=opts.get("tol", 1e-6), axis_names=opts.get("axis_names", ()),
            sync_axes=opts.get("sync_axes", ()), return_info=True)
        extras = dict(residual=info.residual, iters=info.iters,
                      converged=info.converged)
    elif name == "krum":
        out, scores, best = masked_krum(
            b32, mask, num_byzantine=opts.get("num_byzantine", 0),
            axis_names=opts.get("axis_names", ()), return_scores=True)
        # Non-neighbor scores are +inf sentinels; zero them so the struct
        # (and its JSONL trace) stays finite.
        extras = dict(score=jnp.where(mask > 0, scores, 0.0), selected=best)
    else:
        out = _MASKED[name](b32, mask, opts)
    diag = masked_diagnostics(b32, out, mask,
                              axis_names=opts.get("axis_names", ()), **extras)
    if name == "centered_clip":
        # A live sender whose residual to the final center exceeds the
        # radius had its influence truncated this round.
        live = (mask > 0).astype(jnp.float32)
        clipped = live * (diag.dist > opts.get("clip_radius", 1.0))
        diag = diag._replace(clip_frac=jnp.sum(clipped)
                             / jnp.maximum(jnp.sum(live), 1.0))
    return out, diag


def masked_aggregate(name: str, exchange: Pytree, mask: jnp.ndarray,
                     *, perleaf: bool = False, diagnostics: bool = False,
                     **opts) -> Pytree:
    """Dispatch a masked neighborhood aggregation by registry name.

    Options mirror :func:`repro.core.aggregators.get_aggregator` plus
    ``mixing`` (mean only), ``axis_names`` and ``sync_axes`` (sharded
    execution, see module docstring).  The pytree API is a pack -> flat
    rule -> unpack shim over :func:`masked_aggregate_flat`;
    ``perleaf=True`` keeps the pre-refactor leaf-by-leaf dispatch (the
    bench baseline).  An exchange that is already a single array is
    treated as a packed buffer and returned as one.

    ``diagnostics=True`` returns ``(out, AggDiagnostics)``.  Diagnostics
    are a flat-engine feature, so they route even a ``perleaf=True`` call
    through the packed engine (mirroring how the step builders handle
    staleness weights on the per-leaf baseline).
    """
    _check_masked_name(name)
    if isinstance(exchange, jnp.ndarray):
        return masked_aggregate_flat(name, exchange, mask,
                                     diagnostics=diagnostics, **opts)
    if perleaf and not diagnostics:
        return _MASKED[name](exchange, mask, opts)
    spec = packing.pack_spec(exchange, batch_ndim=2)
    out = masked_aggregate_flat(name, spec.pack(exchange, batch_ndim=2),
                                mask, spec=spec, diagnostics=diagnostics,
                                **opts)
    if diagnostics:
        out, diag = out
        return spec.unpack(out, batch_ndim=1), diag
    return spec.unpack(out, batch_ndim=1)
