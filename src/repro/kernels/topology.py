"""Pallas TPU kernel for the decentralized neighborhood hot path.

The decentralized step's coordinate-separable aggregations sweep a dense
``(R, S, d)`` exchange tensor (R receivers x S senders x d coordinates,
:mod:`repro.topology.masked`): per receiver, reduce the sender axis under
its ``(R, S)`` neighbor mask -- a masked mean, or a masked trimmed mean
dropping the ``trim`` most extreme masked entries per coordinate.  Unfused,
that is several HBM passes over R*S*d floats (mask broadcast, fill, sort,
reduce); this kernel tiles d into lane-aligned VMEM blocks with the whole
sender axis resident on-chip and fuses the masking, trimming, and reduction
into ONE HBM sweep.

* :func:`masked_neighbor_reduce_call` -- grid over (receiver, d-tile); each
  grid step loads one receiver's (S, T) slab + its (S,) mask row and emits
  the (T,) masked (trimmed) mean.

Trimming avoids sorting (TPU-hostile): ``trim`` rounds of extreme
elimination, each removing exactly ONE occurrence of the current masked
max and min per coordinate (first occurrence by sender index, via a
broadcasted iota -- ties therefore match a stable sort), then a masked sum
over the survivors.  ``trim=0`` degenerates to the fused masked mean.

dtype: f32 or bf16 exchanges (accumulation always f32).  The oracle is
``ref.masked_neighbor_reduce`` (an independent sort-based implementation);
``tests/test_kernels.py`` pins them against each other in both dtypes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _masked_reduce_kernel(e_ref, m_ref, out_ref, *, trim: int):
    z = e_ref[0].astype(jnp.float32)             # (S, T)
    m = m_ref[...].astype(jnp.float32)           # (1, S)
    s = z.shape[0]
    valid = jnp.broadcast_to(m.reshape(s, 1) > 0, z.shape)
    n = jnp.sum(m)

    row_ids = jax.lax.broadcasted_iota(jnp.int32, z.shape, 0)
    work = valid
    for _ in range(trim):
        # Drop one occurrence of the masked max, then of the masked min;
        # "one occurrence" = smallest sender index among the ties, which is
        # what a stable sort-and-slice would drop too.
        vals = jnp.where(work, z, -jnp.inf)
        peak = jnp.max(vals, axis=0, keepdims=True)
        hit = (vals == peak) & work
        first = jnp.min(jnp.where(hit, row_ids, s), axis=0, keepdims=True)
        work = work & (row_ids != first)

        vals = jnp.where(work, z, jnp.inf)
        trough = jnp.min(vals, axis=0, keepdims=True)
        hit = (vals == trough) & work
        first = jnp.min(jnp.where(hit, row_ids, s), axis=0, keepdims=True)
        work = work & (row_ids != first)

    total = jnp.sum(jnp.where(work, z, 0.0), axis=0)
    out_ref[...] = (total / jnp.maximum(n - 2 * trim, 1.0)).reshape(1, -1)


def masked_neighbor_reduce_call(exchange: jnp.ndarray, mask: jnp.ndarray, *,
                                trim: int = 0, tile: int = DEFAULT_TILE,
                                interpret: bool = True) -> jnp.ndarray:
    """exchange: (R, S, d), mask: (R, S) -> (R, d) f32 per-receiver masked
    (trimmed) means.  d must be a multiple of ``tile`` (ops.py pads); every
    receiver must have > 2*trim masked senders (the topology validators
    guarantee this upstream)."""
    r, s, d = exchange.shape
    assert mask.shape == (r, s), (mask.shape, (r, s))
    assert d % tile == 0, (d, tile)
    grid = (r, d // tile)
    return pl.pallas_call(
        functools.partial(_masked_reduce_kernel, trim=trim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, tile), lambda i, k: (i, 0, k)),
            pl.BlockSpec((1, s), lambda i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(exchange, mask)
