"""Fused SAGA correct+update Pallas kernel.

Per step, SAGA reads the fresh gradient g, the stored row table[idx], and
the running average, then emits

    msg      = g - table[idx] + avg
    new_avg  = avg + (g - table[idx]) / J
    table[idx] <- g            (in-place row update via input/output aliasing)

Unfused that is 5 HBM passes over p floats (+ a J*p scatter); the kernel
does one sweep per p-tile: load three tiles, emit three tiles, with the
table row selected by a scalar-prefetched index (pl.ds dynamic slice on the
J axis) and the table aliased input->output so only the touched row moves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _saga_kernel(idx_ref, grad_ref, table_ref, avg_ref,
                 msg_ref, avg_out_ref, table_out_ref, *, num_samples: int):
    idx = idx_ref[0]
    g = grad_ref[...].astype(jnp.float32)             # (1, T)
    old = pl.load(table_ref, (pl.dslice(idx, 1), slice(None))).astype(jnp.float32)
    avg = avg_ref[...].astype(jnp.float32)
    delta = g - old
    msg_ref[...] = (delta + avg).astype(msg_ref.dtype)
    avg_out_ref[...] = (avg + delta / num_samples).astype(avg_out_ref.dtype)
    # Copy-through + row update (aliased, so only the dirty row really moves
    # on TPU; interpret mode materializes the copy which is fine for tests).
    table_out_ref[...] = table_ref[...]
    pl.store(table_out_ref, (pl.dslice(idx, 1), slice(None)),
             g.astype(table_out_ref.dtype))


def saga_correct_call(grad: jnp.ndarray, table: jnp.ndarray, avg: jnp.ndarray,
                      idx: jnp.ndarray, *, tile: int = DEFAULT_TILE,
                      interpret: bool = True):
    """grad: (p,), table: (J, p), avg: (p,), idx: () int32.
    Returns (msg (p,), new_avg (p,), new_table (J, p))."""
    j, p = table.shape
    assert grad.shape == (p,) and avg.shape == (p,)
    assert p % tile == 0
    grid = (p // tile,)
    kernel = functools.partial(_saga_kernel, num_samples=j)
    msg, new_avg, new_table = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # idx (scalar in vector)
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # grad
            pl.BlockSpec((j, tile), lambda i: (0, i)),     # table
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # avg
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((j, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, p), grad.dtype),
            jax.ShapeDtypeStruct((1, p), avg.dtype),
            jax.ShapeDtypeStruct((j, p), table.dtype),
        ],
        input_output_aliases={2: 2},
        interpret=interpret,
    )(idx.reshape(1), grad.reshape(1, p), table, avg.reshape(1, p))
    return msg[0], new_avg[0], new_table
