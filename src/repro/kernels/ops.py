"""Public jit'd wrappers over the Pallas kernels (padding, full geomed loop).

On this CPU container the kernels execute with ``interpret=True`` (the
kernel bodies run in Python/XLA-CPU, numerically identical); on a TPU
runtime ``interpret=False`` compiles them to Mosaic.  ``INTERPRET`` is
resolved from the backend at import time and can be overridden per call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import robust_stats as rs
from repro.kernels import saga_correct as sc
from repro.kernels import topology as tp
from repro.kernels import weiszfeld as wz

INTERPRET = jax.default_backend() == "cpu"
_TILE = wz.DEFAULT_TILE


def _pad_p(x: jnp.ndarray, tile: int, axis: int = -1):
    p = x.shape[axis]
    pad = (-p) % tile
    if pad == 0:
        return x, p
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), p


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def weiszfeld_step(z: jnp.ndarray, y: jnp.ndarray, *, tile: int = _TILE,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """One fused Weiszfeld iteration on (W, p) messages."""
    interp = INTERPRET if interpret is None else interpret
    zp, p = _pad_p(z, tile)
    yp, _ = _pad_p(y, tile)
    sq = wz.partial_sqdist_call(zp, yp, tile=tile, interpret=interp)
    inv = 1.0 / jnp.maximum(jnp.sqrt(sq), 1e-8)
    num = wz.weighted_sum_call(zp, inv, tile=tile, interpret=interp)
    return (num / jnp.sum(inv))[:p].astype(z.dtype)


@functools.partial(jax.jit, static_argnames=("iters", "tile", "interpret"))
def geomed(z: jnp.ndarray, *, iters: int = 32, tile: int = _TILE,
           interpret: Optional[bool] = None) -> jnp.ndarray:
    """Kernel-backed geometric median (fixed iteration count)."""
    y0 = jnp.mean(z.astype(jnp.float32), axis=0)

    def body(_, y):
        return weiszfeld_step(z, y, tile=tile, interpret=interpret).astype(jnp.float32)

    y = jax.lax.fori_loop(0, iters, body, y0)
    return y.astype(z.dtype)


@functools.partial(jax.jit, static_argnames=("num_segments", "tile", "interpret"))
def partial_sqdist_segments(z: jnp.ndarray, y: jnp.ndarray,
                            seg_ids: jnp.ndarray, *, num_segments: int,
                            tile: int = _TILE,
                            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-(worker, block) squared distances: z (W, p), y (p,), seg_ids (p,)
    int block id per coordinate -> (W, num_segments).  One fused sweep
    instead of num_segments sqdist passes -- the TPU form of the segment sum
    in ``core/geomed.weiszfeld_blockwise_sharded`` (not yet wired into that
    shard_map path); padding introduced here contributes to no block."""
    interp = INTERPRET if interpret is None else interpret
    zp, p = _pad_p(z, tile)
    yp, _ = _pad_p(y, tile)
    onehot = (seg_ids[None, :] == jnp.arange(num_segments)[:, None]).astype(
        jnp.float32)
    ohp, _ = _pad_p(onehot, tile)  # padded coordinates: all-zero columns
    return wz.partial_sqdist_segments_call(zp, yp, ohp, tile=tile,
                                           interpret=interp)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def saga_correct(grad: jnp.ndarray, table: jnp.ndarray, avg: jnp.ndarray,
                 idx: jnp.ndarray, *, tile: int = _TILE,
                 interpret: Optional[bool] = None):
    """Fused SAGA correct+update on a raveled (p,) gradient."""
    interp = INTERPRET if interpret is None else interpret
    gp, p = _pad_p(grad, tile)
    tp, _ = _pad_p(table, tile)
    ap, _ = _pad_p(avg, tile)
    msg, new_avg, new_table = sc.saga_correct_call(
        gp, tp, ap, idx.astype(jnp.int32), tile=tile, interpret=interp)
    return msg[:p], new_avg[:p], new_table[:, :p]


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, q_block: int = 128,
                    kv_block: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Flash attention on (B, S, H, hd) tensors with GQA (KV <= H heads,
    repeated on entry).  Output dtype follows q."""
    interp = INTERPRET if interpret is None else interpret
    b, s, h, hd = q.shape
    kv = k.shape[2]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    to_bh = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    o = fa.flash_attention_call(to_bh(q), to_bh(k), to_bh(v), causal=causal,
                                q_block=q_block, kv_block=kv_block,
                                interpret=interp)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("trim", "tile", "interpret"))
def masked_neighbor_reduce(exchange: jnp.ndarray, mask: jnp.ndarray, *,
                           trim: int = 0, tile: int = _TILE,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused per-receiver masked (trimmed) neighborhood mean on a dense
    (R, S, d) exchange tensor + (R, S) neighbor mask -> (R, d) f32.  The
    decentralized hot path (DESIGN.md Sec. 6); the jnp shard_map path in
    ``topology/masked.py`` is the oracle-checked reference, this is the
    TPU form (one HBM sweep, no sort).  Padding coordinates introduced
    here average masked zeros and are stripped before returning."""
    interp = INTERPRET if interpret is None else interpret
    ep, d = _pad_p(exchange, tile)
    return tp.masked_neighbor_reduce_call(ep, mask, trim=trim, tile=tile,
                                          interpret=interp)[:, :d]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def coordinate_median(z: jnp.ndarray, *, tile: int = _TILE,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    interp = INTERPRET if interpret is None else interpret
    zp, p = _pad_p(z, tile)
    return rs.coordinate_median_call(zp, tile=tile, interpret=interp)[:p]


@functools.partial(jax.jit, static_argnames=("trim", "tile", "interpret"))
def trimmed_mean(z: jnp.ndarray, *, trim: int = 1, tile: int = _TILE,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    interp = INTERPRET if interpret is None else interpret
    zp, p = _pad_p(z, tile)
    return rs.trimmed_mean_call(zp, trim, tile=tile, interpret=interp)[:p]
