"""Pallas TPU kernels for the Weiszfeld iteration (geomed hot loop).

The aggregation inner loop sweeps the (W, p) message matrix twice per
iteration: once to compute per-worker distances ||z_w - y||, once to apply
the reweighting y+ = sum_w z_w/d_w / sum_w 1/d_w.  Unfused, that is 4+ HBM
passes over W*p floats (residual materialization, square, reduce, weighted
sum); these kernels tile p into lane-aligned VMEM blocks with the whole
worker axis resident on-chip, fusing each pass to a single HBM sweep:

* :func:`partial_sqdist_call`  -- grid over p-tiles, accumulates per-worker
  partial squared distances into a (W,) accumulator (revisited every grid
  step; Pallas grid iteration on TPU is sequential so accumulation is safe).
* :func:`partial_sqdist_segments_call` -- same sweep, but distances are
  accumulated per (worker, block) into a (W, L) accumulator given an (L, p)
  block-membership indicator: one fused HBM pass instead of L separate
  per-block sweeps.  This is the TPU-targeted counterpart of the segment
  sum inside ``core/geomed.weiszfeld_blockwise_sharded`` (which currently
  computes it with ``jax.ops.segment_sum``; the kernel is oracle-verified
  but not yet wired into the shard_map path).
* :func:`weighted_sum_call`    -- grid over p-tiles, each tile emits the
  weighted combination of the W messages for its coordinate range.

W is padded to the sublane multiple (8); p to the lane tile (128*k).
dtype: f32 or bf16 messages (accumulation always f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _sqdist_kernel(z_ref, y_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    z = z_ref[...].astype(jnp.float32)        # (W, T)
    y = y_ref[...].astype(jnp.float32)        # (1, T)
    d = z - y
    out_ref[...] += jnp.sum(d * d, axis=1)


def partial_sqdist_call(z: jnp.ndarray, y: jnp.ndarray, *,
                        tile: int = DEFAULT_TILE,
                        interpret: bool = True) -> jnp.ndarray:
    """z: (W, p), y: (p,) -> (W,) squared distances.  p must be a multiple
    of ``tile`` (ops.py pads)."""
    w, p = z.shape
    assert p % tile == 0, (p, tile)
    grid = (p // tile,)
    return pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((w,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=interpret,
    )(z, y.reshape(1, p))


def _sqdist_seg_kernel(z_ref, y_ref, oh_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    z = z_ref[...].astype(jnp.float32)        # (W, T)
    y = y_ref[...].astype(jnp.float32)        # (1, T)
    oh = oh_ref[...].astype(jnp.float32)      # (L, T)
    d = z - y
    out_ref[...] += (d * d) @ oh.T            # (W, L)


def partial_sqdist_segments_call(z: jnp.ndarray, y: jnp.ndarray,
                                 onehot: jnp.ndarray, *,
                                 tile: int = DEFAULT_TILE,
                                 interpret: bool = True) -> jnp.ndarray:
    """z: (W, p), y: (p,), onehot: (L, p) block membership (a coordinate with
    an all-zero onehot column -- e.g. padding -- contributes nowhere) ->
    (W, L) per-(worker, block) squared distances.  p must be a multiple of
    ``tile`` (ops.py pads)."""
    w, p = z.shape
    l = onehot.shape[0]
    assert p % tile == 0, (p, tile)
    assert onehot.shape[1] == p, (onehot.shape, p)
    grid = (p // tile,)
    return pl.pallas_call(
        _sqdist_seg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((l, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((w, l), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((w, l), jnp.float32),
        interpret=interpret,
    )(z, y.reshape(1, p), onehot)


def _wsum_kernel(z_ref, w_ref, out_ref):
    z = z_ref[...].astype(jnp.float32)        # (W, T)
    wv = w_ref[...].astype(jnp.float32)       # (1, W)
    out_ref[...] = (wv @ z)                   # (1, T)


def weighted_sum_call(z: jnp.ndarray, weights: jnp.ndarray, *,
                      tile: int = DEFAULT_TILE,
                      interpret: bool = True) -> jnp.ndarray:
    """z: (W, p), weights: (W,) -> (p,) = sum_w weights[w] z[w] (UNnormalized;
    the caller divides by sum(weights))."""
    w, p = z.shape
    assert p % tile == 0
    grid = (p // tile,)
    out = pl.pallas_call(
        _wsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, tile), lambda i: (0, i)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(z, weights.reshape(1, w))
    return out[0]
