"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def partial_sqdist(z: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """z: (W, p), y: (p,) -> per-worker squared distances (W,)."""
    d = z.astype(jnp.float32) - y.astype(jnp.float32)[None]
    return jnp.sum(d * d, axis=-1)


def partial_sqdist_segments(z: jnp.ndarray, y: jnp.ndarray,
                            seg_ids: jnp.ndarray,
                            num_segments: int) -> jnp.ndarray:
    """z: (W, p), y: (p,), seg_ids: (p,) block ids -> (W, num_segments)
    per-(worker, block) squared distances."""
    d2 = (z.astype(jnp.float32) - y.astype(jnp.float32)[None]) ** 2
    onehot = (seg_ids[None, :] == jnp.arange(num_segments)[:, None]).astype(
        jnp.float32)
    return d2 @ onehot.T


def weighted_sum(z: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """sum_i w[i] z[i] / sum(w); z: (W, p), w: (W,) -> (p,)."""
    return (w.astype(jnp.float32) @ z.astype(jnp.float32)) / jnp.sum(w.astype(jnp.float32))


def weiszfeld_step(z: jnp.ndarray, y: jnp.ndarray, floor: float = 1e-8) -> jnp.ndarray:
    d = jnp.sqrt(partial_sqdist(z, y))
    inv = 1.0 / jnp.maximum(d, floor)
    return weighted_sum(z, inv)


def geomed(z: jnp.ndarray, iters: int = 32) -> jnp.ndarray:
    y = jnp.mean(z.astype(jnp.float32), axis=0)
    for _ in range(iters):
        y = weiszfeld_step(z, y)
    return y.astype(z.dtype)


def saga_correct(grad: jnp.ndarray, table: jnp.ndarray, avg: jnp.ndarray,
                 idx: jnp.ndarray):
    """grad: (p,), table: (J, p), avg: (p,), idx: scalar.
    Returns (msg, new_avg, new_table)."""
    j = table.shape[0]
    old = table[idx].astype(jnp.float32)
    g = grad.astype(jnp.float32)
    msg = g - old + avg.astype(jnp.float32)
    new_avg = avg.astype(jnp.float32) + (g - old) / j
    new_table = table.at[idx].set(grad.astype(table.dtype))
    return (msg.astype(grad.dtype), new_avg.astype(avg.dtype), new_table)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention oracle.  q/k/v: (BH, S, hd) -> (BH, S, hd)."""
    bh, s, hd = q.shape
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))


def masked_neighbor_reduce(exchange: jnp.ndarray, mask: jnp.ndarray,
                           trim: int = 0) -> jnp.ndarray:
    """exchange: (R, S, d), mask: (R, S) -> (R, d) per-receiver masked
    trimmed mean over the sender axis (trim=0: plain masked mean).
    Sort-based: non-neighbors fill to +inf, ranks [trim, n-trim) survive."""
    z = exchange.astype(jnp.float32)
    m = mask[:, :, None]
    n = jnp.sum(mask, axis=1)                                # (R,)
    s = jnp.sort(jnp.where(m > 0, z, jnp.inf), axis=1)
    ranks = jnp.arange(z.shape[1])[None, :, None]
    keep = (ranks >= trim) & (ranks < (n[:, None, None] - trim))
    return (jnp.sum(jnp.where(keep, s, 0.0), axis=1)
            / jnp.maximum(n - 2 * trim, 1.0)[:, None])


def coordinate_median(z: jnp.ndarray) -> jnp.ndarray:
    """z: (W, p) -> (p,) elementwise median."""
    return jnp.median(z, axis=0).astype(z.dtype)


def trimmed_mean(z: jnp.ndarray, trim: int) -> jnp.ndarray:
    s = jnp.sort(z, axis=0)
    w = z.shape[0]
    return jnp.mean(s[trim : w - trim].astype(jnp.float32), axis=0).astype(z.dtype)
