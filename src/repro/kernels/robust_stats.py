"""Per-coordinate order statistics over the worker axis (median / trimmed
mean), Pallas-tiled.

The coordinate-wise rules of the paper's Fig. 6 comparison sort W values per
coordinate.  W is small (tens), so each p-tile keeps the whole worker axis
in VMEM and sorts along the sublane axis in-register; one HBM sweep total.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _median_kernel(z_ref, out_ref, *, num_workers: int):
    z = z_ref[...].astype(jnp.float32)       # (W, T)
    s = jnp.sort(z, axis=0)
    w = num_workers
    if w % 2:
        med = s[w // 2]
    else:
        med = 0.5 * (s[w // 2 - 1] + s[w // 2])
    out_ref[...] = med[None].astype(out_ref.dtype)


def coordinate_median_call(z: jnp.ndarray, *, tile: int = DEFAULT_TILE,
                           interpret: bool = True) -> jnp.ndarray:
    w, p = z.shape
    assert p % tile == 0
    out = pl.pallas_call(
        functools.partial(_median_kernel, num_workers=w),
        grid=(p // tile,),
        in_specs=[pl.BlockSpec((w, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), z.dtype),
        interpret=interpret,
    )(z)
    return out[0]


def _trimmed_kernel(z_ref, out_ref, *, trim: int, num_workers: int):
    z = z_ref[...].astype(jnp.float32)
    s = jnp.sort(z, axis=0)
    kept = s[trim : num_workers - trim]
    out_ref[...] = jnp.mean(kept, axis=0)[None].astype(out_ref.dtype)


def trimmed_mean_call(z: jnp.ndarray, trim: int, *, tile: int = DEFAULT_TILE,
                      interpret: bool = True) -> jnp.ndarray:
    w, p = z.shape
    assert p % tile == 0 and 2 * trim < w
    out = pl.pallas_call(
        functools.partial(_trimmed_kernel, trim=trim, num_workers=w),
        grid=(p // tile,),
        in_specs=[pl.BlockSpec((w, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), z.dtype),
        interpret=interpret,
    )(z)
    return out[0]
