"""Flash-attention Pallas TPU kernel (online softmax, O(S) memory).

Not part of the paper's contribution (the paper's kernels are the
aggregation sweeps), but the framework's attention hot spot: every dense/
MoE/hybrid arch's train/prefill step is built on chunked attention, so a
VMEM-tiled MXU kernel is the natural TPU lowering.

Blocking: grid = (B*H, num_q_blocks, num_kv_blocks); TPU grid iteration is
sequential over the last axis, so the (q-block)-indexed output tiles and the
running max/denominator tiles persist across the kv-block sweep -- the
classic flash accumulation expressed through revisited output blocks
(no scratch buffers needed, works identically under interpret=True):

    j == 0        : init  m = -inf, l = 0, o = 0
    every j       : s = q k^T; m' = max(m, rowmax s); p = exp(s - m')
                    o = o * exp(m - m') + p v;  l = l * exp(m - m') + rowsum p
    j == last     : o /= l

Causal masking is applied per (q-block, kv-block) tile; fully-masked tiles
are skipped with ``pl.when`` (on TPU this prunes ~half the MXU work of a
causal sweep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  causal: bool, q_block: int, kv_block: int, seq_len: int,
                  num_kv_blocks: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if causal:
        live = (i * q_block + q_block - 1) >= (j * kv_block)
    else:
        live = j >= 0  # always true (traced predicate)

    @pl.when(live)
    def _update():
        q = q_ref[0].astype(jnp.float32)            # (qb, hd)
        k = k_ref[0].astype(jnp.float32)            # (kb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = (q @ k.T) * (q.shape[-1] ** -0.5)       # (qb, kb)
        qpos = i * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        kpos = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0]
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        o_ref[0] = o_ref[0] * corr[:, None] + p @ v
        m_ref[0] = m_new
        l_ref[0] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def flash_attention_call(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, q_block: int = 128,
                         kv_block: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """q/k/v: (BH, S, hd) with equal head counts (GQA repeat done by ops.py).
    Returns (BH, S, hd) in fp32."""
    bh, s, hd = q.shape
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    nq = -(-s // q_block)
    nk = -(-s // kv_block)
    pad_q = nq * q_block - s
    pad_k = nk * kv_block - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    kernel = functools.partial(
        _flash_kernel, causal=causal, q_block=q_block, kv_block=kv_block,
        seq_len=s, num_kv_blocks=nk)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nq * q_block, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, nq * q_block), jnp.float32),
            jax.ShapeDtypeStruct((bh, nq * q_block), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o[:, :s]
