"""Pallas TPU kernels for the paper's aggregation/VR hot spots.

Modules: ``weiszfeld`` (geomed inner loop), ``saga_correct`` (fused table
correct+update), ``robust_stats`` (coordinate median / trimmed mean),
``topology`` (masked-neighborhood reduction for the decentralized path);
``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
"""
from repro.kernels import ops, ref
