from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    apply_updates,
    constant_schedule,
    cosine_schedule,
    get_optimizer,
    momentum,
    sgd,
)
