"""Minimal optax-free optimizers.

Each optimizer is a pair ``(init(params) -> state, update(grads, state,
params, step) -> (updates, state))`` mirroring the optax contract; apply with
:func:`apply_updates`.  The paper's update (eq. (11)) is plain ``sgd``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple[Pytree, Pytree]]


# --------------------------------------------------------------------------
# Schedules
# --------------------------------------------------------------------------

def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return sched


def _as_schedule(lr):
    return lr if callable(lr) else constant_schedule(lr)


# --------------------------------------------------------------------------
# Optimizers
# --------------------------------------------------------------------------

def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return ()

    def update(grads, state, params=None, step=0):
        g = jax.tree_util.tree_map(lambda x: -sched(step) * x, grads)
        return g, state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params=None, step=0):
        new_m = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: g + beta * m, new_m, grads)
        else:
            upd = new_m
        return jax.tree_util.tree_map(lambda u: -sched(step) * u, upd), new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW (decoupled weight decay when ``weight_decay`` > 0)."""
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            mu=jax.tree_util.tree_map(z, params),
            nu=jax.tree_util.tree_map(z, params),
        )

    def update(grads, state, params=None, step=0):
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step_f
        bc2 = 1 - b2 ** step_f
        lr_t = sched(step)

        def upd(m, v, p, g):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu, nu, params, grads)
        return updates, AdamState(mu, nu)

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, **kw)
    if name == "adam":
        return adam(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
