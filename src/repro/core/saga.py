"""SAGA variance reduction (per-worker gradient tables), paper Alg. 1.

Each honest worker ``w`` keeps

* ``table``: the most recent per-sample gradient ``f'_{w,j}(phi_{w,j})`` for
  each of its J local samples (leaves shaped ``(J, *param_shape)``), and
* ``avg``:   their running average ``(1/J) sum_j f'_{w,j}(phi_{w,j})``.

Per step the worker draws ``i`` uniformly from ``{1..J}`` and sends the
*corrected* stochastic gradient

    m_w = f'_{w,i}(x) - table[i] + avg                      (Alg. 1)

then performs the in-place bookkeeping

    avg   <- avg + (f'_{w,i}(x) - table[i]) / J
    table[i] <- f'_{w,i}(x)

``m_w`` is an unbiased estimate of worker w's full local gradient (eq. (18))
whose variance vanishes as the iterates converge -- which is exactly what
makes the subsequent robust aggregation effective (Lemma 1 / Thm 1).

The functions below operate on *stacked-worker* pytrees (leading axis W) so
they vectorize the whole federation in one call, and equally work inside
``shard_map`` where the worker axis is a mesh axis (W=1 locally).

Flat-packed execution (DESIGN.md Sec. 8): every SAGA op is elementwise or
a gather/scatter along the worker/sample axes, so the same functions run
unchanged when ``table``/``avg``/``grads`` are packed buffers (``(W, J,
D)`` / ``(W, D)`` single-array "pytrees", :mod:`repro.core.packing`) --
one fused correction + one table scatter per step instead of one per
parameter leaf.  The packed simulation step keeps its SagaState packed for
the whole run; :func:`pack_saga_state` / :func:`unpack_saga_state` convert
between the layouts (bit-exact for float32 messages).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import packing

Pytree = Any


class SagaState(NamedTuple):
    """Per-worker SAGA memory, stacked over workers.

    table leaves: ``(W, J, *shape)``; avg leaves: ``(W, *shape)``.
    For the single-worker (shard_map) path, W == 1.
    """

    table: Pytree
    avg: Pytree

    @property
    def num_samples(self) -> int:
        return jax.tree_util.tree_leaves(self.table)[0].shape[1]


def saga_init(per_sample_grads: Pytree) -> SagaState:
    """Initialize from gradients of *all* J samples at x^0 (Alg. 1 init).

    ``per_sample_grads`` leaves: (W, J, *shape).
    """
    avg = jax.tree_util.tree_map(lambda t: jnp.mean(t, axis=1), per_sample_grads)
    return SagaState(table=per_sample_grads, avg=avg)


def saga_init_zeros(params: Pytree, num_workers: int, num_samples: int,
                    dtype=None) -> SagaState:
    """Cold-start init with a zero table (practical variant: avoids the J
    full-gradient passes at startup; the table warms up over the first
    epoch).  Used at LLM scale where the init sweep is prohibitive."""

    def zeros(p, extra):
        d = dtype or p.dtype
        return jnp.zeros((num_workers, *extra, *p.shape), d)

    table = jax.tree_util.tree_map(lambda p: zeros(p, (num_samples,)), params)
    avg = jax.tree_util.tree_map(lambda p: zeros(p, ()), params)
    return SagaState(table=table, avg=avg)


def pack_saga_state(spec: packing.PackSpec, state: SagaState) -> SagaState:
    """Pytree-layout SagaState -> packed layout (table (W, J, D), avg
    (W, D)) under ``spec`` (the per-message PackSpec of the model)."""
    return SagaState(table=spec.pack(state.table, batch_ndim=2),
                     avg=spec.pack(state.avg, batch_ndim=1))


def unpack_saga_state(spec: packing.PackSpec, state: SagaState) -> SagaState:
    """Inverse of :func:`pack_saga_state`."""
    return SagaState(table=spec.unpack(state.table),
                     avg=spec.unpack(state.avg))


def saga_correct(
    state: SagaState, grads: Pytree, sample_idx: jnp.ndarray
) -> tuple[Pytree, SagaState]:
    """Apply the SAGA correction and table update for every worker at once.

    ``grads`` leaves: (W, *shape) -- fresh stochastic gradients f'_{w,i}(x^k).
    ``sample_idx``: (W,) int32 -- each worker's drawn sample index i_w^k.

    Returns ``(messages, new_state)`` where message leaves are (W, *shape).
    """
    idx = sample_idx

    def correct(g, tab, avg):
        # old = table[w, idx[w]] for each worker w.
        old = jnp.take_along_axis(
            tab, idx.reshape((-1, 1) + (1,) * (g.ndim - 1)).astype(jnp.int32), axis=1
        )[:, 0]
        old = old.astype(g.dtype)
        msg = g - old + avg.astype(g.dtype)
        return msg, old

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_t = treedef.flatten_up_to(state.table)
    flat_a = treedef.flatten_up_to(state.avg)
    out_msgs, new_tabs, new_avgs = [], [], []
    j = jax.tree_util.tree_leaves(state.table)[0].shape[1]
    for g, tab, avg in zip(flat_g, flat_t, flat_a):
        msg, old = correct(g, tab, avg)
        out_msgs.append(msg)
        new_avgs.append((avg + (g - old).astype(avg.dtype) / j).astype(avg.dtype))
        # table[w, idx[w]] <- g[w]
        onehot = jax.nn.one_hot(idx, tab.shape[1], dtype=tab.dtype)  # (W, J)
        onehot = onehot.reshape(onehot.shape + (1,) * (g.ndim - 1))
        new_tabs.append(tab * (1 - onehot) + onehot * g[:, None].astype(tab.dtype))
    messages = jax.tree_util.tree_unflatten(treedef, out_msgs)
    new_state = SagaState(
        table=jax.tree_util.tree_unflatten(treedef, new_tabs),
        avg=jax.tree_util.tree_unflatten(treedef, new_avgs),
    )
    return messages, new_state


def saga_correct_scatter(
    state: SagaState, grads: Pytree, sample_idx: jnp.ndarray
) -> tuple[Pytree, SagaState]:
    """Same semantics as :func:`saga_correct` but with scatter-based table
    update (O(p) memory traffic instead of the O(J*p) one-hot multiply).
    Preferred at scale; `saga_correct` is kept as the simple oracle."""
    idx = sample_idx.astype(jnp.int32)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_t = treedef.flatten_up_to(state.table)
    flat_a = treedef.flatten_up_to(state.avg)
    j = jax.tree_util.tree_leaves(state.table)[0].shape[1]
    w_ids = jnp.arange(flat_g[0].shape[0], dtype=jnp.int32)
    out_msgs, new_tabs, new_avgs = [], [], []
    for g, tab, avg in zip(flat_g, flat_t, flat_a):
        old = tab[w_ids, idx].astype(g.dtype)
        out_msgs.append(g - old + avg.astype(g.dtype))
        new_avgs.append((avg + (g - old).astype(avg.dtype) / j).astype(avg.dtype))
        new_tabs.append(tab.at[w_ids, idx].set(g.astype(tab.dtype)))
    return (
        jax.tree_util.tree_unflatten(treedef, out_msgs),
        SagaState(
            table=jax.tree_util.tree_unflatten(treedef, new_tabs),
            avg=jax.tree_util.tree_unflatten(treedef, new_avgs),
        ),
    )
