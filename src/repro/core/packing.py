"""Flat-packed message layout: one (W, D) buffer for the whole federation.

Every hot-path consumer of worker messages -- the robust aggregators, the
Byzantine attacks, the SAGA correction, the masked topology rules -- is
mathematically a function of the *concatenated* p-dimensional message
vector (the paper's master aggregates the whole gradient, eq. (6)), yet
the original implementation walked the gradient pytree leaf-by-leaf,
multiplying kernel launches, collectives and HBM sweeps by ``num_leaves``.
This module provides the static layout that lets the hot path operate on a
single ``(W, D)`` matrix end-to-end:

* :class:`PackSpec` -- built once per model from the per-message leaf
  shapes/dtypes: flat sizes, cumulative offsets, the raveled dimension
  ``D``, an optional pad to a multiple (``pad_to``), and the on-wire
  ``message_dtype`` (``float32``, or ``bfloat16`` to halve communication
  volume -- robust rules still accumulate in f32, DESIGN.md Sec. 8).
* :meth:`PackSpec.pack` -- pytree with any number of leading batch axes
  (worker axis, (receiver, sender) exchange axes, SAGA (W, J) table axes)
  ``->`` one ``(*batch, D_padded)`` buffer.  Pure reshape+concat+cast at
  trace time: no data-dependent work, jit-free.
* :meth:`PackSpec.unpack` -- the inverse (slice+reshape+cast back to the
  original leaf dtypes; padding is dropped).
* :meth:`PackSpec.seg_ids` -- per-coordinate leaf id (padding coordinates
  get the dummy id ``num_leaves``), the segment map used by blockwise
  (per-leaf-norm) rules on packed buffers.

The spec is deterministic in the tree structure alone, so independently
built specs for the same model agree (pinned by ``tests/test_packing.py``),
and the pytree aggregator API can stay a thin ``pack -> flat rule ->
unpack`` shim with zero layout ambiguity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def assemble(parts, *, pad: int = 0, batch_shape: tuple[int, ...] = (),
             dtype: Any = jnp.float32) -> jnp.ndarray:
    """Concatenate pre-raveled per-leaf pieces (each ``(*batch, n_i)``)
    into one packed ``(*batch, sum(n_i) + pad)`` buffer, zero-filling the
    padding tail.

    The ONE implementation of packed-layout assembly -- ``PackSpec.pack``,
    the spec-mirrored gaussian noise, and the blockwise flat rules all
    route here, so the empty-tree / single-leaf / padding edge cases can
    never drift between them.
    """
    parts = list(parts)
    if pad:
        parts.append(jnp.zeros(batch_shape + (pad,), dtype))
    if not parts:
        return jnp.zeros(batch_shape + (0,), dtype)
    return jnp.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static layout of a packed message buffer.

    ``shapes``/``dtypes`` describe ONE message (no batch axes): leaf ``i``
    occupies the contiguous coordinate range ``offsets[i]:offsets[i] +
    sizes[i]`` of the packed vector.  ``dim`` is the unpadded raveled
    dimension; ``padded_dim`` rounds it up to a multiple of ``pad_to``
    (padding coordinates are zero-filled on pack and dropped on unpack).
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    dim: int
    padded_dim: int
    message_dtype: Any = jnp.float32

    @property
    def num_leaves(self) -> int:
        return len(self.shapes)

    @property
    def boundaries(self) -> tuple[tuple[int, int], ...]:
        """Static (start, stop) coordinate range of every leaf."""
        return tuple((o, o + s) for o, s in zip(self.offsets, self.sizes))

    @property
    def pad(self) -> int:
        return self.padded_dim - self.dim

    def pack(self, tree: Pytree, *, batch_ndim: int = 1) -> jnp.ndarray:
        """Ravel ``tree`` into one ``(*batch, padded_dim)`` buffer.

        Every leaf must carry ``batch_ndim`` leading batch axes followed by
        its spec shape.  Cast to ``message_dtype`` happens here (the single
        point where the f32->bf16 wire quantization can occur).
        """
        leaves = self.treedef.flatten_up_to(tree)
        if not leaves:
            return jnp.zeros((self.padded_dim,), self.message_dtype)
        batch = leaves[0].shape[:batch_ndim]
        parts = []
        for leaf, shape in zip(leaves, self.shapes):
            if tuple(leaf.shape[batch_ndim:]) != shape:
                raise ValueError(
                    f"leaf shape {tuple(leaf.shape)} does not match spec "
                    f"message shape {shape} under batch_ndim={batch_ndim}")
            parts.append(jnp.reshape(leaf, batch + (-1,)).astype(
                self.message_dtype))
        return assemble(parts, pad=self.pad, batch_shape=batch,
                        dtype=self.message_dtype)

    def unpack(self, buf: jnp.ndarray, *, batch_ndim: int | None = None
               ) -> Pytree:
        """Inverse of :meth:`pack`: restore leaf shapes AND dtypes.

        ``batch_ndim`` defaults to ``buf.ndim - 1`` (everything but the
        packed coordinate axis is batch).
        """
        if batch_ndim is None:
            batch_ndim = buf.ndim - 1
        batch = buf.shape[:batch_ndim]
        if buf.shape[batch_ndim] != self.padded_dim:
            raise ValueError(
                f"buffer coordinate axis {buf.shape[batch_ndim]} != "
                f"spec padded_dim {self.padded_dim}")
        out = []
        for (a, b), shape, dtype in zip(self.boundaries, self.shapes,
                                        self.dtypes):
            piece = buf[(slice(None),) * batch_ndim + (slice(a, b),)]
            out.append(jnp.reshape(piece, batch + shape).astype(dtype))
        return self.treedef.unflatten(out)

    def seg_ids(self) -> jnp.ndarray:
        """(padded_dim,) int32 leaf id per packed coordinate; padding
        coordinates carry the dummy id ``num_leaves`` so they join no real
        block in segmented (blockwise) rules."""
        ids = np.full((self.padded_dim,), self.num_leaves, np.int32)
        for i, (a, b) in enumerate(self.boundaries):
            ids[a:b] = i
        return jnp.asarray(ids)

    def struct(self, *, batch: tuple[int, ...] = ()) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct of the packed buffer with leading ``batch``."""
        return jax.ShapeDtypeStruct(batch + (self.padded_dim,),
                                    self.message_dtype)


def pack_spec(tree: Pytree, *, batch_ndim: int = 1,
              message_dtype: Any = jnp.float32, pad_to: int = 1) -> PackSpec:
    """Build the :class:`PackSpec` of ``tree``.

    ``tree`` leaves may be arrays or ShapeDtypeStructs; their first
    ``batch_ndim`` axes are treated as batch (worker/exchange axes) and the
    rest as the per-message shape.  ``pad_to`` rounds the packed dimension
    up to a multiple (e.g. the worker count for all_to_all resharding).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape[batch_ndim:]) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(math.prod(s)) for s in shapes)
    offsets = tuple(int(o) for o in np.concatenate(
        [[0], np.cumsum(sizes)]))[:-1] if sizes else ()
    dim = int(sum(sizes))
    padded = dim + ((-dim) % max(pad_to, 1))
    return PackSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    sizes=sizes, offsets=offsets, dim=dim, padded_dim=padded,
                    message_dtype=jnp.dtype(message_dtype))


def resolve_message_dtype(name: str | Any) -> Any:
    """Map a RobustConfig.message_dtype string to a jnp dtype."""
    if isinstance(name, str):
        allowed = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}
        try:
            return allowed[name]
        except KeyError:
            raise ValueError(
                f"message_dtype must be one of {sorted(allowed)}, "
                f"got {name!r}") from None
    return jnp.dtype(name)
