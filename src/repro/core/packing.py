"""Flat-packed message layout: one (W, D) buffer for the whole federation.

Every hot-path consumer of worker messages -- the robust aggregators, the
Byzantine attacks, the SAGA correction, the masked topology rules -- is
mathematically a function of the *concatenated* p-dimensional message
vector (the paper's master aggregates the whole gradient, eq. (6)), yet
the original implementation walked the gradient pytree leaf-by-leaf,
multiplying kernel launches, collectives and HBM sweeps by ``num_leaves``.
This module provides the static layout that lets the hot path operate on a
single ``(W, D)`` matrix end-to-end:

* :class:`PackSpec` -- built once per model from the per-message leaf
  shapes/dtypes: flat sizes, cumulative offsets, the raveled dimension
  ``D``, an optional pad to a multiple (``pad_to``), and the on-wire
  format (``wire``, a :data:`WIRE_FORMATS` name -- robust rules always
  accumulate in f32, DESIGN.md Secs. 8 and 12).

Wire formats (DESIGN.md Sec. 12): the :data:`WIRE_FORMATS` registry is
the single source of truth for what a message looks like on the wire --
``float32``, ``bfloat16`` (pack-time cast, halves volume), ``int8``
(per-block symmetric scales from the static leaf boundaries,
:meth:`PackSpec.encode` / :meth:`PackSpec.decode`), and ``sign1`` (1-bit
sign messages with a per-client error-feedback residual,
:meth:`PackSpec.transmit`).  The CLI choices, the unknown-name errors and
the wire-byte accounting all derive from the registry, same dict-registry
pattern as the aggregator/attack/reducer registries.
* :meth:`PackSpec.pack` -- pytree with any number of leading batch axes
  (worker axis, (receiver, sender) exchange axes, SAGA (W, J) table axes)
  ``->`` one ``(*batch, D_padded)`` buffer.  Pure reshape+concat+cast at
  trace time: no data-dependent work, jit-free.
* :meth:`PackSpec.unpack` -- the inverse (slice+reshape+cast back to the
  original leaf dtypes; padding is dropped).
* :meth:`PackSpec.seg_ids` -- per-coordinate leaf id (padding coordinates
  get the dummy id ``num_leaves``), the segment map used by blockwise
  (per-leaf-norm) rules on packed buffers.

The spec is deterministic in the tree structure alone, so independently
built specs for the same model agree (pinned by ``tests/test_packing.py``),
and the pytree aggregator API can stay a thin ``pack -> flat rule ->
unpack`` shim with zero layout ambiguity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

Pytree = Any


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """One on-wire message format (a :data:`WIRE_FORMATS` registry entry).

    ``cast_dtype`` is what :meth:`PackSpec.pack` casts the buffer to -- the
    quantized formats keep the in-memory buffer f32 and quantize explicitly
    through :meth:`PackSpec.encode`/:meth:`PackSpec.decode` at the comm
    boundary.  ``bits_per_coord`` drives the wire-byte accounting
    (:meth:`PackSpec.wire_bytes`); quantized formats additionally ship one
    f32 scale per leaf block.  ``error_feedback`` marks formats whose
    senders carry an O(D) residual state (sign1, DESIGN.md Sec. 12)."""

    name: str
    cast_dtype: Any
    bits_per_coord: int
    quantized: bool = False
    error_feedback: bool = False


# name -> WireFormat.  The SINGLE source of truth: ``WIRE_FORMAT_NAMES``,
# the --message-dtype CLI choices and every unknown-name error derive from
# this dict, so registering here is the one place a new wire format is
# added (same pattern as the aggregator/attack/reducer registries).
WIRE_FORMATS: dict[str, WireFormat] = {
    "float32": WireFormat("float32", jnp.float32, 32),
    "bfloat16": WireFormat("bfloat16", jnp.bfloat16, 16),
    "int8": WireFormat("int8", jnp.float32, 8, quantized=True),
    "sign1": WireFormat("sign1", jnp.float32, 1, quantized=True,
                        error_feedback=True),
}

WIRE_FORMAT_NAMES = tuple(WIRE_FORMATS)


def resolve_wire_format(name: str | WireFormat | Any) -> WireFormat:
    """Map a ``RobustConfig.message_dtype`` value to its :class:`WireFormat`.

    Strings resolve through the registry (unknown names raise with the
    registered set); a raw dtype is wrapped as a plain cast format so
    pre-registry callers that passed ``jnp.bfloat16`` directly keep
    working."""
    if isinstance(name, WireFormat):
        return name
    if isinstance(name, str):
        try:
            return WIRE_FORMATS[name]
        except KeyError:
            raise ValueError(
                f"message_dtype must be one of {sorted(WIRE_FORMATS)}, "
                f"got {name!r}") from None
    dt = jnp.dtype(name)
    return WIRE_FORMATS.get(dt.name,
                            WireFormat(dt.name, dt, dt.itemsize * 8))


def assemble(parts, *, pad: int = 0, batch_shape: tuple[int, ...] = (),
             dtype: Any = jnp.float32) -> jnp.ndarray:
    """Concatenate pre-raveled per-leaf pieces (each ``(*batch, n_i)``)
    into one packed ``(*batch, sum(n_i) + pad)`` buffer, zero-filling the
    padding tail.

    The ONE implementation of packed-layout assembly -- ``PackSpec.pack``,
    the spec-mirrored gaussian noise, and the blockwise flat rules all
    route here, so the empty-tree / single-leaf / padding edge cases can
    never drift between them.
    """
    parts = list(parts)
    if pad:
        parts.append(jnp.zeros(batch_shape + (pad,), dtype))
    if not parts:
        return jnp.zeros(batch_shape + (0,), dtype)
    return jnp.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static layout of a packed message buffer.

    ``shapes``/``dtypes`` describe ONE message (no batch axes): leaf ``i``
    occupies the contiguous coordinate range ``offsets[i]:offsets[i] +
    sizes[i]`` of the packed vector.  ``dim`` is the unpadded raveled
    dimension; ``padded_dim`` rounds it up to a multiple of ``pad_to``
    (padding coordinates are zero-filled on pack and dropped on unpack).
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    dim: int
    padded_dim: int
    message_dtype: Any = jnp.float32
    wire: str = "float32"

    @property
    def num_leaves(self) -> int:
        return len(self.shapes)

    @property
    def wire_format(self) -> WireFormat:
        fmt = WIRE_FORMATS.get(self.wire)
        return fmt if fmt is not None else resolve_wire_format(
            self.message_dtype)

    @property
    def quantized(self) -> bool:
        return self.wire_format.quantized

    @property
    def boundaries(self) -> tuple[tuple[int, int], ...]:
        """Static (start, stop) coordinate range of every leaf."""
        return tuple((o, o + s) for o, s in zip(self.offsets, self.sizes))

    @property
    def pad(self) -> int:
        return self.padded_dim - self.dim

    def pack(self, tree: Pytree, *, batch_ndim: int = 1) -> jnp.ndarray:
        """Ravel ``tree`` into one ``(*batch, padded_dim)`` buffer.

        Every leaf must carry ``batch_ndim`` leading batch axes followed by
        its spec shape.  Cast to ``message_dtype`` happens here (the single
        point where the f32->bf16 wire quantization can occur).
        """
        leaves = self.treedef.flatten_up_to(tree)
        if not leaves:
            return jnp.zeros((self.padded_dim,), self.message_dtype)
        batch = leaves[0].shape[:batch_ndim]
        parts = []
        for leaf, shape in zip(leaves, self.shapes):
            if tuple(leaf.shape[batch_ndim:]) != shape:
                raise ValueError(
                    f"leaf shape {tuple(leaf.shape)} does not match spec "
                    f"message shape {shape} under batch_ndim={batch_ndim}")
            parts.append(jnp.reshape(leaf, batch + (-1,)).astype(
                self.message_dtype))
        return assemble(parts, pad=self.pad, batch_shape=batch,
                        dtype=self.message_dtype)

    def unpack(self, buf: jnp.ndarray, *, batch_ndim: int | None = None
               ) -> Pytree:
        """Inverse of :meth:`pack`: restore leaf shapes AND dtypes.

        ``batch_ndim`` defaults to ``buf.ndim - 1`` (everything but the
        packed coordinate axis is batch).
        """
        if batch_ndim is None:
            batch_ndim = buf.ndim - 1
        batch = buf.shape[:batch_ndim]
        if buf.shape[batch_ndim] != self.padded_dim:
            raise ValueError(
                f"buffer coordinate axis {buf.shape[batch_ndim]} != "
                f"spec padded_dim {self.padded_dim}")
        out = []
        for (a, b), shape, dtype in zip(self.boundaries, self.shapes,
                                        self.dtypes):
            piece = buf[(slice(None),) * batch_ndim + (slice(a, b),)]
            out.append(jnp.reshape(piece, batch + shape).astype(dtype))
        return self.treedef.unflatten(out)

    def encode(self, buf: jnp.ndarray, *, axis_names: Sequence[str] = ()
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Quantize a packed buffer: ``(*batch, padded_dim)`` ->
        ``(codes int8 (*batch, padded_dim), scales f32 (*batch, num_leaves))``.

        Scales are per leaf block, read off the static :attr:`boundaries`:
        ``int8`` uses symmetric ``amax/127`` scaling (round-trip error at
        most ``amax/254`` per coordinate), ``sign1`` the EF-signSGD
        ``mean |v|`` magnitude.  When the buffer's coordinate axis is
        sharded over mesh axes, pass them as ``axis_names`` so the block
        statistics reduce over the FULL leaf -- the resulting codes then
        match the single-host encode exactly (int8) and the scales match
        up to summation order (sign1).  Padding coordinates encode to 0.
        """
        fmt = self.wire_format
        if not fmt.quantized:
            raise ValueError(f"wire format {fmt.name!r} is not quantized")
        v32 = buf.astype(jnp.float32)
        batch = buf.shape[:-1]
        code_parts, scales = [], []
        for a, b in self.boundaries:
            v = v32[..., a:b]
            if fmt.name == "int8":
                amax = jnp.max(jnp.abs(v), axis=-1)
                if axis_names:
                    amax = compat.pmax(amax, axis_names)
                scale = amax / 127.0
                safe = jnp.where(amax > 0.0, scale, 1.0)
                codes = jnp.clip(jnp.round(v / safe[..., None]),
                                 -127.0, 127.0).astype(jnp.int8)
            else:  # sign1: codes are exactly +-1, never 0
                s_sum = jnp.sum(jnp.abs(v), axis=-1)
                cnt = jnp.full(batch, float(b - a), jnp.float32)
                if axis_names:
                    # psum-ing the local count too keeps the mean right for
                    # both sharded leaves (counts add up to the leaf size)
                    # and replicated ones (numerator and denominator scale
                    # by the same device count).
                    s_sum = compat.psum(s_sum, axis_names)
                    cnt = compat.psum(cnt, axis_names)
                scale = s_sum / jnp.maximum(cnt, 1.0)
                codes = jnp.where(v >= 0.0, 1, -1).astype(jnp.int8)
            code_parts.append(codes)
            scales.append(scale)
        codes = assemble(code_parts, pad=self.pad, batch_shape=batch,
                         dtype=jnp.int8)
        if scales:
            scale_arr = jnp.stack(scales, axis=-1).astype(jnp.float32)
        else:
            scale_arr = jnp.zeros(batch + (0,), jnp.float32)
        return codes, scale_arr

    def decode(self, codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
        """Inverse of :meth:`encode`: f32 ``(*batch, padded_dim)`` buffer."""
        batch = codes.shape[:-1]
        parts = [codes[..., a:b].astype(jnp.float32) * scales[..., i:i + 1]
                 for i, (a, b) in enumerate(self.boundaries)]
        return assemble(parts, pad=self.pad, batch_shape=batch,
                        dtype=jnp.float32)

    def wire_roundtrip(self, buf: jnp.ndarray, *,
                       axis_names: Sequence[str] = ()) -> jnp.ndarray:
        """What the receiver sees: ``decode(encode(buf))`` for quantized
        formats, ``buf`` itself (the byte-identical bypass -- the SAME
        array object, no copy) otherwise."""
        if not self.quantized:
            return buf
        return self.decode(*self.encode(buf, axis_names=axis_names))

    def transmit(self, buf: jnp.ndarray, residual: jnp.ndarray | None = None,
                 *, axis_names: Sequence[str] = ()
                 ) -> tuple[jnp.ndarray, jnp.ndarray | None]:
        """Sender-side wire step: ``(wire_buf, new_residual)``.

        Non-quantized formats pass both through untouched.  Error-feedback
        formats (sign1) require ``residual`` (the sender's O(D) carried
        state, same leading batch as ``buf``): the residual is folded into
        the message before quantization and the fresh quantization error
        comes back as the new residual, so the error is re-sent -- not
        lost -- next round (arXiv:2108.06658).
        """
        fmt = self.wire_format
        if not fmt.quantized:
            return buf, residual
        if fmt.error_feedback:
            if residual is None:
                raise ValueError(
                    f"wire format {fmt.name!r} carries error feedback; "
                    "pass the per-client residual state")
            t = buf.astype(jnp.float32) + residual
            wire = self.wire_roundtrip(t, axis_names=axis_names)
            return wire, t - wire
        return self.wire_roundtrip(buf, axis_names=axis_names), residual

    def wire_bytes(self) -> int:
        """Bytes one message occupies on the wire (codes + per-block
        scales for quantized formats) -- the ``meta.json`` accounting."""
        fmt = self.wire_format
        n = (fmt.bits_per_coord * self.padded_dim + 7) // 8
        if fmt.quantized:
            n += 4 * self.num_leaves
        return n

    def seg_ids(self) -> jnp.ndarray:
        """(padded_dim,) int32 leaf id per packed coordinate; padding
        coordinates carry the dummy id ``num_leaves`` so they join no real
        block in segmented (blockwise) rules."""
        ids = np.full((self.padded_dim,), self.num_leaves, np.int32)
        for i, (a, b) in enumerate(self.boundaries):
            ids[a:b] = i
        return jnp.asarray(ids)

    def struct(self, *, batch: tuple[int, ...] = ()) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct of the packed buffer with leading ``batch``."""
        return jax.ShapeDtypeStruct(batch + (self.padded_dim,),
                                    self.message_dtype)


def dequantize_slice(codes: jnp.ndarray, scales: jnp.ndarray,
                     seg_ids: jnp.ndarray) -> jnp.ndarray:
    """Dequantize an arbitrary coordinate slice of a packed buffer.

    The sharded paths ship int8 codes through the all_to_all and only then
    dequantize the local coordinate slice, where the leaf boundaries no
    longer line up with the slice -- so decoding is per-coordinate:
    ``codes`` is ``(*batch, n)`` int8, ``scales`` is ``(*batch,
    num_leaves)`` f32, ``seg_ids`` is ``(n,)`` int32 leaf id per slice
    coordinate (dummy id ``num_leaves`` for padding, which decodes to 0
    via an appended zero scale column).
    """
    zero = jnp.zeros(scales.shape[:-1] + (1,), scales.dtype)
    padded = jnp.concatenate([scales, zero], axis=-1)
    return codes.astype(jnp.float32) * jnp.take(padded, seg_ids, axis=-1)


def pack_spec(tree: Pytree, *, batch_ndim: int = 1,
              message_dtype: Any = None, pad_to: int = 1,
              wire: str | WireFormat | None = None) -> PackSpec:
    """Build the :class:`PackSpec` of ``tree``.

    ``tree`` leaves may be arrays or ShapeDtypeStructs; their first
    ``batch_ndim`` axes are treated as batch (worker/exchange axes) and the
    rest as the per-message shape.  ``pad_to`` rounds the packed dimension
    up to a multiple (e.g. the worker count for all_to_all resharding).
    ``wire`` names a :data:`WIRE_FORMATS` entry (the buffer dtype follows
    the format's ``cast_dtype``); ``message_dtype`` is the legacy raw-dtype
    spelling -- pass one or the other, not both.
    """
    if wire is not None:
        if message_dtype is not None:
            raise ValueError("pass either wire= or message_dtype=, not both")
        fmt = resolve_wire_format(wire)
        mdt, wname = jnp.dtype(fmt.cast_dtype), fmt.name
    else:
        mdt = jnp.dtype(message_dtype if message_dtype is not None
                        else jnp.float32)
        wname = mdt.name
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape[batch_ndim:]) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(math.prod(s)) for s in shapes)
    offsets = tuple(int(o) for o in np.concatenate(
        [[0], np.cumsum(sizes)]))[:-1] if sizes else ()
    dim = int(sum(sizes))
    padded = dim + ((-dim) % max(pad_to, 1))
    return PackSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    sizes=sizes, offsets=offsets, dim=dim, padded_dim=padded,
                    message_dtype=mdt, wire=wname)


def resolve_message_dtype(name: str | Any) -> Any:
    """Map a RobustConfig.message_dtype value to the pack-time jnp dtype.

    Registry-driven: string names resolve through :data:`WIRE_FORMATS`
    (so the error message and the CLI choices can never go stale), and
    quantized formats resolve to their f32 ``cast_dtype`` -- the buffer
    stays f32 and quantization happens at the comm boundary.
    """
    if isinstance(name, str):
        return jnp.dtype(resolve_wire_format(name).cast_dtype)
    return jnp.dtype(name)
