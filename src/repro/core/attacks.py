"""Byzantine attack models (paper Sec. V + two stronger literature attacks).

An attack maps the honest workers' messages (stacked pytree, leading axis
W_h) to the full message set (leading axis W = W_h + B) by appending B
malicious rows.  Attackers are assumed omniscient and colluding (they see
the honest messages), which is the paper's threat model.

Paper attacks (Sec. V):

* ``gaussian``      -- N(mean(honest), 30 I) per coordinate.
* ``sign_flip``     -- u * mean(honest) with u = -3.
* ``zero_gradient`` -- -(1/B) sum(honest): makes the *mean* of all W messages
                       exactly zero, stalling mean-aggregated training.

Beyond-paper attacks (used to stress the aggregators harder):

* ``alie``          -- "A Little Is Enough" (Baruch et al. 2019):
                       mean + z * std per coordinate, staying inside the
                       honest cloud to evade norm-based defenses.
* ``ipm``           -- inner-product manipulation (Fall of Empires [20]):
                       -eps * mean(honest), a negatively-aligned small
                       perturbation.
* ``straggler``     -- asynchronous-federation attack (DESIGN.md Sec. 10):
                       reports a message that is stale by ``straggler_k``
                       rounds, proxied as an inflated honest mean
                       (gradient magnitudes decay along the trajectory, so
                       an old report looks like an over-scaled current
                       one); the slot additionally carries staleness
                       ``straggler_k`` on the staleness-aware paths.
* ``dropout``       -- absent participant: the slot's content is zero and
                       its staleness saturates at the bound, so
                       staleness-aware rules weight it to exactly 0
                       (mask-select, never slice+concat).  Robust rules
                       without weights see an all-zeros outlier row.
* ``none``          -- no Byzantine rows appended (W = W_h).

Fault-injection attacks (``FAULT_ATTACKS``, DESIGN.md Sec. 13): these step
OUTSIDE the paper's threat model -- the payloads are not finite vectors a
statistical rule can outvote, they are the hardware/serialization faults
the ``repro.core.guards`` containment layer exists for:

* ``nan``           -- every Byzantine coordinate is NaN: one such row
                       poisons every distance computation and the
                       Weiszfeld iteration itself.
* ``inf_overflow``  -- huge finite payload (+-1e30, signed like the honest
                       mean): finite, so it passes NaN checks, but its
                       squared norms overflow f32 and the magnitude gate
                       (not the non-finite detector) must catch it.
* ``bitflip``       -- seeded coordinate corruption: a deterministic
                       integer-hash of (row, leaf, coordinate, seed) picks
                       ~``bitflip_prob`` of the coordinates and XORs the
                       high exponent bit of their f32 encoding (a memory
                       bitflip proxy: values blow up by ~2^128 or become
                       Inf/NaN).  No ``jax.random`` -- the hash makes the
                       corruption layout- and sharding-invariant, so
                       packed/per-leaf and sharded/replicated runs corrupt
                       the SAME coordinates.

Flat-packed execution (DESIGN.md Sec. 8): every attack is a composition of
axis-0 reductions over the worker axis and elementwise ops, so the SAME
code runs on a packed ``(W, D)`` message buffer (a single-leaf pytree) --
the packed train steps pass the buffer straight through.  The one
layout-dependent piece is the ``gaussian`` attack's draws: pass the
buffer's :class:`repro.core.packing.PackSpec` as ``spec=`` and the noise
is drawn PER ORIGINAL LEAF (same key split, same shapes) and packed, so
packed and per-leaf trajectories stay bit-identical even under the random
attack.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import packing

Pytree = Any
Attack = Callable[[Pytree, jax.Array], Pytree]  # (honest_stacked, key) -> full_stacked


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    name: str = "none"
    num_byzantine: int = 0
    # Attack-specific knobs (paper values as defaults).
    gaussian_variance: float = 30.0
    sign_flip_magnitude: float = -3.0
    alie_z: float = 1.0
    ipm_eps: float = 0.5
    straggler_k: int = 4
    # Fault-injection knobs (module docstring): per-coordinate corruption
    # probability and hash seed of the ``bitflip`` attack.
    bitflip_prob: float = 0.02
    bitflip_seed: int = 0


# Magnitude of the ``inf_overflow`` payload: finite in f32 (and bf16), but
# its squared norm overflows to +inf, which is the failure mode the
# guards' magnitude gate exists for.
OVERFLOW_MAGNITUDE = 1e30


def _honest_mean(honest: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda z: jnp.mean(z, axis=0), honest)


def _append(honest: Pytree, byz: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda h, b: jnp.concatenate([h, b.astype(h.dtype)], axis=0), honest, byz
    )


def _broadcast_rows(tree: Pytree, b: int) -> Pytree:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (b,) + x.shape), tree
    )


def packed_gaussian_noise(spec: packing.PackSpec, key: jax.Array,
                          batch_shape: tuple[int, ...],
                          std) -> jnp.ndarray:
    """Gaussian noise for a packed buffer that mirrors the per-leaf draws
    bit-for-bit: one key per ORIGINAL leaf (same ``jax.random.split``
    count), each drawn in the leaf's ``batch_shape + leaf_shape`` layout,
    then raveled and concatenated like :meth:`PackSpec.pack`.  Padding
    coordinates get zero noise.  Keeps packed and per-leaf gaussian-attack
    trajectories identical (module docstring)."""
    keys = jax.random.split(key, max(spec.num_leaves, 1))
    parts = [
        (std * jax.random.normal(k, batch_shape + shape, jnp.float32)
         ).reshape(batch_shape + (-1,))
        for k, shape in zip(keys, spec.shapes)
    ]
    return packing.assemble(parts, pad=spec.pad, batch_shape=batch_shape)


def gaussian_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array,
                    spec: Optional[packing.PackSpec] = None) -> Pytree:
    mean = _honest_mean(honest)
    std = jnp.sqrt(cfg.gaussian_variance)
    if spec is not None:
        noise = packed_gaussian_noise(spec, key, (cfg.num_byzantine,), std)
        return _append(honest, mean[None] + noise)
    leaves, treedef = jax.tree_util.tree_flatten(mean)
    keys = jax.random.split(key, len(leaves))
    byz = [
        m[None] + std * jax.random.normal(k, (cfg.num_byzantine,) + m.shape, jnp.float32).astype(m.dtype)
        for m, k in zip(leaves, keys)
    ]
    return _append(honest, jax.tree_util.tree_unflatten(treedef, byz))


def sign_flip_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array) -> Pytree:
    del key
    mean = _honest_mean(honest)
    byz = jax.tree_util.tree_map(lambda m: cfg.sign_flip_magnitude * m, mean)
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def zero_gradient_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array) -> Pytree:
    del key
    # m_byz = -(1/B) * sum_honest  =>  sum over all W messages == 0.
    byz = jax.tree_util.tree_map(
        lambda z: -jnp.sum(z, axis=0) / cfg.num_byzantine, honest
    )
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def alie_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array) -> Pytree:
    del key

    def stats(z):
        return jnp.mean(z, axis=0) + cfg.alie_z * jnp.std(z, axis=0)

    byz = jax.tree_util.tree_map(stats, honest)
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def ipm_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array) -> Pytree:
    del key
    byz = jax.tree_util.tree_map(lambda m: -cfg.ipm_eps * m, _honest_mean(honest))
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def straggler_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array) -> Pytree:
    """Stale-by-``straggler_k`` report: an over-scaled honest mean (see the
    module docstring).  The matching staleness counters are injected by the
    step builders via :func:`repro.core.participation.slot_staleness`."""
    del key
    scale = 1.0 + 0.25 * cfg.straggler_k
    byz = jax.tree_util.tree_map(lambda m: scale * m, _honest_mean(honest))
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def dropout_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array) -> Pytree:
    """Absent participant: all-zero content; staleness-aware rules mask the
    slot out entirely (weight 0) via its saturated staleness counter."""
    del key
    byz = jax.tree_util.tree_map(
        lambda z: jnp.zeros_like(z, shape=z.shape[1:]), honest)
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def none_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array) -> Pytree:
    """No Byzantine rows: the message set is the honest set (W = W_h)."""
    del cfg, key
    return honest


# ---------------------------------------------------------------------------
# Fault-injection attacks (module docstring; DESIGN.md Sec. 13).
# ---------------------------------------------------------------------------

def _fault_fill(value_fn, mean: Pytree,
                spec: Optional[packing.PackSpec]) -> Pytree:
    """Coordinate-wise fault payload built from the honest-mean rows.  On
    the packed path the padding coordinates stay 0 (they are zero in every
    honest row, so filling them would make the packed trajectory diverge
    from the per-leaf one through the full-vector distance geometry)."""
    if spec is None:
        return jax.tree_util.tree_map(value_fn, mean)

    def one(m):
        keep = jax.lax.iota(jnp.int32, spec.padded_dim) < spec.dim
        return jnp.where(keep, value_fn(m), jnp.zeros_like(m))

    return jax.tree_util.tree_map(one, mean)


def _hash01(row_ids: jnp.ndarray, n: int, salt: int) -> jnp.ndarray:
    """(R, n) deterministic pseudo-uniforms in [0, 1) from an integer hash
    of (row id, coordinate, salt).  Wrapping uint32 arithmetic only -- no
    ``jax.random`` -- so the draw is independent of sharding, jit
    partitioning and buffer layout (module docstring)."""
    r = row_ids.astype(jnp.uint32)[:, None]
    c = jax.lax.iota(jnp.uint32, n)[None, :]
    h = (r * jnp.uint32(0x9E3779B9) + c * jnp.uint32(0x85EBCA6B)
         + jnp.uint32(salt & 0xFFFFFFFF) * jnp.uint32(0xC2B2AE35))
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> jnp.uint32(16))
    return h.astype(jnp.float32) / jnp.float32(4294967296.0)


def _flip_exponent_bit(x: jnp.ndarray) -> jnp.ndarray:
    """XOR the high exponent bit of the f32 encoding: magnitudes jump by
    ~2^128 (values in [1, 4) become Inf/NaN) -- the memory-corruption
    proxy the ``bitflip`` attack injects."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jax.lax.bitcast_convert_type(bits ^ jnp.uint32(1 << 30),
                                        jnp.float32)


def _bitflip_segment(rows: jnp.ndarray, row_ids: jnp.ndarray,
                     leaf_index: int, prob: float, seed: int) -> jnp.ndarray:
    """Corrupt one leaf's (R, n) flat rows at the hash-selected coords."""
    u = _hash01(row_ids, rows.shape[-1], seed * 1000003 + leaf_index)
    return jnp.where(u < prob, _flip_exponent_bit(rows), rows)


def bitflip_rows(mean: Pytree, row_ids: jnp.ndarray, *, prob: float,
                 seed: int, spec: Optional[packing.PackSpec] = None
                 ) -> Pytree:
    """Byzantine rows for the ``bitflip`` attack: the honest mean broadcast
    to ``len(row_ids)`` rows, with the exponent bit of ~``prob`` of each
    row's coordinates flipped.  ``row_ids`` are the rows' RELATIVE
    Byzantine indices (the hash input), so the append-last sim layout and
    the replace-first distributed layout corrupt identically.  With
    ``spec`` the rows are a packed buffer and the hash runs per ORIGINAL
    leaf segment (spec.boundaries), keeping packed and per-leaf
    trajectories bit-identical; padding coordinates are never corrupted."""
    r = row_ids.shape[0]

    if spec is not None:
        def one(m):
            rows = jnp.broadcast_to(m[None].astype(jnp.float32),
                                    (r,) + m.shape)
            parts = [_bitflip_segment(rows[:, a:b], row_ids, i, prob, seed)
                     for i, (a, b) in enumerate(spec.boundaries)]
            if spec.pad:
                parts.append(rows[:, spec.dim:])
            return jnp.concatenate(parts, axis=-1).astype(m.dtype)
        return jax.tree_util.tree_map(one, mean)

    leaves, treedef = jax.tree_util.tree_flatten(mean)
    out = []
    for i, m in enumerate(leaves):
        rows = jnp.broadcast_to(m[None].astype(jnp.float32), (r,) + m.shape)
        flat = _bitflip_segment(rows.reshape(r, -1), row_ids, i, prob, seed)
        out.append(flat.reshape((r,) + m.shape).astype(m.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def bitflip_edges(mean: Pytree, sender_ids: jnp.ndarray, *, prob: float,
                  seed: int, spec: Optional[packing.PackSpec] = None
                  ) -> Pytree:
    """Per-edge ``bitflip`` payloads for the decentralized exchange:
    (R, S, ...) leaves where Byzantine sender ``s``'s message toward
    receiver ``r`` is receiver ``r``'s neighborhood mean with the exponent
    bit of ~``prob`` of its coordinates flipped.  The flip coordinates are
    hashed per (SENDER, coordinate), so a sender corrupts the same
    positions toward every receiver (corruption, not equivocation).  With
    ``spec`` the hash runs per original leaf segment and padding is never
    corrupted -- the packed/per-leaf trajectory pins hold exactly as for
    :func:`bitflip_rows`."""
    s = sender_ids.shape[0]

    def corrupt(rows, u):                  # rows (R, S, n), u (S, n)
        return jnp.where(u[None] < prob, _flip_exponent_bit(rows), rows)

    if spec is not None:
        def one(m):                        # m: (R, padded_dim)
            r = m.shape[0]
            rows = jnp.broadcast_to(m[:, None].astype(jnp.float32),
                                    (r, s) + m.shape[1:])
            parts = [corrupt(rows[..., a:b],
                             _hash01(sender_ids, b - a, seed * 1000003 + i))
                     for i, (a, b) in enumerate(spec.boundaries)]
            if spec.pad:
                parts.append(rows[..., spec.dim:])
            return jnp.concatenate(parts, axis=-1).astype(m.dtype)
        return jax.tree_util.tree_map(one, mean)

    leaves, treedef = jax.tree_util.tree_flatten(mean)
    out = []
    for i, m in enumerate(leaves):
        r = m.shape[0]
        rows = jnp.broadcast_to(m[:, None].astype(jnp.float32),
                                (r, s) + m.shape[1:]).reshape(r, s, -1)
        flat = corrupt(rows, _hash01(sender_ids, rows.shape[-1],
                                     seed * 1000003 + i))
        out.append(flat.reshape((r, s) + m.shape[1:]).astype(m.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def nan_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array,
               spec: Optional[packing.PackSpec] = None) -> Pytree:
    """Every Byzantine coordinate is NaN (module docstring)."""
    del key
    byz = _fault_fill(lambda m: jnp.full_like(m, jnp.nan),
                      _honest_mean(honest), spec)
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def inf_overflow_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array,
                        spec: Optional[packing.PackSpec] = None) -> Pytree:
    """Huge finite payload signed like the honest mean (module docstring)."""
    del key
    byz = _fault_fill(
        lambda m: jnp.where(m < 0, -OVERFLOW_MAGNITUDE, OVERFLOW_MAGNITUDE
                            ).astype(m.dtype),
        _honest_mean(honest), spec)
    return _append(honest, _broadcast_rows(byz, cfg.num_byzantine))


def bitflip_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array,
                   spec: Optional[packing.PackSpec] = None) -> Pytree:
    """Seeded coordinate corruption of the honest mean (module docstring)."""
    del key
    byz = bitflip_rows(_honest_mean(honest),
                       jnp.arange(cfg.num_byzantine, dtype=jnp.int32),
                       prob=cfg.bitflip_prob, seed=cfg.bitflip_seed,
                       spec=spec)
    return _append(honest, byz)


# name -> attack.  The SINGLE source of truth: ``ATTACK_NAMES`` and every
# unknown-name error derive from this dict, so registering here is the one
# place a new attack is added (same pattern as the aggregator registry).
_ATTACKS: dict[str, Attack] = {
    "none": none_attack,
    "gaussian": gaussian_attack,
    "sign_flip": sign_flip_attack,
    "zero_gradient": zero_gradient_attack,
    "alie": alie_attack,
    "ipm": ipm_attack,
    "straggler": straggler_attack,
    "dropout": dropout_attack,
    "nan": nan_attack,
    "inf_overflow": inf_overflow_attack,
    "bitflip": bitflip_attack,
}

ATTACK_NAMES = tuple(_ATTACKS)

# Attacks whose Byzantine slots carry non-zero staleness counters; the step
# builders switch to the staleness-weighted aggregation path when one of
# these (or partial participation) is active.
STALENESS_ATTACKS = ("straggler", "dropout")

# Fault-injection attacks (module docstring): payloads with non-finite or
# norm-overflowing coordinates that step outside the paper's threat model.
# Tests that assert finite messages for statistical attacks exempt these;
# the repro.core.guards containment layer is what handles them.
FAULT_ATTACKS = ("nan", "inf_overflow", "bitflip")

# Attacks whose byz payload construction is packed-layout aware: they take
# the optional PackSpec so packed and per-leaf trajectories stay
# bit-identical (gaussian mirrors its draws per leaf; the fault attacks
# keep padding coordinates at zero and hash per leaf segment).
_SPEC_AWARE = ("gaussian", "nan", "inf_overflow", "bitflip")


def _check_attack_name(name: str) -> None:
    if name not in _ATTACKS:
        raise ValueError(f"unknown attack {name!r}; known: "
                         f"{', '.join(sorted(_ATTACKS))}")


def apply_attack(cfg: AttackConfig, honest: Pytree, key: jax.Array,
                 *, spec: Optional[packing.PackSpec] = None) -> Pytree:
    """Return the full W-message set seen by the master.

    ``spec``: when ``honest`` is a packed ``(W_h, D)`` buffer, pass its
    PackSpec so the ``gaussian`` attack mirrors the per-leaf draws (module
    docstring); deterministic attacks ignore it."""
    _check_attack_name(cfg.name)
    if cfg.num_byzantine == 0:
        return honest
    if cfg.name in _SPEC_AWARE:
        return _ATTACKS[cfg.name](cfg, honest, key, spec)
    return _ATTACKS[cfg.name](cfg, honest, key)


def apply_attack_stacked(cfg: AttackConfig, msgs: Pytree, key: jax.Array,
                         *, spec: Optional[packing.PackSpec] = None) -> Pytree:
    """Variant for the distributed data-parallel path: ``msgs`` holds ALL W
    workers' messages stacked (leading axis W); the first B rows are
    *replaced* by the attack (their honest compute is discarded), leaving
    W - B honest rows.

    Everything is mask-select over the intact (W, ...) leaves -- honest
    statistics come from masked sums, the Byzantine rows go in with
    ``jnp.where``.  Do NOT rewrite this with ``z[b:]`` + concatenate: an
    unaligned slice/concat of an axis that is sharded across the mesh both
    costs halo exchanges and miscompiles (silently doubled rows) under
    older XLA SPMD partitioners."""
    _check_attack_name(cfg.name)
    if cfg.name == "none" or cfg.num_byzantine == 0:
        return msgs
    b = cfg.num_byzantine
    w = jax.tree_util.tree_leaves(msgs)[0].shape[0]
    wh = w - b

    def honest_mask(z):
        m = (jnp.arange(w) >= b).astype(jnp.float32)
        return m.reshape((w,) + (1,) * (z.ndim - 1))

    def masked_mean(fn):
        return jax.tree_util.tree_map(
            lambda z: jnp.sum(fn(z.astype(jnp.float32)) * honest_mask(z), axis=0) / wh,
            msgs)

    mean = masked_mean(lambda z: z)
    name = cfg.name
    if name == "sign_flip":
        byz = jax.tree_util.tree_map(lambda m: cfg.sign_flip_magnitude * m, mean)
    elif name == "straggler":
        byz = jax.tree_util.tree_map(
            lambda m: (1.0 + 0.25 * cfg.straggler_k) * m, mean)
    elif name == "dropout":
        byz = jax.tree_util.tree_map(jnp.zeros_like, mean)
    elif name == "zero_gradient":
        # -(1/B) sum_honest => the mean of all W messages is exactly zero.
        byz = jax.tree_util.tree_map(lambda m: -(wh / b) * m, mean)
    elif name == "ipm":
        byz = jax.tree_util.tree_map(lambda m: -cfg.ipm_eps * m, mean)
    elif name == "alie":
        sq = masked_mean(jnp.square)
        byz = jax.tree_util.tree_map(
            lambda m, s: m + cfg.alie_z * jnp.sqrt(jnp.maximum(s - m * m, 0.0)),
            mean, sq)
    elif name == "nan":
        byz = _fault_fill(lambda m: jnp.full_like(m, jnp.nan), mean, spec)
    elif name == "inf_overflow":
        byz = _fault_fill(
            lambda m: jnp.where(m < 0, -OVERFLOW_MAGNITUDE,
                                OVERFLOW_MAGNITUDE).astype(m.dtype),
            mean, spec)
    elif name == "bitflip":
        # Relative Byzantine index == row index (the byz rows are rows
        # 0..B-1 here), matching the sim path's appended-row indices.
        byz = bitflip_rows(mean, jnp.arange(w, dtype=jnp.int32),
                           prob=cfg.bitflip_prob, seed=cfg.bitflip_seed,
                           spec=spec)
    elif name == "gaussian":
        std = jnp.sqrt(cfg.gaussian_variance)
        if spec is not None:
            byz = jax.tree_util.tree_map(
                lambda m: m[None] + packed_gaussian_noise(spec, key, (w,), std),
                mean)
        else:
            leaves, treedef = jax.tree_util.tree_flatten(mean)
            keys = jax.random.split(key, len(leaves))
            byz = jax.tree_util.tree_unflatten(treedef, [
                m[None] + std * jax.random.normal(k, (w,) + m.shape, jnp.float32)
                for m, k in zip(leaves, keys)])
    else:  # pragma: no cover - guarded by the _ATTACKS check above
        raise ValueError(f"unknown attack {name!r}")

    def select(z, bz):
        is_byz = (jnp.arange(w) < b).reshape((w,) + (1,) * (z.ndim - 1))
        bz_rows = bz if bz.ndim == z.ndim else jnp.broadcast_to(bz[None], z.shape)
        return jnp.where(is_byz, bz_rows.astype(z.dtype), z)

    return jax.tree_util.tree_map(select, msgs, byz)
