"""Composable Byzantine-robust training steps (paper Alg. 1 and baselines).

Two execution paths share the same math:

* :func:`make_federated_step` -- single-host simulation of the full
  federation: W_h honest workers are vmapped, B Byzantine messages are
  injected by an attack model, the master aggregates with a pluggable rule
  and applies the update.  This is the path used to reproduce every figure/
  table of the paper exactly (CPU-scale, finite-sum losses).

* :func:`distributed_aggregate` / :func:`sharded_aggregate` -- the
  aggregation step for the multi-device path, called inside ``shard_map``
  where each index of the mesh worker axes (a single ``data`` axis, or
  ``(pod, data)`` on multi-pod meshes) is one worker.  ``gather`` mode is
  the paper-faithful master (all_gather + replicated aggregation);
  ``sharded`` mode re-shards by coordinate with an all_to_all and restores
  global geometry with small psums -- distributed Weiszfeld for geomed, a
  partial-Gram psum for krum, per-block segmented Weiszfeld for
  geomed_blockwise (DESIGN.md Sec. 2).  EVERY registry aggregator runs on
  both paths.

Variance-reduction modes come from the :mod:`repro.core.variance`
registry: ``sgd`` (one sample), ``minibatch`` (mean of a random
minibatch), ``saga`` (corrected gradients + table, Alg. 1), ``lsvrg``
(loopless-SVRG snapshots, O(D) state).  This module never branches on the
``cfg.vr`` string -- every path dispatches through the
:class:`repro.core.variance.VarianceReducer` built by ``cfg.reducer()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro import telemetry
from repro.core import aggregators as agg_lib
from repro.core import attacks as attack_lib
from repro.core import guards as guards_lib
from repro.core import packing
from repro.core import participation as participation_lib
from repro.core import variance as vr_lib
from repro.core.geomed import (weiszfeld_blockwise_sharded, weiszfeld_flat,
                               weiszfeld_pytree)
from repro.optim import optimizers as optim_lib

Pytree = Any


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Everything that defines the robust training loop of the paper."""

    aggregator: str = "geomed"        # mean | median | geomed | geomed_groups | trimmed_mean | krum
    vr: str = "saga"                  # repro.core.variance.VR_NAMES: sgd | minibatch | saga | lsvrg
    attack: str = "none"
    num_byzantine: int = 0
    # Communication graph (repro.topology).  "star" is the paper's implicit
    # master federation and keeps this module's paths bit-exact; any other
    # name routes training through the decentralized per-node step
    # (DESIGN.md Sec. 6).  seed/p only reach erdos_renyi.
    topology: str = "star"
    topology_seed: int = 0
    topology_p: float = 0.5
    # What decentralized nodes EXCHANGE (DESIGN.md Sec. 7): "gradient"
    # gossips (variance-reduced) gradient messages and applies the optimizer
    # to the aggregate; "params" takes a local optimizer step first and
    # robust-aggregates the neighbors' half-stepped MODELS
    # (arXiv:2308.05292's setting).  Ignored on the master path.
    gossip: str = "gradient"
    # Time-varying graph schedule (repro.topology.schedule): "static" keeps
    # one fixed graph (topology= above); "cyclic" rotates over a
    # comma-separated topology list in `topology`; "erdos_renyi" resamples a
    # seeded G(N, p) every round (period graphs, cycled).  "star" + "static"
    # is the bit-exact master path.
    schedule: str = "static"
    schedule_period: int = 4
    minibatch_size: int = 50          # paper's BSGD batch size
    # Snapshot-refresh probability for vr="lsvrg" (arXiv:2303.04560): each
    # worker redraws its reference point/anchor with this per-step Bernoulli
    # probability.  1/J matches SAGA's expected table staleness; larger p
    # trades extra full-gradient passes for a tighter anchor.
    lsvrg_p: float = 0.1
    weiszfeld_iters: int = 64
    weiszfeld_tol: float = 1e-6
    num_groups: int = 4               # for geomed_groups
    trim: int = 1                     # for trimmed_mean
    clip_radius: float = 1.0          # for centered_clip
    comm: str = "gather"              # gather | sharded (distributed path only)
    # Flat-packed hot path (DESIGN.md Sec. 8): True (default) packs the
    # worker messages into one (W, D) buffer once per step and runs SAGA,
    # attacks and aggregation on it end-to-end; False keeps the pre-refactor
    # per-leaf pipeline (the benchmarks' baseline).
    packed: bool = True
    # On-wire format of the packed messages, a repro.core.packing
    # WIRE_FORMATS name (DESIGN.md Sec. 12): "float32"; "bfloat16" (halves
    # communication volume via a pack-time cast); "int8" (per-block
    # symmetric scales, 4x smaller); "sign1" (1-bit signs + per-client
    # error-feedback residual state, 32x smaller).  Robust rules always
    # accumulate in f32.  Only honoured on the packed path; the quantized
    # formats REQUIRE packed=True.
    message_dtype: str = "float32"
    # Attack knobs (paper defaults).
    gaussian_variance: float = 30.0
    sign_flip_magnitude: float = -3.0
    alie_z: float = 1.0
    ipm_eps: float = 0.5
    # Client-scale virtualization (DESIGN.md Sec. 10): num_clients > 0
    # virtualizes that many logical clients, of which a seeded cohort of
    # ``cohort_size`` (the simulated federation; the mesh worker count on
    # the distributed paths) participates per round via
    # ``repro.core.participation``.  0 / num_clients == cohort means full
    # participation and keeps every path bit-exact (resolve_participation
    # returns None, mirroring resolve_schedule's star+static rule).
    num_clients: int = 0
    cohort_size: int = 0
    participation_seed: int = 0
    # Bounded-staleness aggregation: per-slot weight decay**staleness with a
    # hard 0 at/beyond max_staleness (how ``dropout`` slots are masked out).
    # decay=1.0 keeps in-bound rows at full weight.
    max_staleness: int = 64
    staleness_decay: float = 1.0
    # Rounds-stale reported by the ``straggler`` attack.
    straggler_k: int = 4
    # In-graph aggregation diagnostics (DESIGN.md Sec. 11): True makes the
    # robust rule also emit its AggDiagnostics struct (per-worker distance/
    # implicit weight, krum scores+selection, clip fraction, Weiszfeld
    # residual), flattened into the step metrics as ``diag_*`` entries.
    # False (default) keeps every engine byte-identical to the
    # pre-telemetry path.  Like staleness weights, diagnostics are a
    # flat-engine feature: they route per-leaf aggregation through one
    # pack -> flat rule -> unpack detour.
    diagnostics: bool = False
    # Self-healing resilience layer (repro.core.guards, DESIGN.md Sec. 13).
    # guards=True arms (a) in-graph per-row fault containment: rows with a
    # non-finite coordinate, or whose norm exceeds guard_multiplier x the
    # round's median-of-norms, get row_weight exactly 0 (mask-select; the
    # engines never change); and (b) the round-health verdict: a round
    # whose aggregate norm is non-finite, or z-scores above reject_zmax vs
    # the EMA tracker carried in the train state (after reject_warmup
    # accepted rounds), is REJECTED -- params/opt/VR state hold via
    # jnp.where and the rejected_rounds counter advances.  False (default)
    # keeps every path byte-identical to the unguarded step (pinned per
    # registry rule like the diagnostics invariant); on clean rounds
    # guards=True is ALSO bit-identical by construction (guards module
    # docstring).
    guards: bool = False
    guard_multiplier: float = 10.0    # magnitude gate; <= 0 disables it
    reject_ema: float = 0.9           # decay of the aggregate-norm EMA
    reject_zmax: float = 6.0          # z threshold; <= 0 -> finite-check only
    reject_warmup: int = 8            # accepted rounds before the z-gate arms
    # Fault-injection knobs of the ``bitflip`` attack (repro.core.attacks).
    bitflip_prob: float = 0.02
    bitflip_seed: int = 0

    def reducer(self) -> vr_lib.VarianceReducer:
        """The :class:`repro.core.variance.VarianceReducer` named by
        ``self.vr`` -- the ONE dispatch point for variance reduction."""
        return vr_lib.get_reducer(self)

    def attack_config(self) -> attack_lib.AttackConfig:
        return attack_lib.AttackConfig(
            name=self.attack,
            num_byzantine=self.num_byzantine,
            gaussian_variance=self.gaussian_variance,
            sign_flip_magnitude=self.sign_flip_magnitude,
            alie_z=self.alie_z,
            ipm_eps=self.ipm_eps,
            straggler_k=self.straggler_k,
            bitflip_prob=self.bitflip_prob,
            bitflip_seed=self.bitflip_seed,
        )

    def aggregator_fn(self, *, perleaf: Optional[bool] = None
                      ) -> agg_lib.Aggregator:
        """Pytree aggregator for this config.  ``perleaf`` defaults to
        ``not self.packed`` (the packed path's shim vs the pre-refactor
        per-leaf baseline)."""
        return agg_lib.get_aggregator(
            self.aggregator,
            perleaf=(not self.packed) if perleaf is None else perleaf,
            max_iters=self.weiszfeld_iters,
            tol=self.weiszfeld_tol,
            num_groups=self.num_groups,
            trim=self.trim,
            num_byzantine=self.num_byzantine,
            clip_radius=self.clip_radius,
        )

    def wire_format(self) -> packing.WireFormat:
        """The :data:`repro.core.packing.WIRE_FORMATS` entry named by
        ``self.message_dtype`` -- the ONE dispatch point for the wire."""
        return packing.resolve_wire_format(self.message_dtype)

    def message_spec(self, tree: Pytree, *, batch_ndim: int = 1,
                     pad_to: int = 1) -> packing.PackSpec:
        """PackSpec of this config's wire messages for ``tree``."""
        return packing.pack_spec(tree, batch_ndim=batch_ndim, pad_to=pad_to,
                                 wire=self.wire_format())

    def flat_aggregator_fn(self, spec: packing.PackSpec,
                           axis_names: Sequence[str] = (),
                           sync_axes: Sequence[str] = (),
                           diagnostics: Optional[bool] = None,
                           ) -> agg_lib.FlatAggregator:
        """Flat aggregator ``(W, D) -> (D,) f32`` for this config (the
        packed hot path; ``axis_names``/``sync_axes`` for shard_map).
        ``diagnostics`` defaults to ``self.diagnostics``; True makes the
        returned fn yield ``(aggregate, AggDiagnostics)``."""
        return agg_lib.get_flat_aggregator(
            self.aggregator, spec,
            max_iters=self.weiszfeld_iters, tol=self.weiszfeld_tol,
            num_groups=self.num_groups, trim=self.trim,
            num_byzantine=self.num_byzantine, clip_radius=self.clip_radius,
            axis_names=tuple(axis_names), sync_axes=tuple(sync_axes),
            diagnostics=(self.diagnostics if diagnostics is None
                         else diagnostics))


class FederatedState(NamedTuple):
    params: Pytree
    opt_state: Pytree
    # Variance-reduction state (reducer-specific: SagaState, LsvrgState, or
    # None for the stateless reducers).  Under partial participation the
    # leaves carry a leading (num_clients,) axis instead of (W_h,).
    vr: Optional[Any]
    step: jnp.ndarray
    key: jax.Array
    # (num_clients,) int32 rounds-since-last-participation counters, or None
    # under full participation (keeps the pre-participation pytree).
    staleness: Optional[jnp.ndarray] = None
    # (num_clients, D) f32 error-feedback residuals for the sign1 wire
    # (DESIGN.md Sec. 12), gathered/scattered with the cohort like the VR
    # tables, or None for formats without error feedback (keeps the
    # pre-quantization pytree).
    ef: Optional[jnp.ndarray] = None
    # (guards.HEALTH_WIDTH,) f32 round-health vector (aggregate-norm EMA +
    # rejected/accepted counters, DESIGN.md Sec. 13) when cfg.guards, or
    # None -- the default keeps the pre-guards pytree (and checkpoints).
    health: Optional[jnp.ndarray] = None


def resolve_topology(cfg: RobustConfig, num_nodes: int,
                     topology: Optional[Any] = None):
    """Resolve the ``topology=`` argument of the step builders: an explicit
    :class:`repro.topology.Topology` wins, else ``cfg.topology`` is built by
    name for ``num_nodes`` nodes.  Returns None for ``"star"`` -- the
    callers keep the master path (bit-exact with the paper reproduction)."""
    from repro import topology as topo_lib  # deferred: topology imports core
    if topology is None:
        topology = cfg.topology
    if isinstance(topology, str):
        if topology == "star":
            return None
        return topo_lib.get_topology(topology, num_nodes,
                                     seed=cfg.topology_seed,
                                     p=cfg.topology_p)
    if topology.name == "star":
        return None
    return topology


def resolve_schedule(cfg: RobustConfig, num_nodes: int,
                     topology: Optional[Any] = None,
                     schedule: Optional[Any] = None):
    """Resolve the (topology, schedule) arguments of the step builders into
    a :class:`repro.topology.GraphSchedule`, or ``None`` for the master
    path.  An explicit ``GraphSchedule`` wins; else ``cfg.schedule`` is
    built by name around the resolved topology.  ``None`` is returned
    exactly for a STATIC schedule whose single graph is the star -- that
    combination is the paper's master federation and the callers keep the
    bit-exact master implementations (gossip mode included: star + static
    always means master gradient semantics, DESIGN.md Sec. 7)."""
    from repro import topology as topo_lib  # deferred: topology imports core
    if isinstance(topology, topo_lib.GraphSchedule) and schedule is None:
        schedule, topology = topology, None
    if schedule is None:
        schedule = cfg.schedule
    if isinstance(schedule, topo_lib.GraphSchedule):
        sched = schedule
    elif schedule == "static":
        topo = resolve_topology(cfg, num_nodes, topology)
        if topo is None:
            return None
        sched = topo_lib.static_schedule(topo)
    else:
        if topology is None:
            topology = cfg.topology
        sched = topo_lib.get_schedule(
            schedule, num_nodes, topology=topology,
            period=cfg.schedule_period, seed=cfg.topology_seed,
            p=cfg.topology_p)
    if sched.is_static and sched.topologies[0].name == "star":
        return None
    return sched


def make_federated_step(
    loss_fn: Callable[[Pytree, Pytree], jnp.ndarray],
    worker_data: Pytree,
    cfg: RobustConfig,
    optimizer: optim_lib.Optimizer,
    *,
    topology: Optional[Any] = None,
    schedule: Optional[Any] = None,
):
    """Build ``(init_fn, step_fn, metrics_keys)`` for the simulated federation.

    ``loss_fn(params, batch)``: mean loss over a batch whose leaves have a
    leading sample axis. ``worker_data``: leaves shaped (W_h, J, ...).

    ``topology``: a name from ``repro.topology.TOPOLOGY_NAMES`` or a built
    :class:`repro.topology.Topology` (default: ``cfg.topology``).
    ``schedule``: a name from ``repro.topology.SCHEDULE_NAMES`` or a built
    :class:`repro.topology.GraphSchedule` for TIME-VARYING graphs (default:
    ``cfg.schedule``).  The default ``"star"`` + ``"static"`` IS this
    function's master path, unchanged and bit-exact; any other graph or
    schedule delegates to :func:`repro.topology.make_decentralized_step`
    (gossip mode per ``cfg.gossip``), whose state carries a leading
    per-node axis on every leaf (DESIGN.md Secs. 6-7).

    With ``cfg.num_clients > 0`` (partial participation, DESIGN.md Sec. 10)
    ``worker_data`` holds ONE shard PER CLIENT -- leaves shaped
    (num_clients, J, ...) -- and each round a seeded cohort of
    ``cfg.cohort_size`` clients fills the honest message slots via one
    compiled gather; per-client VR state and staleness counters live in
    (num_clients, ...) resident tables.
    """
    num_rows = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    if cfg.num_clients:
        if cfg.num_clients != num_rows:
            raise ValueError(
                f"num_clients={cfg.num_clients} but worker_data has "
                f"{num_rows} client shards")
        if not cfg.cohort_size:
            raise ValueError(
                "partial participation in the simulated federation needs "
                "an explicit cohort_size")
    plan = participation_lib.resolve_participation(
        cfg, cfg.cohort_size if cfg.num_clients else num_rows)
    wh = plan.cohort_size if plan is not None else num_rows
    num_clients = plan.num_clients if plan is not None else num_rows
    weighted = participation_lib.uses_staleness(cfg, plan)
    b = cfg.num_byzantine if cfg.attack != "none" else 0
    sched = resolve_schedule(cfg, wh + b, topology, schedule)
    if sched is not None:
        from repro.topology import make_decentralized_step
        return make_decentralized_step(loss_fn, worker_data, cfg, optimizer,
                                       sched)
    j = jax.tree_util.tree_leaves(worker_data)[0].shape[1]
    grad_fn = jax.grad(loss_fn)
    attack_cfg = cfg.attack_config()
    reducer = cfg.reducer()
    wire_fmt = cfg.wire_format()
    if wire_fmt.quantized and not cfg.packed:
        raise ValueError(
            f"message_dtype={cfg.message_dtype!r} is a quantized wire "
            "format and needs the packed path (cfg.packed=True)")

    def sample_batch(data_w, idx):
        """Select samples ``idx`` (vector) of one worker -> batch pytree."""
        return jax.tree_util.tree_map(lambda d: d[idx], data_w)

    def per_worker_grad(params, data_w, idx):
        return grad_fn(params, sample_batch(data_w, idx))

    def per_sample_table(params):
        """Alg. 1 init: table[j] = f'_{w,j}(x^0) for all j -> (W, J, ...)."""
        def worker_tab(data_w):
            return jax.vmap(
                lambda jj: grad_fn(params, sample_batch(data_w, jj[None]))
            )(jnp.arange(j))
        return jax.vmap(worker_tab)(worker_data)

    def full_local_grads(params_per_worker, data):
        """Per-worker FULL local gradient at per-worker params -> (W, ...).
        (The lsvrg anchor oracle: one vectorized pass over each worker's
        whole shard.)"""
        return jax.vmap(grad_fn)(params_per_worker, data)

    def broadcast_params(params, n=None):
        n = wh if n is None else n
        return jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), params)

    pack_fn = None
    if cfg.packed:
        def pack_fn(tree, batch_ndim):
            spec = cfg.message_spec(tree, batch_ndim=batch_ndim)
            return spec.pack(tree, batch_ndim=batch_ndim)

    def init_fn(params, key) -> FederatedState:
        opt_state = optimizer.init(params)
        # Reducer state lives in the message layout for the whole run
        # (packed: one (W, J, D) SAGA table / (W, D) lsvrg buffers).  Under
        # partial participation the tables are resident PER CLIENT --
        # leading (num_clients,) axis -- and each round's cohort rows are
        # gathered into the (W_h, ...) round view.
        vr_state = reducer.init_sim(
            params,
            per_sample_grads_fn=lambda: per_sample_table(params),
            full_grads_fn=lambda p: full_local_grads(
                broadcast_params(p, num_clients), worker_data),
            num_workers=num_clients, pack_fn=pack_fn)
        staleness = (participation_lib.init_staleness(num_clients)
                     if plan is not None else None)
        # Error-feedback residuals start at zero: the first round transmits
        # plain quantized messages and banks the quantization error.
        ef = None
        if wire_fmt.error_feedback:
            d = cfg.message_spec(params, batch_ndim=0).padded_dim
            ef = jnp.zeros((num_clients, d), jnp.float32)
        health = guards_lib.init_health() if cfg.guards else None
        return FederatedState(params, opt_state, vr_state,
                              jnp.zeros((), jnp.int32), key, staleness, ef,
                              health)

    def honest_grads(params, k_idx, data):
        """Per-worker raw honest gradients + the drawn indices.  Returned
        leaves are pytrees; the packed step packs BEFORE the VR correction
        so the table scatter / snapshot select is one fused op.  ``data``
        is the round's (W_h, J, ...) view (the cohort gather under partial
        participation, ``worker_data`` itself otherwise)."""
        idx = reducer.draw_indices(k_idx, wh, j)
        if idx.ndim == 2:       # minibatch layout: (W, B) sample draws
            honest = jax.vmap(functools.partial(per_worker_grad, params))(data, idx)
        else:
            honest = jax.vmap(
                lambda d, i: per_worker_grad(params, d, i[None])
            )(data, idx)
        return honest, idx

    def round_inputs(state):
        """The round's (data, vr rows, honest staleness, cohort): the
        participation layer's single gather (None-cohort under full
        participation keeps everything as-is)."""
        if plan is None:
            stal = jnp.zeros((wh,), jnp.int32) if weighted else None
            return worker_data, state.vr, stal, None
        cohort = plan.cohort_at(state.step)
        data = participation_lib.gather_rows(worker_data, cohort)
        vr_rows = (participation_lib.gather_rows(state.vr, cohort)
                   if reducer.stateful else state.vr)
        return data, vr_rows, jnp.take(state.staleness, cohort, axis=0), cohort

    def finish_round(state, cohort, vr_rows):
        """Scatter the cohort's updated VR rows back into the resident
        tables and advance the staleness counters."""
        if plan is None:
            return vr_rows, state.staleness
        vr_state = (participation_lib.scatter_rows(state.vr, cohort, vr_rows)
                    if reducer.stateful else vr_rows)
        return vr_state, participation_lib.tick_staleness(state.staleness,
                                                          cohort)

    def row_weights_for(honest_stal):
        """(W,) staleness weights of the full message buffer (honest cohort
        + Byzantine slots), or None when the unweighted bit-exact path is
        active."""
        if not weighted:
            return None, None
        slot_stal = participation_lib.slot_staleness(
            honest_stal, cfg.attack, b, straggler_k=cfg.straggler_k,
            max_staleness=cfg.max_staleness)
        return participation_lib.staleness_weights(
            slot_stal, decay=cfg.staleness_decay,
            max_staleness=cfg.max_staleness), slot_stal

    def correct(params, vr, honest, idx, k_idx, *, data, spec=None):
        """Route the raw gradients through the reducer.  The snapshot
        oracles are bound lazily (closures) so stateless/table reducers
        trace none of them; ``spec`` converts between the packed buffer
        layout and the per-leaf pytrees the grad vmaps consume."""
        if not reducer.stateful:
            return honest, vr, {}
        k_vr = jax.random.fold_in(k_idx, 1)   # DCE'd unless the reducer draws

        def as_tree(x):
            return spec.unpack(x) if spec is not None else x

        def as_msgs(tree, batch_ndim=1):
            return (spec.pack(tree, batch_ndim=batch_ndim)
                    if spec is not None else tree)

        def grads_at(snapshot):
            snap = as_tree(snapshot)
            return as_msgs(jax.vmap(
                lambda p_w, d, i: per_worker_grad(p_w, d, i[None])
            )(snap, data, idx))

        def full_grads_at(p):
            return as_msgs(full_local_grads(as_tree(p), data))

        return reducer.correct(
            vr, honest, idx, k_vr,
            params=as_msgs(broadcast_params(params)),
            grads_at=grads_at, full_grads_at=full_grads_at)

    def step_fn_perleaf(state: FederatedState):
        """Pre-refactor per-leaf hot path (cfg.packed=False): the bench
        baseline, byte-for-byte the original pipeline under full
        participation.  Staleness-weighted aggregation is a flat-engine
        feature, so when weights are active the per-leaf messages detour
        through one pack -> weighted flat rule -> unpack."""
        key, k_idx, k_attack = jax.random.split(state.key, 3)
        params = state.params
        data, vr_rows, honest_stal, cohort = round_inputs(state)
        honest, idx = honest_grads(params, k_idx, data)
        honest, vr_rows, vr_metrics = correct(params, vr_rows, honest, idx,
                                              k_idx, data=data)
        vr_state, staleness = finish_round(state, cohort, vr_rows)

        # Honest-message variance (reported in the paper's figures, bottom rows).
        var = telemetry.honest_variance(honest, wh)

        msgs = attack_lib.apply_attack(attack_cfg, honest, k_attack)
        rw, slot_stal = row_weights_for(honest_stal)
        metrics = {"honest_variance": var, **vr_metrics,
                   **telemetry.staleness_metrics(slot_stal)}
        gmask = None
        if cfg.guards:
            # Containment (DESIGN.md Sec. 13): the validity mask is
            # computed on a packed view of the messages and folds into the
            # row weights; the per-leaf baseline below stays the bit-exact
            # clean-round path via the all-valid select.
            gspec = packing.pack_spec(msgs)
            gbuf = gspec.pack(msgs)
            gmask = guards_lib.guard_mask(
                gbuf, multiplier=cfg.guard_multiplier, base_weights=rw)
            metrics["quarantined_rows"] = jnp.sum(1.0 - gmask)
        if rw is None and not cfg.diagnostics:
            agg = cfg.aggregator_fn(perleaf=True)(msgs)
            if gmask is not None:
                flat_fn = cfg.flat_aggregator_fn(gspec, diagnostics=False)
                agg_w = gspec.unpack(
                    flat_fn(guards_lib.sanitize_rows(gbuf, gmask),
                            row_weights=gmask), batch_ndim=0)
                agg = guards_lib.select_tree(guards_lib.all_valid(gmask),
                                             agg, agg_w)
        else:
            spec = packing.pack_spec(msgs)
            flat_fn = cfg.flat_aggregator_fn(spec)
            buf = spec.pack(msgs)
            if gmask is not None:
                out = guards_lib.guarded_flat_call(flat_fn, buf, gmask,
                                                   row_weights=rw)
            else:
                out = (flat_fn(buf) if rw is None
                       else flat_fn(buf, row_weights=rw))
            if cfg.diagnostics:
                agg_vec, diag = out
                metrics.update(telemetry.diagnostics_metrics(diag))
            else:
                agg_vec = out
            agg = spec.unpack(agg_vec, batch_ndim=0)
        updates, opt_state = optimizer.update(agg, state.opt_state, params, state.step)
        new_params = optim_lib.apply_updates(params, updates)
        health = state.health
        if cfg.guards:
            # Round-health verdict: a rejected round holds params/opt/VR
            # (pure jnp.where -- donation-safe, no host sync); step/key/
            # staleness advance so the next round draws fresh randomness.
            accept, health = guards_lib.round_verdict(
                guards_lib.tree_norm(agg), state.health,
                decay=cfg.reject_ema, zmax=cfg.reject_zmax,
                warmup=cfg.reject_warmup)
            new_params, opt_state, vr_state = guards_lib.select_tree(
                accept, (new_params, opt_state, vr_state),
                (params, state.opt_state, state.vr))
            metrics.update(telemetry.health_metrics(health, accept))
        new_state = FederatedState(new_params, opt_state, vr_state,
                                   state.step + 1, key, staleness, state.ef,
                                   health)
        return new_state, metrics

    def step_fn_packed(state: FederatedState):
        """Flat-packed hot path (DESIGN.md Sec. 8): grads are packed into
        ONE (W_h, D) buffer right after the per-worker grad vmap; VR
        correction, attack injection, aggregation and the variance metric
        all run on the buffer; a single unpack feeds the optimizer.  Under
        partial participation the cohort gather/scatter brackets the
        buffer, and the flat rule consumes the slots' staleness weights."""
        key, k_idx, k_attack = jax.random.split(state.key, 3)
        params = state.params
        data, vr_rows, honest_stal, cohort = round_inputs(state)
        honest_tree, idx = honest_grads(params, k_idx, data)
        spec = cfg.message_spec(honest_tree, batch_ndim=1)
        honest = spec.pack(honest_tree)                       # (W_h, D)
        honest, vr_rows, vr_metrics = correct(params, vr_rows, honest, idx,
                                              k_idx, data=data, spec=spec)
        vr_state, staleness = finish_round(state, cohort, vr_rows)

        # Wire quantization (DESIGN.md Sec. 12): honest senders transmit
        # post-VR-correction -- what the master sees (and what the variance
        # metric and the attacks observe) is the DEQUANTIZED wire.  sign1
        # folds each client's carried residual in before quantizing and
        # banks the fresh error; the cohort gather/scatter brackets the
        # residual table exactly like the VR state.
        ef_state = state.ef
        if wire_fmt.quantized:
            ef_rows = state.ef
            if wire_fmt.error_feedback and plan is not None:
                ef_rows = participation_lib.gather_rows(state.ef, cohort)
            honest, ef_rows = spec.transmit(honest, ef_rows)
            if wire_fmt.error_feedback:
                ef_state = (participation_lib.scatter_rows(
                    state.ef, cohort, ef_rows)
                    if plan is not None else ef_rows)

        var = telemetry.honest_variance(honest, wh)

        msgs = attack_lib.apply_attack(attack_cfg, honest, k_attack,
                                       spec=spec)             # (W, D)
        if wire_fmt.quantized:
            # Byzantine payloads are wire-constrained too: re-quantizing the
            # full buffer sends the attack rows through the same format
            # (honest rows are already a fixed point of the round-trip).
            msgs = spec.wire_roundtrip(msgs)
        rw, slot_stal = row_weights_for(honest_stal)
        metrics = {"honest_variance": var, **vr_metrics,
                   **telemetry.staleness_metrics(slot_stal)}
        flat_fn = cfg.flat_aggregator_fn(spec)
        if cfg.guards:
            # Containment on the DEQUANTIZED wire (the roundtrip above
            # already ran): the guard sees exactly what the rule would
            # consume -- dequantize-then-guard ordering, DESIGN.md Sec. 13.
            gmask = guards_lib.guard_mask(
                msgs, multiplier=cfg.guard_multiplier, base_weights=rw)
            out = guards_lib.guarded_flat_call(flat_fn, msgs, gmask,
                                               row_weights=rw)
            metrics["quarantined_rows"] = jnp.sum(1.0 - gmask)
        else:
            out = (flat_fn(msgs) if rw is None
                   else flat_fn(msgs, row_weights=rw))
        if cfg.diagnostics:
            agg_vec, diag = out                               # (D,) f32
            metrics.update(telemetry.diagnostics_metrics(diag))
        else:
            agg_vec = out                                     # (D,) f32
        agg = spec.unpack(agg_vec, batch_ndim=0)
        updates, opt_state = optimizer.update(agg, state.opt_state, params, state.step)
        new_params = optim_lib.apply_updates(params, updates)
        health = state.health
        if cfg.guards:
            # Round-health verdict (same hold as the per-leaf step).
            accept, health = guards_lib.round_verdict(
                guards_lib.tree_norm(agg_vec), state.health,
                decay=cfg.reject_ema, zmax=cfg.reject_zmax,
                warmup=cfg.reject_warmup)
            new_params, opt_state, vr_state, ef_state = \
                guards_lib.select_tree(
                    accept, (new_params, opt_state, vr_state, ef_state),
                    (params, state.opt_state, state.vr, state.ef))
            metrics.update(telemetry.health_metrics(health, accept))
        new_state = FederatedState(new_params, opt_state, vr_state,
                                   state.step + 1, key, staleness, ef_state,
                                   health)
        return new_state, metrics

    return init_fn, (step_fn_packed if cfg.packed else step_fn_perleaf)


# ---------------------------------------------------------------------------
# Distributed aggregation (inside shard_map).  One worker per index of the
# mesh worker axes; each worker's gradient leaves are local shards over the
# model axes.
# ---------------------------------------------------------------------------

def _flatten_concat(
    tree: Pytree,
) -> tuple[jnp.ndarray, Callable[[jnp.ndarray], Pytree], list[int]]:
    """Ravel a pytree into one fp32 vector + inverse (restoring dtypes) +
    the per-leaf flat sizes (the block boundaries sharded geomed_blockwise
    needs).  Thin wrapper over :mod:`repro.core.packing` so the sharded
    comm path and the PackSpec layout can never drift apart."""
    spec = packing.pack_spec(tree, batch_ndim=0)
    flat = spec.pack(tree, batch_ndim=0)
    return flat, lambda vec: spec.unpack(vec, batch_ndim=0), list(spec.sizes)


def _local_leaf_ids(leaf_sizes: Sequence[int], pad: int, num_workers: int,
                    worker_axes: tuple[str, ...]) -> jnp.ndarray:
    """(chunk,) leaf/block id of every coordinate in this device's
    all_to_all slice, derived on-device from the (num_leaves,) cumulative
    leaf boundaries -- no O(p) constant.  Coordinate c belongs to the leaf
    whose cumulative upper bound first exceeds c; padding coordinates land
    past every bound, i.e. in the dummy block ``len(leaf_sizes)``.  The
    linear worker index picks the coordinate range (fully-manual shard_map,
    so compat.axis_index lowers fine)."""
    chunk = (sum(leaf_sizes) + pad) // num_workers
    wid = compat.axis_index(worker_axes)
    coords = wid * chunk + jax.lax.iota(jnp.int32, chunk)
    bounds = jnp.asarray(np.cumsum(leaf_sizes).astype(np.int32))
    return jnp.searchsorted(bounds, coords, side="right").astype(jnp.int32)


def distributed_aggregate(
    grads: Pytree,
    cfg: RobustConfig,
    *,
    worker_axes: tuple[str, ...] = ("data",),
    model_axes: tuple[str, ...] = ("model",),
    row_weights: Optional[jnp.ndarray] = None,
    diagnostics: Optional[bool] = None,
) -> Pytree:
    """Paper-faithful ``gather`` master: all_gather every worker's (model-
    sharded) gradient over the worker axes, then run the robust rule
    redundantly on every device.  Collective volume: W * p_shard bytes
    gathered per device -- the cost the Sec-Perf hillclimb attacks.

    With ``cfg.packed`` (default) the local shard is packed into ONE
    vector first, so the gather is a single collective (instead of one per
    leaf) and the rule runs on the packed (W, D_shard) matrix with one
    norm psum per iteration (DESIGN.md Sec. 8); ``packed=False`` keeps the
    pre-refactor per-leaf pipeline.

    ``row_weights``: optional (W,) staleness weights, REPLICATED on every
    device (a ``P()`` shard_map input), consumed by the flat engines --
    packed path only (the per-leaf baseline predates the weighted rules
    and is kept byte-for-byte).

    ``diagnostics`` (default ``cfg.diagnostics``): packed path only; when
    on, returns ``(tree, AggDiagnostics)`` with the struct replicated on
    every device (the per-row distance psums over ``model_axes`` make it
    so)."""
    diag_on = cfg.diagnostics if diagnostics is None else diagnostics
    if cfg.packed:
        spec = cfg.message_spec(grads, batch_ndim=0)
        buf = spec.pack(grads, batch_ndim=0)                  # (D_shard,)
        if spec.quantized:
            # The QUANTIZED buffer is what crosses the wire: int8 codes (+
            # one f32 scale per block) are all_gather'd and dequantized on
            # the receiver.  Block statistics reduce over the model axes so
            # the per-block scales are the FULL-leaf scales and the codes
            # match the single-host encode (DESIGN.md Sec. 12).
            codes, scales = spec.encode(buf, axis_names=model_axes)
            stacked = spec.decode(
                compat.all_gather(codes, worker_axes, axis=0, tiled=False),
                compat.all_gather(scales, worker_axes, axis=0, tiled=False))
        else:
            stacked = compat.all_gather(buf, worker_axes, axis=0,
                                        tiled=False)
        flat_fn = cfg.flat_aggregator_fn(
            spec, axis_names=model_axes, sync_axes=worker_axes,
            diagnostics=diag_on)
        if cfg.guards:
            # Row norms/finiteness psum over the MODEL axes only: after the
            # all_gather the worker axes are replicated, so every device
            # computes the same full-vector validity mask.
            gmask = guards_lib.guard_mask(
                stacked, multiplier=cfg.guard_multiplier,
                base_weights=row_weights, axis_names=model_axes)
            out = guards_lib.guarded_flat_call(flat_fn, stacked, gmask,
                                               row_weights=row_weights)
        elif row_weights is None:
            out = flat_fn(stacked)
        else:
            out = flat_fn(stacked, row_weights=row_weights)
        if diag_on:
            agg_vec, diag = out
            return spec.unpack(agg_vec, batch_ndim=0), diag
        return spec.unpack(out, batch_ndim=0)
    if row_weights is not None:
        raise ValueError(
            "staleness row_weights need the packed gather path "
            "(cfg.packed=True); the per-leaf baseline is unweighted")
    if cfg.guards:
        raise ValueError(
            "fault-containment guards need the packed gather path "
            "(cfg.packed=True); the per-leaf baseline has no flat buffer "
            "to mask")
    if diag_on:
        raise ValueError(
            "aggregation diagnostics need the packed gather path "
            "(cfg.packed=True); the per-leaf baseline has no flat buffer")
    if cfg.wire_format().quantized:
        raise ValueError(
            f"message_dtype={cfg.message_dtype!r} is a quantized wire "
            "format and needs the packed gather path (cfg.packed=True)")
    # Multi-axis all_gather already collapses the worker axes into ONE
    # leading (W_total,) axis in row-major worker order (compat.all_gather),
    # so single- and multi-pod meshes land on the same stacked layout.
    stacked = jax.tree_util.tree_map(
        lambda g: compat.all_gather(g, worker_axes, axis=0, tiled=False), grads
    )
    name = cfg.aggregator
    if name == "mean":
        return agg_lib.mean_agg_perleaf(stacked)
    if name == "median":
        return agg_lib.median_agg_perleaf(stacked)
    if name == "trimmed_mean":
        return agg_lib.trimmed_mean_agg_perleaf(stacked, trim=cfg.trim)
    if name in ("geomed", "geomed_groups"):
        if name == "geomed_groups":
            stacked = jax.tree_util.tree_map(
                functools.partial(agg_lib.group_means, num_groups=cfg.num_groups),
                stacked)
        return weiszfeld_pytree(
            stacked, max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
            axis_names=model_axes, sync_axes=worker_axes,
        )
    if name == "geomed_blockwise":
        # Per-leaf norms: each parameter block aggregates independently
        # (ZeRO-compatible; weaker per-block guarantee -- see aggregators).
        return jax.tree_util.tree_map(
            lambda z: weiszfeld_pytree(
                z, max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
                axis_names=model_axes, sync_axes=worker_axes),
            stacked)
    if name == "krum":
        return _distributed_krum(stacked, cfg, model_axes)
    if name == "centered_clip":
        # Full-vector residual norms need a psum over the model axes only
        # (the worker axis is materialized by the all_gather above).
        return agg_lib.centered_clip_agg_perleaf(
            stacked, radius=cfg.clip_radius, axis_names=tuple(model_axes))
    raise ValueError(f"unsupported distributed aggregator {name!r}; "
                     f"supported: {GATHER_AGGREGATORS}")


# Aggregators available on each distributed comm path.  Since PR 2 both
# paths cover the whole registry (sharded krum via a partial-Gram psum,
# sharded geomed_blockwise via segmented Weiszfeld); the split names are
# kept because tests and benchmarks enumerate each path explicitly.
GATHER_AGGREGATORS = agg_lib.AGGREGATOR_NAMES
SHARDED_AGGREGATORS = agg_lib.AGGREGATOR_NAMES


def _partial_gram_sq_dists(flat: jnp.ndarray,
                           axes: tuple[str, ...]) -> jnp.ndarray:
    """(W, W) squared distances from each device's (W, c) coordinate slice:
    the local Gram partials are psum'd over ``axes``, which restores the
    full-vector pairwise geometry because squared distances are separable
    over any coordinate partition."""
    sq = jnp.sum(flat ** 2, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (flat @ flat.T)
    return compat.psum(d2, axes) if axes else d2


def _distributed_krum(stacked: Pytree, cfg: RobustConfig,
                      model_axes: tuple[str, ...]) -> Pytree:
    leaves = [z.reshape(z.shape[0], -1).astype(jnp.float32)
              for z in jax.tree_util.tree_leaves(stacked)]
    flat = jnp.concatenate(leaves, axis=-1)
    scores = agg_lib.krum_scores(
        _partial_gram_sq_dists(flat, tuple(model_axes)), cfg.num_byzantine)
    best = jnp.argmin(scores)
    return jax.tree_util.tree_map(lambda z: z[best], stacked)


def sharded_aggregate(
    grads: Pytree,
    cfg: RobustConfig,
    *,
    worker_axes: tuple[str, ...] = ("data",),
    model_axes: tuple[str, ...] = ("model",),
    num_workers: int,
    row_weights: Optional[jnp.ndarray] = None,
    diagnostics: Optional[bool] = None,
) -> Pytree:
    """Beyond-paper ``sharded`` master (DESIGN.md Sec. 2, comm=sharded).

    Instead of replicating the (W, p) message matrix, re-shard it by
    coordinate with an ``all_to_all`` over the worker axes (one axis or
    ``(pod, data)``): every device ends up with a distinct p_shard/W
    coordinate slice of ALL W messages, the rule runs on the slices with
    global geometry restored by small psums, and the aggregated slices are
    re-assembled with an all_gather.  Bytes moved per device drop from the
    gather master's O(W * p_shard) to O(2 * p_shard) plus the per-rule
    psums:

    * coordinate-separable rules (mean/median/trimmed_mean) need none;
    * geomed / geomed_groups / centered_clip psum W floats of per-worker
      norm partials per Weiszfeld/clip iteration;
    * krum reuses the same coordinate resharding but psums one (W, W)
      partial Gram matrix -- squared distances are separable over any
      coordinate partition -- and then selects the winning slice everywhere;
    * geomed_blockwise keeps per-leaf norms via block-segmented Weiszfeld
      (one (W, num_leaves) psum per iteration, ``weiszfeld_blockwise_sharded``).

    Every registry aggregator is supported (``SHARDED_AGGREGATORS``).
    ``row_weights``: optional (W,) staleness weights, REPLICATED on every
    device; the same weighted forms run on the coordinate slices unchanged
    because every flat engine treats the weights per ROW (DESIGN.md
    Sec. 10).  ``None`` keeps every branch bit-for-bit.

    ``diagnostics`` (default ``cfg.diagnostics``): when on, returns
    ``(tree, AggDiagnostics)``; the struct's per-row distance/Gram psums
    run over worker+model axes, so it carries full-vector geometry and is
    replicated on every device.  The off path is byte-identical to before.
    """
    diag_on = cfg.diagnostics if diagnostics is None else diagnostics
    w = num_workers
    flat, unflatten, leaf_sizes = _flatten_concat(grads)
    p = flat.shape[0]
    pad = (-p) % w
    wire_fmt = cfg.wire_format()
    if wire_fmt.quantized:
        # Quantized coordinates through the all_to_all (the comm-volume
        # win ROADMAP item 3 targets): each worker encodes its FULL local
        # message once (block stats psum'd over the model axes so the
        # scales are whole-leaf), ships int8 code slices, all_gathers the
        # tiny (W, num_leaves) scale matrix, and dequantizes its slice
        # per-coordinate -- the slice cuts across leaf boundaries, so
        # the seg-id map picks each coordinate's scale (padding
        # coordinates hit the dummy zero column).  Everything after this
        # point accumulates in f32, unchanged.
        wspec = packing.pack_spec(grads, batch_ndim=0, wire=wire_fmt)
        codes, scales = wspec.encode(flat, axis_names=model_axes)
        codes = jnp.pad(codes, (0, pad)).reshape(w, -1)
        z_codes = compat.all_to_all(codes, worker_axes, split_axis=0,
                                    concat_axis=0, tiled=False).reshape(w, -1)
        z_local = packing.dequantize_slice(
            z_codes,
            compat.all_gather(scales, worker_axes, axis=0, tiled=False),
            _local_leaf_ids(leaf_sizes, pad, w, worker_axes))
    else:
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(w, -1)  # row r = my message's slice destined to worker r
        # After all_to_all: row r = worker r's slice for MY coordinate range.
        z_local = compat.all_to_all(chunks, worker_axes, split_axis=0,
                                    concat_axis=0, tiled=False)
        z_local = z_local.reshape(w, -1)
    comm_axes = tuple(worker_axes) + tuple(model_axes)
    rw = row_weights
    gmask = None
    if cfg.guards:
        # Guard geometry on the coordinate slices: the per-row partial
        # stats psum over worker+model axes, so the (W,) validity mask
        # reflects FULL-vector norms and is replicated on every device.
        gmask = guards_lib.guard_mask(
            z_local, multiplier=cfg.guard_multiplier, base_weights=rw,
            axis_names=comm_axes)

    name = cfg.aggregator
    if diag_on:
        # Diagnostics route every rule through the registry flat engines
        # (same per-row math as the inline branches below, plus the struct):
        # the engines psum their per-row partials over ``comm_axes``, so the
        # struct reflects full-vector geometry and is replicated.  With
        # guards the mask simply folds into the row weights (diagnostics
        # carries no bit-identity promise).
        if gmask is not None:
            z_local = guards_lib.sanitize_rows(z_local, gmask)
            rw = gmask if rw is None else rw * gmask
        common = dict(axis_names=comm_axes, row_weights=rw, diagnostics=True)
        if name == "mean":
            slice_agg, diag = agg_lib.mean_flat(z_local, **common)
        elif name == "median":
            slice_agg, diag = agg_lib.median_flat(z_local, **common)
        elif name == "trimmed_mean":
            slice_agg, diag = agg_lib.trimmed_mean_flat(
                z_local, trim=cfg.trim, **common)
        elif name == "geomed":
            slice_agg, diag = agg_lib.geomed_flat(
                z_local, max_iters=cfg.weiszfeld_iters,
                tol=cfg.weiszfeld_tol, **common)
        elif name == "geomed_groups":
            slice_agg, diag = agg_lib.geomed_groups_flat(
                z_local, num_groups=cfg.num_groups,
                max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
                **common)
        elif name == "centered_clip":
            slice_agg, diag = agg_lib.centered_clip_flat(
                z_local, radius=cfg.clip_radius, **common)
        elif name == "krum":
            slice_agg, diag = agg_lib.krum_flat(
                z_local, num_byzantine=cfg.num_byzantine, **common)
        elif name == "geomed_blockwise":
            slice_agg, info = weiszfeld_blockwise_sharded(
                z_local,
                _local_leaf_ids(leaf_sizes, pad, w, worker_axes),
                len(leaf_sizes) + 1,
                axis_names=comm_axes,
                max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
                row_weights=rw, return_info=True)
            diag = telemetry.flat_diagnostics(
                z_local, slice_agg, row_weights=rw, axis_names=comm_axes,
                residual=info.residual, iters=info.iters,
                converged=info.converged)
        else:
            raise ValueError(
                f"unknown aggregator {name!r} for comm='sharded'; "
                f"supported: {SHARDED_AGGREGATORS}")
        full = compat.all_gather(slice_agg, worker_axes, axis=0,
                                 tiled=False).reshape(-1)
        return unflatten(full[:p]), diag
    def run(z, rw_):
        # One closure over the (slice, weights) pair so the guards path can
        # evaluate the SAME inline branches twice (unweighted baseline +
        # mask-weighted fold) and select -- see below.
        if name == "mean":
            return (jnp.mean(z, axis=0) if rw_ is None
                    else agg_lib.mean_flat(z, row_weights=rw_))
        if name == "median":
            return (jnp.median(z, axis=0) if rw_ is None
                    else agg_lib.median_flat(z, row_weights=rw_))
        if name == "trimmed_mean":
            if rw_ is None:
                s = jnp.sort(z, axis=0)
                return jnp.mean(s[cfg.trim : w - cfg.trim], axis=0)
            return agg_lib.trimmed_mean_flat(z, trim=cfg.trim,
                                             row_weights=rw_)
        if name == "geomed":
            return weiszfeld_flat(
                z, max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
                axis_names=comm_axes, row_weights=rw_,
            )
        if name == "geomed_groups":
            if rw_ is None:
                return weiszfeld_flat(
                    agg_lib.group_means(z, cfg.num_groups),
                    max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
                    axis_names=comm_axes,
                )
            # Weighted group means + group-mass Weiszfeld: per-row math, so
            # the coordinate slices aggregate consistently across devices.
            return agg_lib.geomed_groups_flat(
                z, num_groups=cfg.num_groups,
                max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
                axis_names=comm_axes, row_weights=rw_)
        if name == "centered_clip":
            # Same psum trick as the distributed Weiszfeld: full-vector
            # residual norms are restored by a psum of W floats over
            # worker+model axes.
            return agg_lib.centered_clip_flat(
                z, radius=cfg.clip_radius, axis_names=comm_axes,
                row_weights=rw_)
        if name == "krum":
            # Pairwise-distance resharding: the (W, W) Gram partials of the
            # coordinate slices psum to the full-vector pairwise distances,
            # so the (replicated) selection index is exact; the winner's
            # slices are reassembled by the common all_gather below.
            if rw_ is None:
                scores = agg_lib.krum_scores(
                    _partial_gram_sq_dists(z, comm_axes), cfg.num_byzantine)
                return z[jnp.argmin(scores)]
            # Weighted selection: the scores (hence argmin) are replicated
            # because the Gram psum restores global geometry and the
            # weights are replicated, so every device picks the same row.
            return agg_lib.krum_flat(
                z, num_byzantine=cfg.num_byzantine,
                axis_names=comm_axes, row_weights=rw_)
        if name == "geomed_blockwise":
            # Per-leaf norms survive the resharding because every coordinate
            # knows its block id: segmented Weiszfeld psums a
            # (W, num_leaves) matrix per iteration instead of W floats.
            return weiszfeld_blockwise_sharded(
                z,
                _local_leaf_ids(leaf_sizes, pad, w, worker_axes),
                len(leaf_sizes) + 1,  # + dummy block for padding coordinates
                axis_names=comm_axes,
                max_iters=cfg.weiszfeld_iters, tol=cfg.weiszfeld_tol,
                row_weights=rw_)
        raise ValueError(
            f"unknown aggregator {name!r} for comm='sharded'; "
            f"supported: {SHARDED_AGGREGATORS}")

    if gmask is None:
        slice_agg = run(z_local, rw)
    elif rw is not None:
        # Existing staleness weights: the mask folds multiplicatively, and
        # valid rows keep their weight bitwise (rw * 1.0 == rw exactly).
        slice_agg = run(guards_lib.sanitize_rows(z_local, gmask),
                        rw * gmask)
    else:
        # No base weights: all-ones-weighted engines are NOT bitwise
        # identical to the unweighted fast paths, so both are evaluated and
        # the baseline bytes win whenever no row was quarantined (guards
        # module docstring) -- redundant aggregation is the price of
        # armed guards, never of guards=False.
        out_w = run(guards_lib.sanitize_rows(z_local, gmask), gmask)
        slice_agg = jnp.where(guards_lib.all_valid(gmask),
                              run(z_local, None), out_w)

    # Re-assemble the full (padded) vector on every worker.
    full = compat.all_gather(slice_agg, worker_axes, axis=0,
                             tiled=False).reshape(-1)
    return unflatten(full[:p])


def distributed_attack(
    msg: Pytree,
    cfg: RobustConfig,
    *,
    worker_axes: tuple[str, ...] = ("data",),
    key: Optional[jax.Array] = None,
) -> Pytree:
    """Inject Byzantine behaviour inside ``shard_map``: workers with index
    < num_byzantine replace their message per the attack model.  Honest
    statistics are obtained with masked psums over the worker axes (the
    paper's attackers are colluding/omniscient, so this leaks nothing that
    the threat model doesn't already grant them)."""
    if cfg.attack == "none" or cfg.num_byzantine == 0:
        return msg
    w = 1
    for a in worker_axes:
        w = w * compat.axis_size(a)
    wid = compat.axis_index(worker_axes)
    b = cfg.num_byzantine
    wh = w - b
    is_byz = wid < b

    def masked_sum(x):
        return compat.psum(jnp.where(is_byz, 0.0, 1.0) * x.astype(jnp.float32),
                           worker_axes)

    honest_mean = jax.tree_util.tree_map(lambda x: masked_sum(x) / wh, msg)

    name = cfg.attack
    if name == "sign_flip":
        byz = jax.tree_util.tree_map(lambda m: cfg.sign_flip_magnitude * m, honest_mean)
    elif name == "zero_gradient":
        byz = jax.tree_util.tree_map(lambda m: -(wh / b) * m, honest_mean)
    elif name == "ipm":
        byz = jax.tree_util.tree_map(lambda m: -cfg.ipm_eps * m, honest_mean)
    elif name == "gaussian":
        if key is None:
            raise ValueError("gaussian attack needs a per-worker key")
        std = jnp.sqrt(cfg.gaussian_variance)
        leaves, treedef = jax.tree_util.tree_flatten(honest_mean)
        keys = jax.random.split(jax.random.fold_in(key, wid), len(leaves))
        byz = jax.tree_util.tree_unflatten(
            treedef,
            [m + std * jax.random.normal(k, m.shape, jnp.float32) for m, k in zip(leaves, keys)],
        )
    elif name == "alie":
        sq_mean = jax.tree_util.tree_map(lambda x: masked_sum(x * x) / wh, msg)
        byz = jax.tree_util.tree_map(
            lambda m, s: m + cfg.alie_z * jnp.sqrt(jnp.maximum(s - m * m, 0.0)),
            honest_mean, sq_mean)
    elif name == "straggler":
        # Stale-by-k report: a scaled honest mean standing in for a message
        # computed k rounds ago (the same deterministic proxy the sim path
        # uses, so cross-path pins compare like with like).
        byz = jax.tree_util.tree_map(
            lambda m: (1.0 + 0.25 * cfg.straggler_k) * m, honest_mean)
    elif name == "dropout":
        # Absent worker: the slot's payload is zeros; the bounded-staleness
        # weights (slot staleness = max_staleness -> weight exactly 0) are
        # what actually remove it from the aggregation -- mask-select, the
        # worker axis is never sliced.
        byz = jax.tree_util.tree_map(jnp.zeros_like, honest_mean)
    elif name == "nan":
        byz = jax.tree_util.tree_map(
            lambda m: jnp.full_like(m, jnp.nan), honest_mean)
    elif name == "inf_overflow":
        byz = jax.tree_util.tree_map(
            lambda m: jnp.where(m < 0, -attack_lib.OVERFLOW_MAGNITUDE,
                                attack_lib.OVERFLOW_MAGNITUDE
                                ).astype(m.dtype),
            honest_mean)
    elif name == "bitflip":
        # Hash input is the RELATIVE Byzantine index (wid, matching the
        # replace-first layout).  Coordinate indices are LOCAL to this
        # device's shard of each leaf -- deterministic and layout-stable
        # for a fixed mesh, but not pinned against the single-host
        # apply_attack coordinates (the sim/packed pins cover that form).
        flipped = attack_lib.bitflip_rows(
            honest_mean, wid[None].astype(jnp.int32),
            prob=cfg.bitflip_prob, seed=cfg.bitflip_seed)
        byz = jax.tree_util.tree_map(lambda z: z[0], flipped)
    else:
        raise ValueError(f"unknown attack {name!r}")

    return jax.tree_util.tree_map(
        lambda orig, bad: jnp.where(is_byz, bad.astype(jnp.float32), orig.astype(jnp.float32)).astype(orig.dtype),
        msg, byz)
