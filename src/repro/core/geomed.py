"""Geometric median via the Weiszfeld algorithm (paper eq. (6), Remark 1).

The geometric median of a set ``{z_w}`` is ``argmin_y sum_w ||y - z_w||``.
Computing it exactly is costly, so (as in the paper, following Weiszfeld/
Plastria [32]) we use the iteration

    y^{t+1} = sum_w z_w / d_w  /  sum_w 1 / d_w,      d_w = max(||z_w - y^t||, nu)

stopped after ``max_iters`` iterations or when the iterate moves less than
``tol`` (an epsilon-approximate geometric median in the sense of eq. (12)).

Three entry points:

* :func:`weiszfeld`           -- dense ``(W, p)`` stacked messages.
* :func:`weiszfeld_flat`      -- one packed ``(W, D)`` message matrix
                                 (:mod:`repro.core.packing`): the flat
                                 engine behind the pytree aggregator shims
                                 (DESIGN.md Sec. 8); one fused distance
                                 reduction and one psum per iteration.
* :func:`weiszfeld_pytree`    -- messages are pytrees with a leading worker
                                 axis on every leaf (norms taken over the full
                                 concatenated vector, NOT per-leaf).
* :func:`weiszfeld_sharded`   -- for use inside ``shard_map``: every device
                                 holds a coordinate-slice of all W messages;
                                 squared-distance partials are ``psum``-ed over
                                 the given mesh axes each iteration, so the
                                 heavy (W, p) matrix never needs to be
                                 replicated.  This is the beyond-paper
                                 distributed Weiszfeld described in DESIGN.md.
* :func:`weiszfeld_blockwise_sharded` -- segmented variant of the above for
                                 ``geomed_blockwise``: every parameter block
                                 (pytree leaf) runs its own Weiszfeld, jointly,
                                 with one fused (W, num_blocks) psum per
                                 iteration (DESIGN.md Sec. 2).

All variants are jit-compatible (``lax.while_loop``).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro import compat

Pytree = Any

# Numerical floor for distances; plays the role of Weiszfeld smoothing so the
# iteration is well defined when y coincides with one of the points.
_DIST_FLOOR = 1e-8


class WeiszfeldInfo(NamedTuple):
    """Convergence facts of one Weiszfeld solve (telemetry, DESIGN.md
    Sec. 11).  The while_loop already carries all three -- ``return_info``
    merely stops discarding them, so the default return path is unchanged."""

    residual: jnp.ndarray   # () f32 final iterate move (inf if 0 iterations)
    iters: jnp.ndarray      # () int32 iterations run
    converged: jnp.ndarray  # () bool residual <= tol


def _weiszfeld_body(points: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """One Weiszfeld iteration on dense stacked points (W, p)."""
    d = jnp.sqrt(jnp.sum((points - y[None, :]) ** 2, axis=-1))
    inv = 1.0 / jnp.maximum(d, _DIST_FLOOR)
    return (inv @ points) / jnp.sum(inv)


def geomed_objective(points: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """sum_w ||y - z_w|| -- the objective of eq. (6)."""
    return jnp.sum(jnp.sqrt(jnp.sum((points - y[None, :]) ** 2, axis=-1)))


def weiszfeld(
    points: jnp.ndarray,
    *,
    max_iters: int = 64,
    tol: float = 1e-6,
) -> jnp.ndarray:
    """Epsilon-approximate geometric median of ``points`` with shape (W, p).

    Initialised at the coordinate-wise mean.  Runs at most ``max_iters``
    Weiszfeld iterations, stopping early once the iterate moves less than
    ``tol`` in l2 norm.
    """
    points = jnp.asarray(points)
    if points.ndim != 2:
        raise ValueError(f"weiszfeld expects (W, p), got {points.shape}")
    y0 = jnp.mean(points, axis=0)

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(it < max_iters, delta > tol)

    def body(state):
        y, _, it = state
        y_new = _weiszfeld_body(points, y)
        delta = jnp.sqrt(jnp.sum((y_new - y) ** 2))
        return y_new, delta, it + 1

    y, _, _ = jax.lax.while_loop(cond, body, (y0, jnp.asarray(jnp.inf, points.dtype), 0))
    return y


# ---------------------------------------------------------------------------
# Pytree variant: worker messages are whole gradient pytrees.
# ---------------------------------------------------------------------------

def _tree_sqdist_partials(stacked: Pytree, y: Pytree) -> jnp.ndarray:
    """Per-worker squared distances summed across all leaves -> (W,)."""
    leaves = jax.tree_util.tree_leaves(stacked)
    y_leaves = jax.tree_util.tree_leaves(y)
    total = None
    for z, yl in zip(leaves, y_leaves):
        w = z.shape[0]
        part = jnp.sum(
            (z.reshape(w, -1).astype(jnp.float32) - yl.reshape(1, -1).astype(jnp.float32)) ** 2,
            axis=-1,
        )
        total = part if total is None else total + part
    return total


def _tree_weighted_mean(stacked: Pytree, weights: jnp.ndarray) -> Pytree:
    """sum_w weights[w] * z_w / sum(weights), per leaf."""
    wsum = jnp.maximum(jnp.sum(weights), _DIST_FLOOR)

    def leaf(z):
        w = weights.reshape((z.shape[0],) + (1,) * (z.ndim - 1)).astype(jnp.float32)
        return jnp.sum(z.astype(jnp.float32) * w, axis=0) / wsum

    out = jax.tree_util.tree_map(leaf, stacked)
    # Restore original leaf dtypes.
    return jax.tree_util.tree_map(lambda o, z: o.astype(z.dtype), out, jax.tree_util.tree_map(lambda z: z[0], stacked))


def weiszfeld_pytree(
    stacked: Pytree,
    *,
    max_iters: int = 64,
    tol: float = 1e-6,
    axis_names: Sequence[str] = (),
    sync_axes: Sequence[str] = (),
    row_weights: jnp.ndarray | None = None,
    return_info: bool = False,
) -> Pytree:
    """Geometric median of W pytree messages.

    ``stacked``: pytree whose every leaf has a leading worker axis of size W.
    Distances are over the full concatenated parameter vector (all leaves),
    matching the paper: the master aggregates the whole p-dim message.

    ``axis_names``: if non-empty, the leaves are assumed to be *coordinate
    shards* inside a ``shard_map`` and the squared-distance partials are
    ``psum``-ed over those mesh axes (distributed Weiszfeld).  The returned
    median is then the local coordinate shard of the global median.

    ``sync_axes``: additional mesh axes over which the (numerically already
    identical) stopping statistic is ``pmax``-synchronized, so the
    ``while_loop`` predicate is replicated across all devices (required for
    lockstep SPMD early stopping).  Use the worker axes here in gather mode.

    ``row_weights``: optional (W,) per-message weights (the bounded-staleness
    weights of DESIGN.md Sec. 10).  Each message's Weiszfeld contribution
    ``1/d_w`` is scaled by its weight, so weight 0 removes a row exactly
    (the mask-as-weight trick of :mod:`repro.topology.masked`) and fractional
    weights down-weigh stale reports.  ``None`` keeps the unweighted code
    path bit-for-bit.

    The iterate stays float32 throughout and is cast back to the leaf dtypes
    only on return: re-quantizing y to bf16 every iteration would both slow
    convergence and make gather-mode results drift from the sharded path
    (which flattens to f32 once up front).
    """
    stacked32 = jax.tree_util.tree_map(
        lambda z: z.astype(jnp.float32), stacked)
    y0 = jax.tree_util.tree_map(lambda z: jnp.mean(z, axis=0), stacked32)

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(it < max_iters, delta > tol)

    def body(state):
        y, _, it = state
        sq = _tree_sqdist_partials(stacked32, y)
        for ax in axis_names:
            sq = jax.lax.psum(sq, ax)
        inv = 1.0 / jnp.maximum(jnp.sqrt(sq), _DIST_FLOOR)
        if row_weights is not None:
            inv = inv * row_weights.astype(jnp.float32)
        y_new = _tree_weighted_mean(stacked32, inv)

        move = sum(
            jnp.sum((a - b) ** 2)
            for a, b in zip(jax.tree_util.tree_leaves(y_new), jax.tree_util.tree_leaves(y))
        )
        for ax in axis_names:
            move = jax.lax.psum(move, ax)
        for ax in sync_axes:
            move = jax.lax.pmax(move, ax)
        return y_new, jnp.sqrt(move), it + 1

    state0 = (y0, jnp.asarray(jnp.inf, jnp.float32), 0)
    y, delta, it = jax.lax.while_loop(cond, body, state0)
    out = jax.tree_util.tree_map(lambda yl, z: yl.astype(z.dtype), y, stacked)
    if return_info:
        return out, WeiszfeldInfo(residual=delta,
                                  iters=jnp.asarray(it, jnp.int32),
                                  converged=delta <= tol)
    return out


def weiszfeld_flat(
    buf: jnp.ndarray,
    *,
    max_iters: int = 64,
    tol: float = 1e-6,
    axis_names: Sequence[str] = (),
    sync_axes: Sequence[str] = (),
    row_weights: jnp.ndarray | None = None,
    return_info: bool = False,
) -> jnp.ndarray:
    """Weiszfeld on one packed ``(W, D)`` message matrix -- the flat engine
    behind the pytree shims (DESIGN.md Sec. 8).

    A 2-D array is the single-leaf case of :func:`weiszfeld_pytree`, so the
    math is shared: per iteration ONE fused squared-distance reduction over
    the packed coordinate axis (instead of one per pytree leaf), one fused
    weighted mean, and -- under ``shard_map`` -- one ``psum`` of W floats
    over ``axis_names`` (instead of per-leaf collectives).  Returns the
    ``(D,)`` float32 geometric median; callers unpack/cast.
    """
    if buf.ndim != 2:
        raise ValueError(f"weiszfeld_flat expects (W, D), got {buf.shape}")
    return weiszfeld_pytree(
        buf.astype(jnp.float32), max_iters=max_iters, tol=tol,
        axis_names=axis_names, sync_axes=sync_axes, row_weights=row_weights,
        return_info=return_info)


def weiszfeld_sharded(
    z_local: jnp.ndarray,
    *,
    axis_names: Sequence[str],
    max_iters: int = 64,
    tol: float = 1e-6,
) -> jnp.ndarray:
    """Distributed Weiszfeld inside ``shard_map``.

    ``z_local``: (W, p_local) -- this device's coordinate slice of all W
    messages.  Per-iteration communication is a single ``psum`` of W floats
    over ``axis_names``; the (W, p) matrix itself is never replicated.
    Returns the local slice (p_local,) of the global geometric median.
    """
    return weiszfeld_pytree(
        z_local, max_iters=max_iters, tol=tol, axis_names=axis_names
    )


def weiszfeld_blockwise_sharded(
    z_local: jnp.ndarray,
    seg_ids: jnp.ndarray,
    num_segments: int,
    *,
    axis_names: Sequence[str],
    max_iters: int = 64,
    tol: float = 1e-6,
    row_weights: jnp.ndarray | None = None,
    return_info: bool = False,
) -> jnp.ndarray:
    """Per-block (segmented) distributed Weiszfeld inside ``shard_map``.

    ``z_local``: (W, c) -- this device's coordinate slice of all W messages
    (the same layout as :func:`weiszfeld_sharded`).  ``seg_ids``: (c,) int32
    block id of each local coordinate; a block is one pytree leaf of the
    original gradient, so this computes ``geomed_blockwise`` (independent
    geometric median per leaf) without ever gathering the leaves.  Padding
    coordinates should carry a dedicated dummy block id (their all-zero
    messages then median to zero and never affect real blocks).

    All blocks iterate in lockstep: one fused psum of a (W, num_segments)
    matrix of per-(worker, block) squared-distance partials over
    ``axis_names`` per iteration, instead of num_segments separate W-float
    psums.  Each coordinate is reweighted by its own block's inverse
    distances, and the loop stops when the largest per-block iterate move
    drops below ``tol`` (a block that converged early simply keeps its
    fixed point).  Returns the (c,) f32 local slice of all blocks' medians.
    """
    z32 = z_local.astype(jnp.float32)

    def seg_psum(coord_partials):
        """(..., c) per-coordinate partials -> global (..., num_segments):
        O(c) segment sum over the trailing axis, then ONE multi-axis psum."""
        part = jax.ops.segment_sum(jnp.moveaxis(coord_partials, -1, 0),
                                   seg_ids, num_segments=num_segments)
        return compat.psum(jnp.moveaxis(part, 0, -1), axis_names)

    y0 = jnp.mean(z32, axis=0)

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(it < max_iters, delta > tol)

    def body(state):
        y, _, it = state
        diff = z32 - y[None]
        sq = seg_psum(diff * diff)                           # (W, L)
        inv = 1.0 / jnp.maximum(jnp.sqrt(sq), _DIST_FLOOR)   # (W, L)
        if row_weights is not None:
            # Staleness weights scale each message's contribution in every
            # block (weight 0 removes the row exactly, same as the mask in
            # masked_weiszfeld_segments).
            inv = inv * row_weights.astype(jnp.float32)[:, None]
        w_coord = inv[:, seg_ids]                            # (W, c)
        denom = jnp.sum(inv, axis=0)[seg_ids]                # (c,)
        y_new = jnp.sum(w_coord * z32, axis=0) / jnp.maximum(denom, _DIST_FLOOR)

        move = seg_psum((y_new - y) ** 2)                    # (L,) global
        return y_new, jnp.sqrt(jnp.max(move)), it + 1

    state0 = (y0, jnp.asarray(jnp.inf, jnp.float32), 0)
    y, delta, it = jax.lax.while_loop(cond, body, state0)
    if return_info:
        return y, WeiszfeldInfo(residual=delta,
                                iters=jnp.asarray(it, jnp.int32),
                                converged=delta <= tol)
    return y
