"""In-graph fault containment + round-health verdicts (DESIGN.md Sec. 13).

The paper's threat model assumes Byzantine messages are *finite* vectors
the geometric median can outvote -- but a single NaN/Inf row poisons every
distance computation (and the Weiszfeld iteration itself).  This module is
the containment layer underneath the statistical aggregators:

* :func:`guard_mask` -- per-row message sanitization computed inside the
  compiled step: non-finite detection (any NaN/Inf coordinate) plus a
  robust magnitude gate (per-round median-of-norms x a static multiplier)
  produce a (W,) validity mask in {0, 1}.  The mask folds multiplicatively
  into the existing ``row_weights`` of the flat/masked/sharded engines, so
  quarantined rows get weight exactly 0 -- no slicing, no new engine code.

* :func:`guarded_flat_call` -- the fold itself, with a bit-identity
  guarantee: an honest-only round with guards ON produces the SAME BITS as
  guards OFF.  The all-ones-weight path of the flat engines is NOT
  bit-identical to the unweighted path (the weighted median picks the
  lower-middle row where ``jnp.median`` averages the two middles), so when
  no base weights exist the call evaluates both the unweighted and the
  mask-weighted rule and selects with one ``jnp.where`` on the replicated
  "every row valid" scalar.  Both branches run unconditionally on every
  device (no ``lax.cond`` around collectives), and any NaN in the
  discarded branch is dropped by the select.  When base weights are
  already active, ``rw * 1.0 == rw`` exactly and the fold is free.

* :func:`sanitize_rows` -- zero the quarantined rows before they meet a
  weighted engine.  Weight 0 removes a row's *mass* but ``0 * NaN == NaN``
  inside the weighted sums, so containment needs the payload gone too;
  ``jnp.where(mask, z, 0)`` with an all-ones mask returns ``z`` bit-exact.

* :func:`round_verdict` -- the round-health layer: accept/reject each
  round in-graph from the aggregate's norm (non-finite => reject;
  z-score vs an EMA mean/second-moment carried in the train state =>
  reject).  A rejected round holds params/opt/VR state via
  :func:`select_tree` (pure ``jnp.where`` -- no host sync, donation-safe)
  and increments the ``rejected_rounds`` counter inside the health vector.

Everything here is jnp + ``compat.psum`` only: the same helpers run in
the single-host simulation (no axis names), under auto-sharded jit, and
inside ``shard_map`` where rows or coordinates are device-local and the
per-row partial sums must be restored with psums over ``axis_names``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import compat

Pytree = Any

# Rows with norms at/below this floor are never magnitude-quarantined, so a
# converged round of near-zero gradients cannot gate itself (the median of
# norms may be ~0 while an honest straggler row is merely small).
_NORM_FLOOR = 1e-12

# Layout of the (4,) f32 health vector carried in the train state:
# [EMA of aggregate norm, EMA of squared norm, rejected rounds, accepted
# rounds].  A flat f32 vector (not a NamedTuple) keeps the train-state
# pytree a single extra leaf -- trivially checkpointable and shard-spec'd
# as replicated.
HEALTH_WIDTH = 4


def init_health() -> jnp.ndarray:
    """Zeroed (HEALTH_WIDTH,) health vector for a fresh run."""
    return jnp.zeros((HEALTH_WIDTH,), jnp.float32)


def _row_stats(msgs: Pytree, axis_names: Sequence[str]
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (non-finite coordinate count, squared norm) over every leaf
    of ``msgs`` (leading axis W on each leaf), psum'd over ``axis_names``
    when the coordinates are sharded.  Non-finite coordinates contribute 0
    to the squared norm so the norm itself stays finite-or-inf-by-magnitude
    (an inf norm means genuinely huge finite values, which the gate
    quarantines via ``inf <= limit`` being False)."""
    bad = None
    sq = None
    for z in jax.tree_util.tree_leaves(msgs):
        zf = z.astype(jnp.float32).reshape(z.shape[0], -1)
        finite = jnp.isfinite(zf)
        zb = jnp.sum((~finite).astype(jnp.float32), axis=1)
        zs = jnp.sum(jnp.where(finite, zf, 0.0) ** 2, axis=1)
        bad = zb if bad is None else bad + zb
        sq = zs if sq is None else sq + zs
    if axis_names:
        bad = compat.psum(bad, tuple(axis_names))
        sq = compat.psum(sq, tuple(axis_names))
    return bad, sq


def _masked_median(x: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Median of ``x`` over the rows where ``valid`` -- invalid rows sort
    to +inf and the two middle indices are picked from the valid count.
    With zero valid rows this returns +inf (the magnitude gate then passes
    nothing, which is what an all-poisoned round deserves)."""
    s = jnp.sort(jnp.where(valid, x, jnp.inf))
    n = jnp.sum(valid.astype(jnp.int32))
    lo = jnp.maximum((n - 1) // 2, 0)
    hi = n // 2
    return 0.5 * (s[lo] + s[hi])


def guard_mask(msgs: Pytree, *, multiplier: float = 10.0,
               base_weights: Optional[jnp.ndarray] = None,
               axis_names: Sequence[str] = ()) -> jnp.ndarray:
    """(W,) f32 validity mask in {0, 1} for a stacked message set.

    A row is quarantined (mask 0) when it has >= 1 non-finite coordinate,
    or when its L2 norm exceeds ``multiplier`` x the median norm of the
    finite rows (``multiplier <= 0`` disables the magnitude gate).  The
    median votes come from finite rows with positive ``base_weights`` (when
    given), so already-masked-out slots (dropout, stale cohort rows) don't
    drag the scale estimate down.  ``axis_names``: mesh axes the row
    COORDINATES are sharded over (the per-row partials psum over them, so
    the mask is replicated)."""
    bad, sq = _row_stats(msgs, axis_names)
    finite_row = bad == 0
    mask = finite_row
    if multiplier > 0:
        norms = jnp.sqrt(sq)
        votes = finite_row
        if base_weights is not None:
            votes = votes & (base_weights > 0)
        med = _masked_median(norms, votes)
        limit = jnp.maximum(multiplier * med, _NORM_FLOOR)
        mask = mask & ((norms <= limit) | (norms <= _NORM_FLOOR))
    return mask.astype(jnp.float32)


def pairwise_guard_mask(exchange: Pytree, mask: jnp.ndarray, *,
                        multiplier: float = 10.0,
                        axis_names: Sequence[str] = ()) -> jnp.ndarray:
    """(R, S) validity mask for a decentralized per-edge exchange.

    ``exchange`` leaves are (R, S, ...) -- what receiver r heard from
    sender s; ``mask`` is the (R, S) neighbor mask (possibly already
    weight-scaled).  Each receiver sanitizes its own in-neighborhood: the
    median-of-norms is per RECEIVER over its unmasked finite senders, so a
    Byzantine sender quarantined at one receiver can still count against
    the budget at another (exactly the decentralized trust model)."""
    bad = None
    sq = None
    for z in jax.tree_util.tree_leaves(exchange):
        zf = z.astype(jnp.float32).reshape(z.shape[0], z.shape[1], -1)
        finite = jnp.isfinite(zf)
        zb = jnp.sum((~finite).astype(jnp.float32), axis=-1)
        zs = jnp.sum(jnp.where(finite, zf, 0.0) ** 2, axis=-1)
        bad = zb if bad is None else bad + zb
        sq = zs if sq is None else sq + zs
    if axis_names:
        bad = compat.psum(bad, tuple(axis_names))
        sq = compat.psum(sq, tuple(axis_names))
    finite_rs = bad == 0
    out = finite_rs
    if multiplier > 0:
        norms = jnp.sqrt(sq)
        votes = finite_rs & (mask > 0)
        med = jax.vmap(_masked_median)(norms, votes)          # (R,)
        limit = jnp.maximum(multiplier * med, _NORM_FLOOR)[:, None]
        out = out & ((norms <= limit) | (norms <= _NORM_FLOOR))
    return out.astype(jnp.float32)


def sanitize_rows(msgs: Pytree, mask: jnp.ndarray) -> Pytree:
    """Zero the rows ``mask`` quarantines (leading-axis select on every
    leaf).  With an all-ones mask this is a bit-exact identity; with a
    partial mask it removes the payload whose weight just went to 0, so
    ``0 * NaN`` can never leak back in through a weighted sum."""
    def one(z):
        m = mask.reshape(mask.shape + (1,) * (z.ndim - mask.ndim))
        return jnp.where(m > 0, z, jnp.zeros_like(z))
    return jax.tree_util.tree_map(one, msgs)


def all_valid(mask: jnp.ndarray) -> jnp.ndarray:
    """Replicated scalar: True iff no row/edge was quarantined."""
    return jnp.all(mask >= 1.0)


def guarded_flat_call(flat_fn: Callable[..., Any], buf: jnp.ndarray,
                      mask: jnp.ndarray, *,
                      row_weights: Optional[jnp.ndarray] = None) -> Any:
    """Run a flat aggregator with the guard mask folded into its row
    weights, bit-identical to the unguarded call on clean rounds.

    With base ``row_weights`` the fold is ``rw * mask`` (exact when the
    mask is all ones).  Without them, both the unweighted and the
    mask-weighted rule are evaluated and a single ``jnp.where`` on the
    replicated all-valid scalar picks the unweighted bits on clean rounds
    (module docstring: all-ones weights are NOT bit-identical to the
    unweighted engines, and ``lax.cond`` around collectives is off-limits
    inside shard_map).  The redundant aggregation is the price of the
    guarantee and only exists while guards are armed."""
    clean_buf = sanitize_rows(buf, mask)
    # Double-compute + select: the masked branch digests quarantined rows,
    # the raw branch reproduces the EXACT guards-off computation (weights
    # stay untouched constants, no sanitize elementwise feeding the
    # reduce), and the clean-round select picks the raw one -- so a clean
    # round is bit-identical to the unguarded engine.  The optimization
    # barriers keep XLA from multi-output-fusing the two reductions
    # (sibling fusion changes the accumulation order and breaks the
    # clean-round bit-identity the registry pins).
    if row_weights is not None:
        out_w = flat_fn(clean_buf, row_weights=row_weights * mask)
        out_u = jax.lax.optimization_barrier(
            flat_fn(jax.lax.optimization_barrier(buf),
                    row_weights=row_weights))
    else:
        out_w = flat_fn(clean_buf, row_weights=mask)
        out_u = jax.lax.optimization_barrier(
            flat_fn(jax.lax.optimization_barrier(buf)))
    clean = all_valid(mask)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(clean, a, b), out_u, out_w)


def select_tree(pred: jnp.ndarray, on_true: Pytree, on_false: Pytree
                ) -> Pytree:
    """Elementwise ``jnp.where(pred, a, b)`` over matching pytrees -- the
    donation-safe hold used when a round is rejected (same shapes in and
    out, no host sync)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def tree_norm(tree: Pytree, axis_names: Sequence[str] = ()) -> jnp.ndarray:
    """Global L2 norm over every leaf of ``tree`` (psum'd over
    ``axis_names`` when the leaves are shards) -- the scalar the round
    verdict watches."""
    sq = None
    for z in jax.tree_util.tree_leaves(tree):
        zs = jnp.sum(z.astype(jnp.float32) ** 2)
        sq = zs if sq is None else sq + zs
    if sq is None:
        sq = jnp.zeros((), jnp.float32)
    if axis_names:
        sq = compat.psum(sq, tuple(axis_names))
    return jnp.sqrt(sq)


def round_verdict(agg_norm: jnp.ndarray, health: jnp.ndarray, *,
                  decay: float = 0.9, zmax: float = 6.0,
                  warmup: int = 8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """In-graph accept/reject for one round -> (accept bool, new health).

    Reject when the aggregate norm is non-finite, or (after ``warmup``
    accepted rounds have seeded the tracker) when its one-sided z-score vs
    the EMA mean/second-moment exceeds ``zmax``.  The z denominator has a
    5% relative floor so a collapsed variance on a smooth trajectory can't
    reject ordinary noise, and the one-sided form never rejects a norm
    BELOW the EMA (descent shrinks gradients; only blow-ups are faults).
    The EMA advances only on accepted rounds -- a sustained attack cannot
    drag the tracker up to meet it.  ``zmax <= 0`` keeps the non-finite
    check only."""
    ema, ema_sq = health[0], health[1]
    rejected, seen = health[2], health[3]
    agg_norm = agg_norm.astype(jnp.float32)
    finite = jnp.isfinite(agg_norm)
    if zmax > 0:
        var = jnp.maximum(ema_sq - ema * ema, 0.0)
        scale = jnp.sqrt(var) + 0.05 * ema + _NORM_FLOOR
        z = (agg_norm - ema) / scale
        accept = finite & ((seen < warmup) | (z <= zmax))
    else:
        accept = finite
    norm0 = jnp.where(finite, agg_norm, 0.0)
    d = jnp.where(seen > 0.5, jnp.float32(decay), 0.0)  # first round seeds
    new_ema = jnp.where(accept, d * ema + (1.0 - d) * norm0, ema)
    new_sq = jnp.where(accept, d * ema_sq + (1.0 - d) * norm0 * norm0,
                       ema_sq)
    okf = accept.astype(jnp.float32)
    new_health = jnp.stack([new_ema, new_sq, rejected + (1.0 - okf),
                            seen + okf])
    return accept, new_health
