"""Robust aggregation rules over stacked worker messages.

Every aggregator consumes a pytree whose leaves carry a leading worker axis
(size W) and returns the aggregated pytree (worker axis reduced).  Rules:

* ``mean``          -- the non-robust baseline (distributed SGD / SAGA).
* ``geomed``        -- geometric median (the paper's rule, eq. (6)/(11)).
* ``geomed_groups`` -- hierarchical geomed-of-group-means ([10],[18] in the
                       paper; beyond-paper comm/variance optimization).
* ``median``        -- coordinate-wise median [11].
* ``trimmed_mean``  -- coordinate-wise b-trimmed mean [12].
* ``krum``          -- Krum selection [14]; needs B in advance (as noted in
                       the paper, Sec. III-B).

A registry (``_REGISTRY`` / :func:`get_aggregator`) builds
``fn(stacked_tree) -> tree`` from a name + options so the training loop
composes them freely; ``AGGREGATOR_NAMES`` and the unknown-name error are
derived from the registry, so adding an entry updates both.  Every
registered rule also runs on BOTH distributed comm paths
(``comm="gather"`` and ``comm="sharded"``, see
:mod:`repro.core.robust_step` and DESIGN.md Sec. 2).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.geomed import weiszfeld_pytree

Pytree = Any
Aggregator = Callable[[Pytree], Pytree]


def _per_leaf(fn):
    def agg(stacked: Pytree) -> Pytree:
        return jax.tree_util.tree_map(fn, stacked)
    return agg


def mean_agg(stacked: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda z: jnp.mean(z, axis=0), stacked)


def median_agg(stacked: Pytree) -> Pytree:
    """Coordinate-wise median over the worker axis."""
    return jax.tree_util.tree_map(lambda z: jnp.median(z, axis=0).astype(z.dtype), stacked)


def trimmed_mean_agg(stacked: Pytree, *, trim: int) -> Pytree:
    """Coordinate-wise trimmed mean: drop the ``trim`` largest and smallest
    entries per coordinate, average the rest."""

    def leaf(z):
        w = z.shape[0]
        if 2 * trim >= w:
            raise ValueError(f"trim={trim} too large for W={w}")
        s = jnp.sort(z, axis=0)
        kept = s[trim : w - trim]
        return jnp.mean(kept, axis=0).astype(z.dtype)

    return jax.tree_util.tree_map(leaf, stacked)


def geomed_agg(stacked: Pytree, *, max_iters: int = 64, tol: float = 1e-6) -> Pytree:
    return weiszfeld_pytree(stacked, max_iters=max_iters, tol=tol)


def group_means(z: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """Contiguous-block group means over the worker axis (the worker
    partition of [10]/[18]); tolerates W not divisible by num_groups
    (block sizes differ by at most one)."""
    w = z.shape[0]
    ids = (jnp.arange(w) * num_groups) // w
    flat = z.reshape(w, -1).astype(jnp.float32)
    sums = jax.ops.segment_sum(flat, ids, num_segments=num_groups)
    counts = jax.ops.segment_sum(jnp.ones((w,), jnp.float32), ids,
                                 num_segments=num_groups)
    return (sums / counts[:, None]).reshape((num_groups,) + z.shape[1:]).astype(z.dtype)


def geomed_groups_agg(
    stacked: Pytree, *, num_groups: int, max_iters: int = 64, tol: float = 1e-6
) -> Pytree:
    """Geometric median of group means.

    Workers are split into ``num_groups`` round-robin groups; each group is
    mean-reduced (cheap: an all-reduce over the sub-axis when distributed),
    and the geometric median is taken across the group means.  Reduces both
    the collective volume (W*p -> G*p) and the inner variation fed to the
    geomed (variance / group_size), at the price of a lower breakdown point
    (one Byzantine worker poisons its whole group, so tolerance drops to
    num_groups/2 poisoned groups).
    """
    grouped = jax.tree_util.tree_map(
        functools.partial(group_means, num_groups=num_groups), stacked)
    return weiszfeld_pytree(grouped, max_iters=max_iters, tol=tol)


def _pairwise_sq_dists(stacked: Pytree) -> jnp.ndarray:
    """(W, W) matrix of squared distances over full concatenated messages."""
    leaves = [z.reshape(z.shape[0], -1).astype(jnp.float32) for z in jax.tree_util.tree_leaves(stacked)]
    flat = jnp.concatenate(leaves, axis=-1)
    sq = jnp.sum(flat**2, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (flat @ flat.T)
    return jnp.maximum(d2, 0.0)


def krum_scores(d2: jnp.ndarray, num_byzantine: int) -> jnp.ndarray:
    """Krum scores from a (W, W) squared-distance matrix: per row, the sum of
    the W-B-2 smallest off-diagonal entries (self-distance masked to +inf).
    Shared by the local, gather, and sharded krum paths -- the comm modes
    differ only in how d2 is assembled (local Gram, model-axis psum, or
    coordinate-resharded partial Gram psum'd over worker+model axes)."""
    w = d2.shape[0]
    d2 = jnp.maximum(d2, 0.0) + jnp.diag(jnp.full((w,), jnp.inf, d2.dtype))
    n_near = max(w - num_byzantine - 2, 1)
    return jnp.sum(jnp.sort(d2, axis=1)[:, :n_near], axis=1)


def krum_agg(stacked: Pytree, *, num_byzantine: int) -> Pytree:
    """Krum [14]: score(w) = sum of squared distances to the W-B-2 nearest
    other messages; output the message with the minimal score."""
    best = jnp.argmin(krum_scores(_pairwise_sq_dists(stacked), num_byzantine))
    return jax.tree_util.tree_map(lambda z: z[best], stacked)


def centered_clip_agg(stacked: Pytree, *, radius: float = 1.0,
                      iters: int = 3,
                      axis_names: tuple = ()) -> Pytree:
    """Centered clipping (Karimireddy et al. 2021) — beyond-paper baseline.

    v <- v + mean_w clip(m_w - v, radius), iterated from the coordinate
    median; clips the *influence* of any single worker to ``radius`` per
    iteration, giving a breakdown point of 1/2 with O(W p) work and no sort.

    ``axis_names``: mesh axes over which the per-worker squared residual
    partials are psum'd when the leaves are coordinate shards inside a
    ``shard_map`` (same convention as :func:`...geomed.weiszfeld_pytree`);
    this single implementation backs the local, gather and sharded comm
    paths.  The iterate stays float32 and is cast to the leaf dtypes once at
    the end (see DESIGN.md Sec. 2 on the f32-iterate policy).
    """
    stacked32 = jax.tree_util.tree_map(lambda z: z.astype(jnp.float32), stacked)

    def clip_tree(v):
        # clip scale from the *global* per-worker residual norms (all leaves)
        diffs = jax.tree_util.tree_map(
            lambda zl, vl: zl - vl[None], stacked32, v)
        sq = None
        for dl in jax.tree_util.tree_leaves(diffs):
            part = jnp.sum(dl.reshape(dl.shape[0], -1) ** 2, axis=-1)
            sq = part if sq is None else sq + part
        for ax in axis_names:
            sq = jax.lax.psum(sq, ax)
        scale = jnp.minimum(1.0, radius / jnp.maximum(jnp.sqrt(sq), 1e-12))
        return jax.tree_util.tree_map(
            lambda vl, dl: vl + jnp.mean(
                dl * scale.reshape((-1,) + (1,) * (dl.ndim - 1)), axis=0),
            v, diffs)

    v = median_agg(stacked32)
    for _ in range(iters):
        v = clip_tree(v)
    return jax.tree_util.tree_map(lambda vl, z: vl.astype(z.dtype), v, stacked)


def geomed_blockwise_agg(stacked: Pytree, *, max_iters: int = 64,
                         tol: float = 1e-6) -> Pytree:
    """Per-leaf geometric median (norms per parameter block, not global).

    Weaker guarantee than full-vector geomed (an attacker can spend its
    budget per block), but each block aggregates independently -- which is
    what makes ZeRO/FSDP-sharded robust aggregation possible at >=100B
    params (no global norm psum across the full gradient).  Beyond-paper.
    """
    return jax.tree_util.tree_map(
        lambda z: weiszfeld_pytree(z, max_iters=max_iters, tol=tol), stacked)


# name -> builder(opts) -> Aggregator.  AGGREGATOR_NAMES and the
# unknown-name error below derive from this dict: registering here is the
# ONE place a new rule is added.
_REGISTRY: dict[str, Callable[[dict], Aggregator]] = {
    "mean": lambda opts: mean_agg,
    "median": lambda opts: median_agg,
    "geomed": lambda opts: functools.partial(
        geomed_agg,
        max_iters=opts.get("max_iters", 64),
        tol=opts.get("tol", 1e-6)),
    "geomed_groups": lambda opts: functools.partial(
        geomed_groups_agg,
        num_groups=opts["num_groups"],
        max_iters=opts.get("max_iters", 64),
        tol=opts.get("tol", 1e-6)),
    "trimmed_mean": lambda opts: functools.partial(
        trimmed_mean_agg, trim=opts.get("trim", 1)),
    "krum": lambda opts: functools.partial(
        krum_agg, num_byzantine=opts.get("num_byzantine", 0)),
    "centered_clip": lambda opts: functools.partial(
        centered_clip_agg, radius=opts.get("clip_radius", 1.0)),
    "geomed_blockwise": lambda opts: functools.partial(
        geomed_blockwise_agg,
        max_iters=opts.get("max_iters", 64), tol=opts.get("tol", 1e-6)),
}

AGGREGATOR_NAMES = tuple(_REGISTRY)


def get_aggregator(name: str, **opts) -> Aggregator:
    """Build an aggregator by name.

    Options: ``geomed``/``geomed_groups``/``geomed_blockwise`` accept
    ``max_iters``/``tol`` (and ``num_groups``); ``trimmed_mean`` accepts
    ``trim``; ``krum`` accepts ``num_byzantine``; ``centered_clip`` accepts
    ``clip_radius``.
    """
    try:
        build = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; known: "
            f"{', '.join(sorted(_REGISTRY))}") from None
    return build(opts)
