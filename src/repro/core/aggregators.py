"""Robust aggregation rules over stacked worker messages.

Every aggregator consumes a pytree whose leaves carry a leading worker axis
(size W) and returns the aggregated pytree (worker axis reduced).  Rules:

* ``mean``          -- the non-robust baseline (distributed SGD / SAGA).
* ``geomed``        -- geometric median (the paper's rule, eq. (6)/(11)).
* ``geomed_groups`` -- hierarchical geomed-of-group-means ([10],[18] in the
                       paper; beyond-paper comm/variance optimization).
* ``median``        -- coordinate-wise median [11].
* ``trimmed_mean``  -- coordinate-wise b-trimmed mean [12].
* ``krum``          -- Krum selection [14]; needs B in advance (as noted in
                       the paper, Sec. III-B).

Since the flat-packed refactor (DESIGN.md Sec. 8) the ENGINE of every rule
operates on one packed ``(W, D)`` message matrix (:mod:`repro.core.packing`)
-- one kernel per reduction instead of one per pytree leaf -- and the
pytree API above is a thin ``pack -> flat rule -> unpack`` shim, so the
registry, the launch layer and the tests are unchanged.  The flat rules
are exposed directly via :func:`get_flat_aggregator` for callers that
already hold packed buffers (the packed train steps).  The pre-refactor
per-leaf implementations are retained under ``get_aggregator(name,
perleaf=True)``: they are the baseline that ``benchmarks/bench_step.py``
times the packed path against, and the tolerance anchor for the
refactor-regression tests.

A registry (``_REGISTRY`` / :func:`get_aggregator`) builds
``fn(stacked_tree) -> tree`` from a name + options so the training loop
composes them freely; ``AGGREGATOR_NAMES`` and the unknown-name error are
derived from the registry, so adding an entry updates both (a flat rule is
required for every entry -- the registries are pinned against each other).
Every registered rule also runs on BOTH distributed comm paths
(``comm="gather"`` and ``comm="sharded"``, see
:mod:`repro.core.robust_step` and DESIGN.md Sec. 2).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import packing
from repro.core.geomed import weiszfeld_flat, weiszfeld_pytree
from repro.telemetry.diagnostics import AggDiagnostics, flat_diagnostics

Pytree = Any
Aggregator = Callable[[Pytree], Pytree]
FlatAggregator = Callable[..., jnp.ndarray]  # (W, D)[, row_weights] -> (D,) f32

# Guards weight-sum divisions when every row is dropped (all weights 0).
_WEIGHT_FLOOR = 1e-8


# ---------------------------------------------------------------------------
# Flat engine: every rule on one packed (W, D) message matrix.
# Contract: input is the packed buffer (any float dtype); output is the
# (D,) float32 aggregate (callers unpack/cast).  ``axis_names``/``sync_axes``
# follow the weiszfeld_pytree convention for shard_map execution.
#
# Every rule accepts an optional keyword ``row_weights`` -- a (W,) vector of
# per-message staleness weights (DESIGN.md Sec. 10).  ``None`` (the default)
# keeps the unweighted code path bit-for-bit; weight 0 removes a row exactly
# (mask-select semantics -- this is how ``dropout`` slots disappear without
# ever slicing the worker axis) and fractional weights down-weigh stale
# reports.  In shard_map the weights vector is replicated on every device,
# so the same forms run on coordinate slices unchanged.
#
# Every rule also accepts ``diagnostics=False`` (DESIGN.md Sec. 11): True
# returns ``(aggregate, AggDiagnostics)`` instead of the bare aggregate,
# surfacing the per-worker suspicion signal each rule computes internally
# (implicit Weiszfeld weights, krum scores/selection, clip fractions).  The
# False branch is the pre-telemetry code, byte-identical; rules with no
# model-axis collectives take ``axis_names`` purely so the diagnostics'
# distance partials can be psum'd when rows are coordinate shards.
# ---------------------------------------------------------------------------

def _sorted_with_weights(buf: jnp.ndarray, row_weights: jnp.ndarray):
    """Per-coordinate ascending sort of ``buf`` with the weight vector
    permuted along each coordinate's sort order -> (vals, wsort)."""
    b32 = buf.astype(jnp.float32)
    order = jnp.argsort(b32, axis=0)
    vals = jnp.take_along_axis(b32, order, axis=0)
    wsort = row_weights.astype(jnp.float32)[order]
    return vals, wsort


def mean_flat(buf: jnp.ndarray, *, row_weights=None, axis_names=(),
              diagnostics: bool = False) -> jnp.ndarray:
    if row_weights is None:
        out = jnp.mean(buf.astype(jnp.float32), axis=0)
    else:
        w = row_weights.astype(jnp.float32)
        num = jnp.sum(buf.astype(jnp.float32) * w[:, None], axis=0)
        out = num / jnp.maximum(jnp.sum(w), _WEIGHT_FLOOR)
    if not diagnostics:
        return out
    # The mean's implicit weight IS (normalized) row_weights -- uniform when
    # None; the distance trace still exposes outliers it failed to reject.
    rw = (jnp.ones((buf.shape[0],), jnp.float32) if row_weights is None
          else row_weights.astype(jnp.float32))
    return out, flat_diagnostics(buf, out, row_weights=row_weights,
                                 axis_names=axis_names, weight=rw)


def median_flat(buf: jnp.ndarray, *, row_weights=None, axis_names=(),
                diagnostics: bool = False) -> jnp.ndarray:
    if diagnostics:
        out = median_flat(buf, row_weights=row_weights)
        return out, flat_diagnostics(buf, out, row_weights=row_weights,
                                     axis_names=axis_names)
    if row_weights is None:
        return jnp.median(buf.astype(jnp.float32), axis=0)
    # Weighted median per coordinate: the smallest value whose cumulative
    # weight reaches half the total mass (dropped rows carry zero mass and
    # can never be selected unless everything is dropped).
    vals, wsort = _sorted_with_weights(buf, row_weights)
    cum = jnp.cumsum(wsort, axis=0)
    half = 0.5 * jnp.sum(row_weights.astype(jnp.float32))
    sel = jnp.argmax(cum >= half, axis=0)                      # (D,)
    return jnp.take_along_axis(vals, sel[None, :], axis=0)[0]


def trimmed_mean_flat(buf: jnp.ndarray, *, trim: int,
                      row_weights=None, axis_names=(),
                      diagnostics: bool = False) -> jnp.ndarray:
    w = buf.shape[0]
    if 2 * trim >= w:
        raise ValueError(f"trim={trim} too large for W={w}")
    if diagnostics:
        out = trimmed_mean_flat(buf, trim=trim, row_weights=row_weights)
        return out, flat_diagnostics(buf, out, row_weights=row_weights,
                                     axis_names=axis_names)
    if row_weights is None:
        s = jnp.sort(buf.astype(jnp.float32), axis=0)
        return jnp.mean(s[trim : w - trim], axis=0)
    # Weight-MASS trimming: per coordinate, drop the trim/W fraction of the
    # total weight mass from each tail and average what remains.  With unit
    # weights this reduces exactly to the unweighted rule (each sorted row
    # occupies one unit of mass), and zero-weight rows occupy zero mass so
    # they are auto-excluded rather than eating into the trim budget.
    vals, wsort = _sorted_with_weights(buf, row_weights)
    total = jnp.sum(row_weights.astype(jnp.float32))
    lo = (trim / w) * total
    hi = ((w - trim) / w) * total
    cum = jnp.cumsum(wsort, axis=0)
    kept = jnp.clip(jnp.minimum(cum, hi) - jnp.maximum(cum - wsort, lo),
                    0.0, None)
    return jnp.sum(kept * vals, axis=0) / jnp.maximum(hi - lo, _WEIGHT_FLOOR)


def geomed_flat(buf: jnp.ndarray, *, max_iters: int = 64, tol: float = 1e-6,
                axis_names: Sequence[str] = (),
                sync_axes: Sequence[str] = (),
                row_weights=None, diagnostics: bool = False) -> jnp.ndarray:
    if diagnostics:
        out, info = weiszfeld_flat(buf, max_iters=max_iters, tol=tol,
                                   axis_names=axis_names, sync_axes=sync_axes,
                                   row_weights=row_weights, return_info=True)
        # The generic inverse-distance weight evaluated at the returned
        # fixed point IS the implicit Weiszfeld weight of each message.
        return out, flat_diagnostics(buf, out, row_weights=row_weights,
                                     axis_names=axis_names,
                                     residual=info.residual, iters=info.iters,
                                     converged=info.converged)
    return weiszfeld_flat(buf, max_iters=max_iters, tol=tol,
                          axis_names=axis_names, sync_axes=sync_axes,
                          row_weights=row_weights)


def group_means(z: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """Contiguous-block group means over the worker axis (the worker
    partition of [10]/[18]); tolerates W not divisible by num_groups
    (block sizes differ by at most one).  Works on any (W, ...) leaf --
    including the packed (W, D) buffer, where it IS the flat group
    reduction."""
    w = z.shape[0]
    ids = (jnp.arange(w) * num_groups) // w
    flat = z.reshape(w, -1).astype(jnp.float32)
    sums = jax.ops.segment_sum(flat, ids, num_segments=num_groups)
    counts = jax.ops.segment_sum(jnp.ones((w,), jnp.float32), ids,
                                 num_segments=num_groups)
    return (sums / counts[:, None]).reshape((num_groups,) + z.shape[1:]).astype(z.dtype)


def geomed_groups_flat(buf: jnp.ndarray, *, num_groups: int,
                       max_iters: int = 64, tol: float = 1e-6,
                       axis_names: Sequence[str] = (),
                       sync_axes: Sequence[str] = (),
                       row_weights=None, diagnostics: bool = False
                       ) -> jnp.ndarray:
    if diagnostics:
        # The inner solve runs on the GROUP means; per-worker dist/weight are
        # still reported against the final aggregate (a Byzantine row drags
        # its whole group, and the drag shows up as distance).
        grouped = group_means(buf.astype(jnp.float32), num_groups)
        if row_weights is None:
            out, info = weiszfeld_flat(
                grouped, max_iters=max_iters, tol=tol, axis_names=axis_names,
                sync_axes=sync_axes, return_info=True)
        else:
            out = geomed_groups_flat(
                buf, num_groups=num_groups, max_iters=max_iters, tol=tol,
                axis_names=axis_names, sync_axes=sync_axes,
                row_weights=row_weights)
            info = None
        diag = flat_diagnostics(
            buf, out, row_weights=row_weights, axis_names=axis_names,
            residual=None if info is None else info.residual,
            iters=None if info is None else info.iters,
            converged=None if info is None else info.converged)
        return out, diag
    if row_weights is None:
        grouped = group_means(buf.astype(jnp.float32), num_groups)  # (G, D)
        return weiszfeld_flat(grouped, max_iters=max_iters, tol=tol,
                              axis_names=axis_names, sync_axes=sync_axes)
    # Weighted group means, and each group enters the outer Weiszfeld with
    # its total member mass (a group of all-dropped rows has mass 0 and is
    # removed exactly).
    w = buf.shape[0]
    wts = row_weights.astype(jnp.float32)
    ids = (jnp.arange(w) * num_groups) // w
    sums = jax.ops.segment_sum(buf.astype(jnp.float32) * wts[:, None], ids,
                               num_segments=num_groups)
    mass = jax.ops.segment_sum(wts, ids, num_segments=num_groups)
    grouped = sums / jnp.maximum(mass, _WEIGHT_FLOOR)[:, None]
    return weiszfeld_flat(grouped, max_iters=max_iters, tol=tol,
                          axis_names=axis_names, sync_axes=sync_axes,
                          row_weights=mass)


def flat_sq_dists(flat: jnp.ndarray,
                  axis_names: Sequence[str] = ()) -> jnp.ndarray:
    """(W, W) pairwise squared distances of packed (W, D) messages.  When
    the rows are coordinate shards inside shard_map, the Gram partials are
    psum'd over ``axis_names`` (squared distances are separable over any
    coordinate partition)."""
    flat = flat.astype(jnp.float32)
    sq = jnp.sum(flat ** 2, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (flat @ flat.T)
    if axis_names:
        d2 = compat.psum(d2, tuple(axis_names))
    return jnp.maximum(d2, 0.0)


def krum_scores(d2: jnp.ndarray, num_byzantine: int) -> jnp.ndarray:
    """Krum scores from a (W, W) squared-distance matrix: per row, the sum of
    the W-B-2 smallest off-diagonal entries (self-distance masked to +inf).
    Shared by the local, gather, and sharded krum paths -- the comm modes
    differ only in how d2 is assembled (local Gram, model-axis psum, or
    coordinate-resharded partial Gram psum'd over worker+model axes)."""
    w = d2.shape[0]
    d2 = jnp.maximum(d2, 0.0) + jnp.diag(jnp.full((w,), jnp.inf, d2.dtype))
    n_near = max(w - num_byzantine - 2, 1)
    return jnp.sum(jnp.sort(d2, axis=1)[:, :n_near], axis=1)


def krum_flat(buf: jnp.ndarray, *, num_byzantine: int,
              axis_names: Sequence[str] = (),
              row_weights=None, diagnostics: bool = False) -> jnp.ndarray:
    """Krum [14] on the packed buffer: score = sum of squared distances to
    the W-B-2 nearest other messages; output the winning row."""
    if row_weights is None:
        scores = krum_scores(flat_sq_dists(buf, axis_names), num_byzantine)
        best = jnp.argmin(scores)
        out = buf.astype(jnp.float32)[best]
        if diagnostics:
            # Krum's implicit weight is winner-take-all: a one-hot of the
            # selected row.  Scores carry the full suspicion ranking.
            return out, flat_diagnostics(
                buf, out, axis_names=axis_names,
                weight=jax.nn.one_hot(best, buf.shape[0], dtype=jnp.float32),
                score=scores, selected=best)
        return out
    # Weighted Krum: dropped rows (weight 0) can be neither neighbors nor
    # candidates -- their distance columns and scores go to a +inf stand-in
    # (never slice+concat, per the old-XLA hazard) -- the neighbor count
    # shrinks to the TRACED number of live rows, and surviving candidates'
    # scores are divided by their weight so stale reports lose ties against
    # fresh ones.  With unit weights the selection matches the unweighted
    # rule.
    w = buf.shape[0]
    big = jnp.float32(1e30)
    wts = row_weights.astype(jnp.float32)
    alive = wts > 0.0
    d2 = jnp.maximum(flat_sq_dists(buf, axis_names), 0.0)
    d2 = d2 + jnp.diag(jnp.full((w,), big))
    d2 = jnp.where(alive[None, :], d2, big)
    ds = jnp.sort(d2, axis=1)
    m = jnp.sum(alive.astype(jnp.int32))
    n_near = jnp.clip(m - num_byzantine - 2, 1, max(w - 1, 1))
    keep = (jnp.arange(w)[None, :] < n_near) & (ds < big)
    scores = jnp.sum(jnp.where(keep, ds, 0.0), axis=1)
    scores = jnp.where(alive, scores / jnp.maximum(wts, _WEIGHT_FLOOR), big)
    best = jnp.argmin(scores)
    out = buf.astype(jnp.float32)[best]
    if diagnostics:
        return out, flat_diagnostics(
            buf, out, row_weights=row_weights, axis_names=axis_names,
            weight=jax.nn.one_hot(best, w, dtype=jnp.float32),
            score=scores, selected=best)
    return out


def centered_clip_flat(buf: jnp.ndarray, *, radius: float = 1.0,
                       iters: int = 3,
                       axis_names: Sequence[str] = (),
                       row_weights=None, diagnostics: bool = False
                       ) -> jnp.ndarray:
    """Centered clipping (Karimireddy et al. 2021) on the packed buffer:
    v <- v + mean_w clip(m_w - v, radius) iterated from the coordinate
    median; one fused residual-norm reduction per iteration (psum'd over
    ``axis_names`` when the rows are coordinate shards).  With
    ``row_weights`` the center starts at the weighted median and each
    iteration takes the weight-normalized mean of the clipped residuals."""
    b32 = buf.astype(jnp.float32)
    if row_weights is None:
        v = jnp.median(b32, axis=0)
    else:
        v = median_flat(b32, row_weights=row_weights)
        wnorm = row_weights.astype(jnp.float32)
        wnorm = wnorm / jnp.maximum(jnp.sum(wnorm), _WEIGHT_FLOOR)
    for _ in range(iters):
        diffs = b32 - v[None]
        sq = jnp.sum(diffs * diffs, axis=-1)
        if axis_names:
            sq = compat.psum(sq, tuple(axis_names))
        scale = jnp.minimum(1.0, radius / jnp.maximum(jnp.sqrt(sq), 1e-12))
        if row_weights is None:
            v = v + jnp.mean(diffs * scale[:, None], axis=0)
        else:
            v = v + jnp.sum(diffs * (scale * wnorm)[:, None], axis=0)
    if diagnostics:
        # Implicit weight: each row's share of the last clipped-mean update
        # (its base weight times its final clip scale).  clip_frac counts
        # the live rows whose residual exceeded the radius, i.e. whose
        # influence was actually truncated.
        base = (jnp.full((buf.shape[0],), 1.0 / buf.shape[0], jnp.float32)
                if row_weights is None else wnorm)
        live = (jnp.ones((buf.shape[0],), jnp.float32) if row_weights is None
                else (row_weights.astype(jnp.float32) > 0).astype(jnp.float32))
        clip_frac = (jnp.sum(live * (scale < 1.0))
                     / jnp.maximum(jnp.sum(live), 1.0))
        return v, flat_diagnostics(buf, v, row_weights=row_weights,
                                   axis_names=axis_names, weight=base * scale,
                                   clip_frac=clip_frac)
    return v


def geomed_blockwise_flat(buf: jnp.ndarray, *, spec: packing.PackSpec,
                          max_iters: int = 64, tol: float = 1e-6,
                          axis_names: Sequence[str] = (),
                          sync_axes: Sequence[str] = (),
                          row_weights=None, diagnostics: bool = False
                          ) -> jnp.ndarray:
    """Per-leaf geometric median on the packed buffer: each leaf's
    coordinate slice runs its OWN Weiszfeld loop (independent iteration
    counts, matching the per-leaf semantics -- an attacker can spend its
    budget per block, see the pytree docstring).  The slices are static
    ``spec.boundaries``, so this is trace-time slicing of the one buffer,
    not a re-materialized pytree; padding coordinates aggregate to zero."""
    b32 = buf.astype(jnp.float32)
    if diagnostics:
        parts, infos = [], []
        for a, b in spec.boundaries:
            part, info = weiszfeld_flat(
                b32[:, a:b], max_iters=max_iters, tol=tol,
                axis_names=axis_names, sync_axes=sync_axes,
                row_weights=row_weights, return_info=True)
            parts.append(part)
            infos.append(info)
        out = packing.assemble(parts, pad=spec.pad)
        # Blocks iterate independently: summarize with the worst block
        # (max residual/iters, all-converged); dist/weight stay full-vector
        # so the per-worker suspicion trace is comparable across rules.
        return out, flat_diagnostics(
            buf, out, row_weights=row_weights, axis_names=axis_names,
            residual=jnp.max(jnp.stack([i.residual for i in infos])),
            iters=jnp.max(jnp.stack([i.iters for i in infos])),
            converged=jnp.all(jnp.stack([i.converged for i in infos])))
    parts = [
        weiszfeld_flat(b32[:, a:b], max_iters=max_iters, tol=tol,
                       axis_names=axis_names, sync_axes=sync_axes,
                       row_weights=row_weights)
        for a, b in spec.boundaries
    ]
    return packing.assemble(parts, pad=spec.pad)


# name -> builder(spec, opts) -> FlatAggregator.  In bijection with
# _REGISTRY below (enforced at import time), so a new rule must land in
# both or the module fails loudly.
_FLAT_REGISTRY: dict[str, Callable[[packing.PackSpec, dict], FlatAggregator]] = {
    "mean": lambda spec, o: functools.partial(
        mean_flat, axis_names=o.get("axis_names", ()),
        diagnostics=o.get("diagnostics", False)),
    "median": lambda spec, o: functools.partial(
        median_flat, axis_names=o.get("axis_names", ()),
        diagnostics=o.get("diagnostics", False)),
    "trimmed_mean": lambda spec, o: functools.partial(
        trimmed_mean_flat, trim=o.get("trim", 1),
        axis_names=o.get("axis_names", ()),
        diagnostics=o.get("diagnostics", False)),
    "geomed": lambda spec, o: functools.partial(
        geomed_flat, max_iters=o.get("max_iters", 64), tol=o.get("tol", 1e-6),
        axis_names=o.get("axis_names", ()), sync_axes=o.get("sync_axes", ()),
        diagnostics=o.get("diagnostics", False)),
    "geomed_groups": lambda spec, o: functools.partial(
        geomed_groups_flat, num_groups=o["num_groups"],
        max_iters=o.get("max_iters", 64), tol=o.get("tol", 1e-6),
        axis_names=o.get("axis_names", ()), sync_axes=o.get("sync_axes", ()),
        diagnostics=o.get("diagnostics", False)),
    "krum": lambda spec, o: functools.partial(
        krum_flat, num_byzantine=o.get("num_byzantine", 0),
        axis_names=o.get("axis_names", ()),
        diagnostics=o.get("diagnostics", False)),
    "centered_clip": lambda spec, o: functools.partial(
        centered_clip_flat, radius=o.get("clip_radius", 1.0),
        axis_names=o.get("axis_names", ()),
        diagnostics=o.get("diagnostics", False)),
    "geomed_blockwise": lambda spec, o: functools.partial(
        geomed_blockwise_flat, spec=spec,
        max_iters=o.get("max_iters", 64), tol=o.get("tol", 1e-6),
        axis_names=o.get("axis_names", ()), sync_axes=o.get("sync_axes", ()),
        diagnostics=o.get("diagnostics", False)),
}


def get_flat_aggregator(name: str, spec: packing.PackSpec,
                        **opts) -> FlatAggregator:
    """Build a flat aggregator ``fn(buf (W, D)) -> (D,) f32`` by name.

    Options mirror :func:`get_aggregator`, plus ``axis_names``/``sync_axes``
    for shard_map execution (rows as coordinate shards) and
    ``diagnostics=True`` to get ``(aggregate, AggDiagnostics)`` back
    (DESIGN.md Sec. 11; False keeps the engine byte-identical)."""
    try:
        build = _FLAT_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; known: "
            f"{', '.join(sorted(_FLAT_REGISTRY))}") from None
    return build(spec, opts)


# ---------------------------------------------------------------------------
# Pytree API: thin pack -> flat rule -> unpack shims over the engine.
# ---------------------------------------------------------------------------

def _via_flat(name: str, stacked: Pytree, opts: dict) -> Pytree:
    spec = packing.pack_spec(stacked)
    out = get_flat_aggregator(name, spec, **opts)(spec.pack(stacked))
    return spec.unpack(out, batch_ndim=0)


def mean_agg(stacked: Pytree) -> Pytree:
    return _via_flat("mean", stacked, {})


def median_agg(stacked: Pytree) -> Pytree:
    """Coordinate-wise median over the worker axis."""
    return _via_flat("median", stacked, {})


def trimmed_mean_agg(stacked: Pytree, *, trim: int) -> Pytree:
    """Coordinate-wise trimmed mean: drop the ``trim`` largest and smallest
    entries per coordinate, average the rest."""
    return _via_flat("trimmed_mean", stacked, {"trim": trim})


def geomed_agg(stacked: Pytree, *, max_iters: int = 64, tol: float = 1e-6) -> Pytree:
    return _via_flat("geomed", stacked, {"max_iters": max_iters, "tol": tol})


def geomed_groups_agg(
    stacked: Pytree, *, num_groups: int, max_iters: int = 64, tol: float = 1e-6
) -> Pytree:
    """Geometric median of group means.

    Workers are split into ``num_groups`` contiguous-block groups; each
    group is mean-reduced (cheap: an all-reduce over the sub-axis when
    distributed), and the geometric median is taken across the group means.
    Reduces both the collective volume (W*p -> G*p) and the inner variation
    fed to the geomed (variance / group_size), at the price of a lower
    breakdown point (one Byzantine worker poisons its whole group, so
    tolerance drops to num_groups/2 poisoned groups).
    """
    return _via_flat("geomed_groups", stacked,
                     {"num_groups": num_groups, "max_iters": max_iters,
                      "tol": tol})


def krum_agg(stacked: Pytree, *, num_byzantine: int) -> Pytree:
    """Krum [14]: score(w) = sum of squared distances to the W-B-2 nearest
    other messages; output the message with the minimal score."""
    return _via_flat("krum", stacked, {"num_byzantine": num_byzantine})


def centered_clip_agg(stacked: Pytree, *, radius: float = 1.0,
                      iters: int = 3,
                      axis_names: tuple = ()) -> Pytree:
    """Centered clipping (Karimireddy et al. 2021) -- beyond-paper baseline.

    ``axis_names``: mesh axes over which the per-worker squared residual
    partials are psum'd when the leaves are coordinate shards inside a
    ``shard_map`` (same convention as :func:`...geomed.weiszfeld_pytree`);
    this single implementation backs the local, gather and sharded comm
    paths.  The iterate stays float32 and is cast to the leaf dtypes once at
    the end (see DESIGN.md Sec. 2 on the f32-iterate policy).
    """
    spec = packing.pack_spec(stacked)
    out = centered_clip_flat(spec.pack(stacked), radius=radius, iters=iters,
                             axis_names=axis_names)
    return spec.unpack(out, batch_ndim=0)


def geomed_blockwise_agg(stacked: Pytree, *, max_iters: int = 64,
                         tol: float = 1e-6) -> Pytree:
    """Per-leaf geometric median (norms per parameter block, not global).

    Weaker guarantee than full-vector geomed (an attacker can spend its
    budget per block), but each block aggregates independently -- which is
    what makes ZeRO/FSDP-sharded robust aggregation possible at >=100B
    params (no global norm psum across the full gradient).  Beyond-paper.
    """
    return _via_flat("geomed_blockwise", stacked,
                     {"max_iters": max_iters, "tol": tol})


# ---------------------------------------------------------------------------
# Pre-refactor per-leaf implementations: the bench baseline + regression
# anchor (benchmarks/bench_step.py, tests/test_packing.py).  Selected via
# ``get_aggregator(name, perleaf=True)`` / ``RobustConfig.packed=False``.
# ---------------------------------------------------------------------------

def mean_agg_perleaf(stacked: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda z: jnp.mean(z, axis=0), stacked)


def median_agg_perleaf(stacked: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda z: jnp.median(z, axis=0).astype(z.dtype), stacked)


def trimmed_mean_agg_perleaf(stacked: Pytree, *, trim: int) -> Pytree:
    def leaf(z):
        w = z.shape[0]
        if 2 * trim >= w:
            raise ValueError(f"trim={trim} too large for W={w}")
        s = jnp.sort(z, axis=0)
        kept = s[trim : w - trim]
        return jnp.mean(kept, axis=0).astype(z.dtype)

    return jax.tree_util.tree_map(leaf, stacked)


def geomed_agg_perleaf(stacked: Pytree, *, max_iters: int = 64,
                       tol: float = 1e-6) -> Pytree:
    return weiszfeld_pytree(stacked, max_iters=max_iters, tol=tol)


def geomed_groups_agg_perleaf(
    stacked: Pytree, *, num_groups: int, max_iters: int = 64, tol: float = 1e-6
) -> Pytree:
    grouped = jax.tree_util.tree_map(
        functools.partial(group_means, num_groups=num_groups), stacked)
    return weiszfeld_pytree(grouped, max_iters=max_iters, tol=tol)


def _pairwise_sq_dists(stacked: Pytree) -> jnp.ndarray:
    """(W, W) matrix of squared distances over full concatenated messages."""
    leaves = [z.reshape(z.shape[0], -1).astype(jnp.float32)
              for z in jax.tree_util.tree_leaves(stacked)]
    flat = jnp.concatenate(leaves, axis=-1)
    return flat_sq_dists(flat)


def krum_agg_perleaf(stacked: Pytree, *, num_byzantine: int) -> Pytree:
    best = jnp.argmin(krum_scores(_pairwise_sq_dists(stacked), num_byzantine))
    return jax.tree_util.tree_map(lambda z: z[best], stacked)


def centered_clip_agg_perleaf(stacked: Pytree, *, radius: float = 1.0,
                              iters: int = 3,
                              axis_names: tuple = ()) -> Pytree:
    stacked32 = jax.tree_util.tree_map(lambda z: z.astype(jnp.float32), stacked)

    def clip_tree(v):
        diffs = jax.tree_util.tree_map(
            lambda zl, vl: zl - vl[None], stacked32, v)
        sq = None
        for dl in jax.tree_util.tree_leaves(diffs):
            part = jnp.sum(dl.reshape(dl.shape[0], -1) ** 2, axis=-1)
            sq = part if sq is None else sq + part
        for ax in axis_names:
            sq = jax.lax.psum(sq, ax)
        scale = jnp.minimum(1.0, radius / jnp.maximum(jnp.sqrt(sq), 1e-12))
        return jax.tree_util.tree_map(
            lambda vl, dl: vl + jnp.mean(
                dl * scale.reshape((-1,) + (1,) * (dl.ndim - 1)), axis=0),
            v, diffs)

    v = median_agg_perleaf(stacked32)
    for _ in range(iters):
        v = clip_tree(v)
    return jax.tree_util.tree_map(lambda vl, z: vl.astype(z.dtype), v, stacked)


def geomed_blockwise_agg_perleaf(stacked: Pytree, *, max_iters: int = 64,
                                 tol: float = 1e-6) -> Pytree:
    return jax.tree_util.tree_map(
        lambda z: weiszfeld_pytree(z, max_iters=max_iters, tol=tol), stacked)


# name -> builder(opts) -> Aggregator.  AGGREGATOR_NAMES and the
# unknown-name error below derive from this dict: registering here is the
# ONE place a new rule is added (a matching _FLAT_REGISTRY entry is
# required; the import-time assertion below keeps the engines in lockstep).
_REGISTRY: dict[str, Callable[[dict], Aggregator]] = {
    "mean": lambda opts: mean_agg,
    "median": lambda opts: median_agg,
    "geomed": lambda opts: functools.partial(
        geomed_agg,
        max_iters=opts.get("max_iters", 64),
        tol=opts.get("tol", 1e-6)),
    "geomed_groups": lambda opts: functools.partial(
        geomed_groups_agg,
        num_groups=opts["num_groups"],
        max_iters=opts.get("max_iters", 64),
        tol=opts.get("tol", 1e-6)),
    "trimmed_mean": lambda opts: functools.partial(
        trimmed_mean_agg, trim=opts.get("trim", 1)),
    "krum": lambda opts: functools.partial(
        krum_agg, num_byzantine=opts.get("num_byzantine", 0)),
    "centered_clip": lambda opts: functools.partial(
        centered_clip_agg, radius=opts.get("clip_radius", 1.0)),
    "geomed_blockwise": lambda opts: functools.partial(
        geomed_blockwise_agg,
        max_iters=opts.get("max_iters", 64), tol=opts.get("tol", 1e-6)),
}

_PERLEAF_REGISTRY: dict[str, Callable[[dict], Aggregator]] = {
    "mean": lambda opts: mean_agg_perleaf,
    "median": lambda opts: median_agg_perleaf,
    "geomed": lambda opts: functools.partial(
        geomed_agg_perleaf,
        max_iters=opts.get("max_iters", 64), tol=opts.get("tol", 1e-6)),
    "geomed_groups": lambda opts: functools.partial(
        geomed_groups_agg_perleaf,
        num_groups=opts["num_groups"],
        max_iters=opts.get("max_iters", 64), tol=opts.get("tol", 1e-6)),
    "trimmed_mean": lambda opts: functools.partial(
        trimmed_mean_agg_perleaf, trim=opts.get("trim", 1)),
    "krum": lambda opts: functools.partial(
        krum_agg_perleaf, num_byzantine=opts.get("num_byzantine", 0)),
    "centered_clip": lambda opts: functools.partial(
        centered_clip_agg_perleaf, radius=opts.get("clip_radius", 1.0)),
    "geomed_blockwise": lambda opts: functools.partial(
        geomed_blockwise_agg_perleaf,
        max_iters=opts.get("max_iters", 64), tol=opts.get("tol", 1e-6)),
}

assert set(_REGISTRY) == set(_FLAT_REGISTRY) == set(_PERLEAF_REGISTRY), (
    "aggregator registries out of sync: every rule needs a pytree shim, a "
    "flat engine entry, and a per-leaf baseline")

AGGREGATOR_NAMES = tuple(_REGISTRY)


def get_aggregator(name: str, *, perleaf: bool = False, **opts) -> Aggregator:
    """Build an aggregator by name.

    Options: ``geomed``/``geomed_groups``/``geomed_blockwise`` accept
    ``max_iters``/``tol`` (and ``num_groups``); ``trimmed_mean`` accepts
    ``trim``; ``krum`` accepts ``num_byzantine``; ``centered_clip`` accepts
    ``clip_radius``.  ``perleaf=True`` selects the pre-refactor per-leaf
    implementation (the bench baseline) instead of the packed engine.
    """
    registry = _PERLEAF_REGISTRY if perleaf else _REGISTRY
    try:
        build = registry[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; known: "
            f"{', '.join(sorted(registry))}") from None
    return build(opts)
