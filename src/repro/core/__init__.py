"""Byrd-SAGA core: robust aggregation + variance reduction + attacks."""
from repro.core.aggregators import (
    AGGREGATOR_NAMES,
    geomed_agg,
    geomed_blockwise_agg,
    geomed_groups_agg,
    get_aggregator,
    get_flat_aggregator,
    krum_agg,
    krum_scores,
    mean_agg,
    median_agg,
    trimmed_mean_agg,
)
from repro.core.attacks import (
    ATTACK_NAMES,
    FAULT_ATTACKS,
    STALENESS_ATTACKS,
    AttackConfig,
    apply_attack,
)
from repro.core.guards import (
    guard_mask,
    init_health,
    pairwise_guard_mask,
    round_verdict,
    sanitize_rows,
)
from repro.core.geomed import (
    geomed_objective,
    weiszfeld,
    weiszfeld_blockwise_sharded,
    weiszfeld_flat,
    weiszfeld_pytree,
    weiszfeld_sharded,
)
from repro.core.packing import (
    WIRE_FORMAT_NAMES,
    WIRE_FORMATS,
    PackSpec,
    WireFormat,
    pack_spec,
    resolve_wire_format,
)
from repro.core.participation import (
    ParticipationPlan,
    gather_rows,
    init_staleness,
    resolve_participation,
    scatter_rows,
    slot_staleness,
    staleness_weights,
    tick_staleness,
    uses_staleness,
)
from repro.core.robust_step import (
    GATHER_AGGREGATORS,
    SHARDED_AGGREGATORS,
    FederatedState,
    RobustConfig,
    distributed_aggregate,
    distributed_attack,
    make_federated_step,
    sharded_aggregate,
)
from repro.core.saga import SagaState, saga_correct, saga_correct_scatter, saga_init, saga_init_zeros
from repro.core.variance import (
    VR_NAMES,
    LsvrgState,
    VarianceReducer,
    get_reducer,
)
