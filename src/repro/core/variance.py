"""Pluggable variance reduction: the :class:`VarianceReducer` strategy layer.

The paper's core claim is that VARIANCE REDUCTION is what lets geometric-
median aggregation tell Byzantine messages from honest noise (Lemma 1 /
Thm 1): as the iterates converge, honest messages concentrate while
attacks cannot.  SAGA (Alg. 1) is one way to get that property; loopless
SVRG (arXiv:2303.04560) is another with O(D) instead of O(J*D) per-client
state, and the stochastic-ADMM variant (arXiv:2106.06891) shows a second
optimizer family wants the same plug-in point.  This module makes the
reduction method a first-class strategy so every execution path --
simulation master, decentralized sim, shard_map gather and sharded comm,
every topology/gossip mode -- dispatches through ONE registry instead of
scattering ``cfg.vr`` string comparisons across the layers.

Registry contract (mirrors the aggregator/attack registries): ``_REDUCERS``
is the single source of truth; ``VR_NAMES`` and the unknown-name error are
derived from it, so adding a reducer is one entry here plus its class.

The reducer interface (see :class:`VarianceReducer`):

* ``draw_indices(key, w, j)``     -- the per-step sample draw (reproduces the
  historical shapes bit-exactly: ``(W,)`` for single-sample reducers,
  ``(W, B)`` for minibatch).
* ``correct(state, grads, sample_idx, key, ...)`` -- turn raw stochastic
  gradients into variance-reduced messages + the new state + metrics.
  Layout-agnostic: ``grads``/state leaves may be per-leaf pytrees
  (``(W, *shape)``) or the packed ``(W, D)`` buffer of DESIGN.md Sec. 8 --
  every reducer op is elementwise or a gather/scatter over the worker axis.
* ``init_sim(...)`` / ``init_zeros(...)`` -- state construction for the
  finite-sum simulation paths (lazy oracles: only what the reducer needs
  is computed) and the cold-start launch paths.
* ``pack_state`` / ``unpack_state``   -- PackSpec layout conversion.
* ``state_specs`` / ``state_structs`` -- the launch layer's sharding specs
  and ShapeDtypeStructs for the state (per-worker leaves sharded over the
  worker axes, DESIGN.md Sec. 4).
* ``memory_elems(w, j, d)``       -- the state-size estimate the dryrun
  memory accounting reports (O(W*(J+1)*D) for SAGA, O(2*W*D) for lsvrg).

Correction oracles: SAGA only needs the drawn gradient and its table;
snapshot-based reducers (lsvrg) also need gradients evaluated at OTHER
parameters.  ``correct`` therefore takes optional callables bound by the
step builder:

* ``params``        -- the current per-worker parameters in the STATE's
  layout (master paths broadcast the shared iterate; decentralized paths
  pass the per-node copies);
* ``grads_at(p)``   -- per-worker gradients at per-worker params ``p`` for
  THIS step's already-drawn samples/batch, in the message layout;
* ``full_grads_at(p)`` -- per-worker FULL local gradients at shared params
  ``p`` (one vectorized pass over each worker's whole shard).  The launch
  paths have no finite local dataset and pass ``None``; lsvrg then anchors
  on the current batch gradient (the practical large-scale variant,
  DESIGN.md Sec. 9).

SAGA through this interface is BIT-EXACT with the pre-refactor pipeline
(tests/test_variance.py pins the seam): ``correct`` is a verbatim
delegation to :func:`repro.core.saga.saga_correct_scatter` and the index
draw reproduces the historical ``jax.random.randint`` call shapes.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import saga as saga_lib

Pytree = Any

# correct() -> (messages, new_state, metrics)
CorrectOut = tuple[Pytree, Any, dict]


def _bcast_like(vec: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """(W,) -> (W, 1, ..., 1) broadcastable against a (W, ...) leaf."""
    return vec.reshape((-1,) + (1,) * (leaf.ndim - 1))


class LsvrgState(NamedTuple):
    """Per-worker loopless-SVRG memory (arXiv:2303.04560), stacked over
    workers: ``snapshot`` holds each worker's reference point x~_w (the
    params at its last Bernoulli refresh), ``anchor`` its full local
    gradient mu_w = grad f_w(x~_w).  Leaves are (W, *shape) pytrees or the
    packed (W, D) buffers -- O(2D) per client either way, the whole point
    vs SAGA's O((J+1) D) table."""

    snapshot: Pytree
    anchor: Pytree


class VarianceReducer:
    """Base strategy: no reduction (plain stochastic gradients).

    Subclasses override the state lifecycle; the base class IS the ``sgd``
    reducer (stateless identity correction, single-sample draw).
    """

    name = "sgd"
    #: whether the reducer carries per-worker state at all
    stateful = False
    #: whether ``correct`` consumes the drawn sample index (table reducers)
    uses_sample_idx = False

    def __init__(self, cfg=None):
        self.cfg = cfg

    # -- sampling ----------------------------------------------------------
    def draw_indices(self, key: jax.Array, num_workers: int,
                     num_samples: int) -> jnp.ndarray:
        """Per-worker sample draw for this step; (W,) int32 by default."""
        return jax.random.randint(key, (num_workers,), 0, num_samples)

    # -- lifecycle ---------------------------------------------------------
    def wants_state(self, saga_num_samples: int = 0) -> bool:
        """Whether the launch layer should allocate/carry VR state (SAGA
        additionally needs a positive table size)."""
        return self.stateful

    def init_sim(self, params: Pytree, *,
                 per_sample_grads_fn: Callable[[], Pytree],
                 full_grads_fn: Callable[[Pytree], Pytree],
                 num_workers: int,
                 pack_fn: Optional[Callable[[Pytree, int], Pytree]] = None):
        """Initial state on the finite-sum simulation paths.

        ``per_sample_grads_fn()``: the Alg.-1 table sweep -> leaves
        (W, J, ...).  ``full_grads_fn(params)``: per-worker full local
        gradients at ``params`` -> leaves (W, ...).  ``pack_fn(tree,
        batch_ndim)`` packs into the Sec.-8 buffer layout (None keeps the
        per-leaf layout).  Oracles are lazy so only what the reducer needs
        is traced.
        """
        return None

    def init_zeros(self, params: Pytree, num_workers: int,
                   num_samples: int = 0, dtype=None):
        """Cold-start state for the launch paths (no init sweep)."""
        return None

    def correct(self, state, grads: Pytree, sample_idx, key: jax.Array, *,
                params: Optional[Pytree] = None,
                grads_at: Optional[Callable[[Pytree], Pytree]] = None,
                full_grads_at: Optional[Callable[[Pytree], Pytree]] = None,
                ) -> CorrectOut:
        return grads, state, {}

    # -- layout ------------------------------------------------------------
    def pack_state(self, spec: packing.PackSpec, state):
        """Pytree-layout state -> packed (Sec. 8) layout."""
        return state

    def unpack_state(self, spec: packing.PackSpec, state):
        return state

    def state_specs(self, pspecs: Pytree, wa_spec):
        """PartitionSpecs of the state for the launch layer: per-worker
        leaves sharded over the worker axes like the gradients."""
        return None

    def state_structs(self, param_structs: Pytree, num_workers: int,
                      num_samples: int = 0):
        """ShapeDtypeStructs of the state for ``num_workers`` workers."""
        return None

    # -- accounting --------------------------------------------------------
    def memory_elems(self, num_workers: int, num_samples: int,
                     model_dim: int) -> int:
        """Total state elements for (W, J, D) -- the dryrun/bench estimate."""
        return 0

    #: HBM passes over the per-device message shard that one correction
    #: costs (the analytic roofline term; 0 for stateless reducers).
    state_hbm_passes = 0


class MinibatchReducer(VarianceReducer):
    """The paper's BSGD baseline: mean gradient of a random minibatch.
    Reduction happens in the SAMPLING (a (W, B) index draw feeding a mean
    loss), so the correction itself is the identity."""

    name = "minibatch"

    def draw_indices(self, key, num_workers, num_samples):
        return jax.random.randint(
            key, (num_workers, self.cfg.minibatch_size), 0, num_samples)


class SagaReducer(VarianceReducer):
    """Paper Alg. 1: per-sample gradient table + running average
    (:mod:`repro.core.saga`).  O((J+1) D) per client -- the memory wall
    lsvrg removes."""

    name = "saga"
    stateful = True
    uses_sample_idx = True

    def wants_state(self, saga_num_samples: int = 0) -> bool:
        return saga_num_samples > 0

    def init_sim(self, params, *, per_sample_grads_fn, full_grads_fn,
                 num_workers, pack_fn=None):
        per_sample = per_sample_grads_fn()                    # (W, J, ...)
        if pack_fn is not None:
            per_sample = pack_fn(per_sample, 2)               # (W, J, D)
        return saga_lib.saga_init(per_sample)

    def init_zeros(self, params, num_workers, num_samples=0, dtype=None):
        return saga_lib.saga_init_zeros(params, num_workers, num_samples,
                                        dtype=dtype)

    def correct(self, state, grads, sample_idx, key, *, params=None,
                grads_at=None, full_grads_at=None) -> CorrectOut:
        msgs, new_state = saga_lib.saga_correct_scatter(state, grads,
                                                        sample_idx)
        return msgs, new_state, {}

    def pack_state(self, spec, state):
        return saga_lib.pack_saga_state(spec, state)

    def unpack_state(self, spec, state):
        return saga_lib.unpack_saga_state(spec, state)

    def state_specs(self, pspecs, wa_spec):
        from jax.sharding import PartitionSpec as P
        is_p = lambda x: isinstance(x, P)
        return saga_lib.SagaState(
            table=jax.tree_util.tree_map(
                lambda s: P(wa_spec, None, *tuple(s)), pspecs, is_leaf=is_p),
            avg=jax.tree_util.tree_map(
                lambda s: P(wa_spec, *tuple(s)), pspecs, is_leaf=is_p))

    def state_structs(self, param_structs, num_workers, num_samples=0):
        return saga_lib.SagaState(
            table=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(
                    (num_workers, num_samples) + s.shape, s.dtype),
                param_structs),
            avg=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((num_workers,) + s.shape,
                                               s.dtype), param_structs))

    def memory_elems(self, num_workers, num_samples, model_dim):
        return num_workers * (num_samples + 1) * model_dim

    # row read + avg r/w + row write (DESIGN.md Sec. 4)
    state_hbm_passes = 4


class LooplessSvrgReducer(VarianceReducer):
    """Byzantine-robust loopless SVRG (arXiv:2303.04560).

    Message: m_w = grad f_{w,i}(x^k) - grad f_{w,i}(x~_w) + mu_w, then with
    probability ``cfg.lsvrg_p`` (a per-worker Bernoulli coin drawn from the
    step key INSIDE the compiled step -- branchless where-select, no
    retrace) the snapshot refreshes: x~_w <- x^k, mu_w <- grad f_w(x^k).
    Same unbiased, vanishing-variance property as SAGA (what makes the
    robust aggregation work) with O(2D) per-client state instead of the
    O((J+1) D) table.

    The full-gradient refresh uses ``full_grads_at`` when the path can
    provide it (the finite-sum simulation paths: one vectorized pass over
    each worker's local shard); launch paths pass ``None`` and the anchor
    falls back to the current batch gradient -- the standard large-scale
    estimate (the anchor is then itself stochastic, but still centered).
    """

    name = "lsvrg"
    stateful = True

    def init_sim(self, params, *, per_sample_grads_fn, full_grads_fn,
                 num_workers, pack_fn=None):
        snapshot = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (num_workers,) + p.shape) + 0,
            params)
        anchor = full_grads_fn(params)                        # (W, ...)
        if pack_fn is not None:
            snapshot = pack_fn(snapshot, 1)                   # (W, D)
            anchor = pack_fn(anchor, 1)
        return LsvrgState(snapshot=snapshot, anchor=anchor)

    def init_zeros(self, params, num_workers, num_samples=0, dtype=None):
        def snap(p):
            return jnp.broadcast_to(
                p[None].astype(dtype or p.dtype),
                (num_workers,) + p.shape) + 0
        return LsvrgState(
            snapshot=jax.tree_util.tree_map(snap, params),
            anchor=jax.tree_util.tree_map(
                lambda p: jnp.zeros((num_workers,) + p.shape,
                                    dtype or p.dtype), params))

    def correct(self, state, grads, sample_idx, key, *, params=None,
                grads_at=None, full_grads_at=None) -> CorrectOut:
        if params is None or grads_at is None:
            raise ValueError(
                "lsvrg needs params= and grads_at= (gradients at the "
                "snapshot); the step builder must bind both oracles")
        g_snap = grads_at(state.snapshot)
        msgs = jax.tree_util.tree_map(
            lambda g, s, a: g - s.astype(g.dtype) + a.astype(g.dtype),
            grads, g_snap, state.anchor)
        # Bernoulli(p) snapshot refresh, one coin per worker per step.
        w = jax.tree_util.tree_leaves(grads)[0].shape[0]
        coin = jax.random.bernoulli(key, self.cfg.lsvrg_p, (w,))
        fresh = full_grads_at(params) if full_grads_at is not None else grads
        new_state = LsvrgState(
            snapshot=jax.tree_util.tree_map(
                lambda s, p: jnp.where(_bcast_like(coin, s),
                                       p.astype(s.dtype), s),
                state.snapshot, params),
            anchor=jax.tree_util.tree_map(
                lambda a, f: jnp.where(_bcast_like(coin, a),
                                       f.astype(a.dtype), a),
                state.anchor, fresh))
        metrics = {"vr_snapshot_rate": jnp.mean(coin.astype(jnp.float32))}
        return msgs, new_state, metrics

    def pack_state(self, spec, state):
        return LsvrgState(snapshot=spec.pack(state.snapshot, batch_ndim=1),
                          anchor=spec.pack(state.anchor, batch_ndim=1))

    def unpack_state(self, spec, state):
        return LsvrgState(snapshot=spec.unpack(state.snapshot),
                          anchor=spec.unpack(state.anchor))

    def state_specs(self, pspecs, wa_spec):
        from jax.sharding import PartitionSpec as P
        is_p = lambda x: isinstance(x, P)
        worker = jax.tree_util.tree_map(
            lambda s: P(wa_spec, *tuple(s)), pspecs, is_leaf=is_p)
        return LsvrgState(snapshot=worker, anchor=worker)

    def state_structs(self, param_structs, num_workers, num_samples=0):
        worker = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((num_workers,) + s.shape, s.dtype),
            param_structs)
        return LsvrgState(snapshot=worker, anchor=worker)

    def memory_elems(self, num_workers, num_samples, model_dim):
        return 2 * num_workers * model_dim

    # snapshot read (for grads_at) + anchor read + snapshot/anchor writes +
    # the refresh gradient write
    state_hbm_passes = 5


# name -> reducer class.  VR_NAMES and the unknown-name error derive from
# this dict (the aggregator/attack registry convention): registering here
# is the ONE place a new reduction method is added.
_REDUCERS: dict[str, type[VarianceReducer]] = {
    "sgd": VarianceReducer,
    "minibatch": MinibatchReducer,
    "saga": SagaReducer,
    "lsvrg": LooplessSvrgReducer,
}

VR_NAMES = tuple(_REDUCERS)


def get_reducer(cfg) -> VarianceReducer:
    """Build the variance reducer named by ``cfg.vr`` (a
    :class:`repro.core.robust_step.RobustConfig` or anything carrying the
    knobs the reducer reads: ``vr``, ``minibatch_size``, ``lsvrg_p``)."""
    try:
        cls = _REDUCERS[cfg.vr]
    except KeyError:
        raise ValueError(
            f"unknown variance reducer {cfg.vr!r}; known: "
            f"{', '.join(sorted(_REDUCERS))}") from None
    return cls(cfg)
