"""Client-scale virtualization: partial participation + bounded staleness.

The paper's federation has W workers that ALL report every round; the
production federation the ROADMAP targets has ``num_clients >> W`` logical
clients of which a seeded cohort of W participates per round, arriving
late, stale, or not at all (DESIGN.md Sec. 10).  This module is that layer:

* :class:`ParticipationPlan` -- the cohort sampler.  Like
  :class:`repro.topology.schedule.GraphSchedule`, the per-round cohorts are
  PRECOMPUTED numpy constants stacked into one (T, W) array that enters the
  jit as a compile-time constant; the traced round counter selects a row
  with one ``lax.dynamic_index_in_dim``.  One compiled program, no
  per-round retrace, and the whole round's client->slot mapping is a single
  gather.

* Cohort construction is SHUFFLED-EPOCH: each epoch is a seeded permutation
  of [0, num_clients) chopped into ceil(C/W) rounds (a short tail round is
  topped up from the head of the SAME permutation, which cannot collide
  with the tail -- the two position ranges are disjoint).  Consequences the
  property suite pins: every cohort has exactly W DISTINCT members (so the
  per-client state scatter is alias-free), and every client participates at
  least once per epoch -- deterministic coverage within ceil(C/W) rounds,
  no coupon-collector tail.

* Per-client round bookkeeping: ``gather_rows``/``scatter_rows`` move the
  cohort's variance-reduction state rows between the (C, ...) resident
  tables and the (W, ...) round view, and ``tick_staleness`` advances the
  per-client staleness counters (+1 everywhere, reset to 0 for the cohort
  -- counters never go negative).

* Bounded-staleness weighting: :func:`staleness_weights` maps integer
  staleness counters to per-row aggregation weights
  ``decay**staleness`` with a hard cutoff at ``max_staleness`` (weight
  exactly 0 -- the ``dropout`` attack reports that sentinel, which is how
  absent slots are masked out of every flat rule without slicing the
  worker axis).  :func:`slot_staleness` injects the attack-side counters
  (``straggler``/``dropout``) next to the honest cohort's.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attacks import STALENESS_ATTACKS

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParticipationPlan:
    """Seeded partial-participation plan: which clients fill the W message
    slots each round.

    ``num_clients``: C, the number of logical clients (resident VR-state
    rows).  ``cohort_size``: W, the number of slots per round (the honest
    width of the packed message buffer).  ``epochs``: how many shuffled
    epochs are precomputed before the plan wraps (rounds repeat with period
    ``num_rounds``, like a cyclic GraphSchedule).
    """

    num_clients: int
    cohort_size: int
    seed: int = 0
    epochs: int = 4

    def __post_init__(self):
        if not 0 < self.cohort_size <= self.num_clients:
            raise ValueError(
                f"cohort_size={self.cohort_size} must be in "
                f"[1, num_clients={self.num_clients}]")
        if self.epochs < 1:
            raise ValueError(f"epochs={self.epochs} must be >= 1")

    @property
    def rounds_per_epoch(self) -> int:
        return math.ceil(self.num_clients / self.cohort_size)

    @property
    def num_rounds(self) -> int:
        """T: the wrap period of the precomputed cohort stack."""
        return self.epochs * self.rounds_per_epoch

    @functools.cached_property
    def stacked_cohorts(self) -> np.ndarray:
        """(T, W) int32 client ids, one row per round -- the compile-time
        constant behind :meth:`cohort_at` (the GraphSchedule template).

        Within an epoch, round r takes ``perm[r*W:(r+1)*W]``; the last
        round of an epoch may run past C and is topped up from ``perm[:k]``
        (head positions < W <= r*W, so head and tail never overlap and each
        cohort stays duplicate-free).
        """
        c, w = self.num_clients, self.cohort_size
        rounds = []
        for e in range(self.epochs):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, e]))
            perm = rng.permutation(c)
            for r in range(self.rounds_per_epoch):
                chunk = perm[r * w:(r + 1) * w]
                if chunk.size < w:
                    chunk = np.concatenate([chunk, perm[: w - chunk.size]])
                rounds.append(chunk)
        return np.stack(rounds).astype(np.int32)

    def cohort_at(self, t) -> jnp.ndarray:
        """(W,) int32 client ids of round ``t`` (traced or concrete).

        The stack enters the jit as ONE constant; per-round selection is a
        single ``dynamic_index_in_dim`` on ``t % T`` -- no retrace, no
        per-round host work (same pattern as ``GraphSchedule.mask_at``).
        """
        stack = jnp.asarray(self.stacked_cohorts, jnp.int32)
        idx = jnp.asarray(t, jnp.int32) % self.num_rounds
        return jax.lax.dynamic_index_in_dim(stack, idx, axis=0,
                                            keepdims=False)

    def describe(self) -> str:
        return (f"participation: {self.num_clients} clients, cohort "
                f"{self.cohort_size}/round, {self.epochs} epochs "
                f"({self.num_rounds}-round period, seed {self.seed})")


def resolve_participation(cfg, cohort_size: int) -> Optional[ParticipationPlan]:
    """Build the plan from a RobustConfig, or ``None`` for full
    participation.

    ``cohort_size`` is the slot count of the execution path (the honest
    width of the sim federation, the mesh worker count distributed, the
    node count decentralized).  ``num_clients == 0`` means "no virtual
    clients" and ``num_clients == cohort_size`` means every client reports
    every round; both return ``None`` so the caller stays on the exact
    pre-participation code path (the bit-exactness bypass, mirroring
    ``resolve_schedule``'s star+static rule).
    """
    if cfg.num_clients in (0, cohort_size):
        return None
    if cfg.num_clients < cohort_size:
        raise ValueError(
            f"num_clients={cfg.num_clients} is smaller than the "
            f"{cohort_size}-slot cohort; use num_clients=0 for full "
            "participation")
    if cfg.cohort_size not in (0, cohort_size):
        raise ValueError(
            f"cohort_size={cfg.cohort_size} does not match the execution "
            f"path's {cohort_size} message slots")
    return ParticipationPlan(num_clients=cfg.num_clients,
                             cohort_size=cohort_size,
                             seed=cfg.participation_seed)


# ---------------------------------------------------------------------------
# Per-client round bookkeeping.
# ---------------------------------------------------------------------------

def gather_rows(tree: Pytree, cohort: jnp.ndarray) -> Pytree:
    """Select the cohort's rows from (C, ...)-leading leaves -> (W, ...).
    One compiled gather per leaf; the cohort ids are the only traced
    input."""
    return jax.tree_util.tree_map(
        lambda leaf: jnp.take(leaf, cohort, axis=0), tree)


def scatter_rows(tree: Pytree, cohort: jnp.ndarray, rows: Pytree) -> Pytree:
    """Write the round's updated (W, ...) rows back into the (C, ...)
    resident tables.  Safe because plan cohorts are duplicate-free (module
    docstring) -- the scatter never aliases."""
    return jax.tree_util.tree_map(
        lambda leaf, r: leaf.at[cohort].set(r.astype(leaf.dtype)),
        tree, rows)


def init_staleness(num_clients: int) -> jnp.ndarray:
    """(C,) int32 rounds-since-last-participation counters, all fresh."""
    return jnp.zeros((num_clients,), jnp.int32)


def tick_staleness(staleness: jnp.ndarray,
                   cohort: jnp.ndarray) -> jnp.ndarray:
    """Advance the per-client counters one round: +1 for everyone, reset to
    0 for the participating cohort.  Counters start at 0 and only this
    function updates them, so they can never go negative."""
    return (staleness + 1).at[cohort].set(0)


def staleness_weights(staleness: jnp.ndarray, *, decay: float,
                      max_staleness: int) -> jnp.ndarray:
    """Bounded-staleness aggregation weights: ``decay**s``, hard 0 at or
    beyond ``max_staleness``.  ``decay=1.0`` keeps all in-bound rows at
    weight 1 (pure dropout masking); the cutoff is what turns a saturated
    counter (the ``dropout`` sentinel) into exact mask-out."""
    s = jnp.asarray(staleness, jnp.int32)
    w = jnp.asarray(decay, jnp.float32) ** s.astype(jnp.float32)
    return jnp.where(s >= max_staleness, 0.0, w)


def slot_staleness(honest_staleness: jnp.ndarray, attack: str,
                   num_byzantine: int, *, straggler_k: int,
                   max_staleness: int, byz_first: bool = False) -> jnp.ndarray:
    """Per-SLOT staleness of the full W-row message buffer.

    ``honest_staleness``: the cohort's counters (0 under full
    participation).  Byzantine slots get the attack's counter: ``straggler``
    reports stale-by-k, ``dropout`` the saturated ``max_staleness`` sentinel
    (-> weight exactly 0), every other attack a fresh 0.

    ``byz_first=False`` (sim master convention): B Byzantine rows are
    APPENDED after the honest ones.  ``byz_first=True`` (distributed
    convention): the buffer already has W rows and the FIRST B were
    replaced by the attack -- mask-select, the honest vector is full
    length.
    """
    s = jnp.asarray(honest_staleness, jnp.int32)
    if attack == "straggler":
        byz_val = straggler_k
    elif attack == "dropout":
        byz_val = max_staleness
    else:
        byz_val = 0
    if num_byzantine == 0 or attack == "none":
        return s
    if byz_first:
        w = s.shape[0]
        return jnp.where(jnp.arange(w) < num_byzantine, byz_val, s)
    return jnp.concatenate(
        [s, jnp.full((num_byzantine,), byz_val, jnp.int32)])


def uses_staleness(cfg, plan: Optional[ParticipationPlan]) -> bool:
    """Trace-time switch: thread per-row staleness weights through the
    aggregation only when something can make them non-trivial -- partial
    participation or a staleness attack.  When False the aggregators are
    called WITHOUT ``row_weights`` and take the exact pre-participation
    code path (the bit-exactness discipline)."""
    return plan is not None or cfg.attack in STALENESS_ATTACKS
