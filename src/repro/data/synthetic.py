"""Synthetic datasets matched to the paper's experimental workloads.

The container is offline, so IJCNN1 / COVTYPE / MNIST are replaced by
synthetic generators with matched dimensionality and class structure; the
benchmarks validate the paper's *claims/orderings* (which are about the
optimization dynamics, not the datasets) rather than dataset-exact curves.

* :func:`logreg_dataset` -- binary classification with labels in {-1, +1}
  drawn from a ground-truth logistic model (IJCNN1-like: p=22;
  COVTYPE-like: p=54).
* :func:`mnist_like`     -- 10-class Gaussian-blob images (p=784) for the
  1-hidden-layer NN of Table I.
* :func:`token_stream`   -- LM token batches for the large-model examples.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Dataset(NamedTuple):
    x: jnp.ndarray
    y: jnp.ndarray


def logreg_dataset(key: jax.Array, n: int, p: int, *, noise: float = 0.1,
                   scale: float = 1.0) -> Dataset:
    """Features ~ N(0, scale); labels from a planted logistic model with
    label-flip noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    x = scale * jax.random.normal(k1, (n, p), jnp.float32)
    w_true = jax.random.normal(k2, (p,), jnp.float32)
    logits = x @ w_true
    prob_flip = noise
    y = jnp.sign(logits)
    flip = jax.random.bernoulli(k3, prob_flip, (n,))
    y = jnp.where(flip, -y, y)
    y = jnp.where(y == 0, 1.0, y)
    return Dataset(x=x, y=y.astype(jnp.float32))


def ijcnn1_like(key: jax.Array, n: int = 4_000) -> Dataset:
    """IJCNN1 surrogate: p=22 (real set: 49,990 x 22)."""
    return logreg_dataset(key, n, 22)


def covtype_like(key: jax.Array, n: int = 4_000) -> Dataset:
    """COVTYPE surrogate: p=54 (real set: 581,012 x 54)."""
    return logreg_dataset(key, n, 54)


def mnist_like(key: jax.Array, n: int = 2_000, num_classes: int = 10,
               p: int = 784) -> Dataset:
    """Gaussian class-blob images in [0,1]^784 with integer labels."""
    k1, k2 = jax.random.split(key)
    centers = jax.random.uniform(k1, (num_classes, p), jnp.float32)
    y = jnp.arange(n) % num_classes
    noise = 0.3 * jax.random.normal(k2, (n, p), jnp.float32)
    x = jnp.clip(centers[y] + noise, 0.0, 1.0)
    return Dataset(x=x, y=y.astype(jnp.int32))


def token_stream(key: jax.Array, batch: int, seq_len: int, vocab: int) -> dict:
    """One LM training batch: tokens + next-token labels."""
    toks = jax.random.randint(key, (batch, seq_len + 1), 0, vocab, jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def logreg_loss(rho: float = 0.01):
    """l2-regularized logistic loss of the paper (Sec. V-A):
    f(x) = ln(1 + exp(-b <a, x>)) + rho/2 ||x||^2, averaged over the batch."""

    def loss(params, batch):
        w = params["w"]
        a, b = batch["a"], batch["b"]
        margins = -b * (a @ w)
        # log(1+exp(m)) stable.
        nll = jnp.mean(jnp.logaddexp(0.0, margins))
        return nll + 0.5 * rho * jnp.sum(w * w)

    return loss


def logreg_full_loss_and_opt(data: Dataset, rho: float = 0.01,
                             iters: int = 4000, lr: float = 0.5):
    """Solve the full-batch problem to high precision (deterministic GD with
    backtracking-free constant step) to obtain f(x*) for optimality gaps."""
    loss = logreg_loss(rho)
    batch = {"a": data.x, "b": data.y}
    p = data.x.shape[1]
    params = {"w": jnp.zeros((p,), jnp.float32)}
    g = jax.jit(jax.grad(loss))

    @jax.jit
    def body(params, _):
        grad = g(params, batch)
        return {"w": params["w"] - lr * grad["w"]}, None

    params, _ = jax.lax.scan(body, params, None, length=iters)
    return params, float(loss(params, batch))
