"""Federated partitioning: split a finite dataset across W honest workers.

The paper distributes the dataset evenly over W-B honest workers (each gets
J samples).  ``partition`` supports the iid split used in Figs. 3-4, the
"everybody holds the whole dataset" setting of Fig. 5 (outer variation
delta^2 = 0), and a Dirichlet non-iid split for heterogeneity stress tests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def partition(data: Pytree, num_workers: int, *, mode: str = "iid",
              seed: int = 0, samples_per_worker: int | None = None) -> Pytree:
    """Return worker-stacked data: leaves (W, J, ...).

    ``mode``:
      * ``iid``        -- random shuffle, even contiguous split.
      * ``replicated`` -- every worker holds the same J samples (delta^2=0,
                          paper Fig. 5).
      * ``sorted``     -- sort by label (max heterogeneity; beyond-paper).
    """
    leaves, treedef = jax.tree_util.tree_flatten(data)
    n = leaves[0].shape[0]
    rng = np.random.default_rng(seed)

    if mode == "replicated":
        j = samples_per_worker or n
        idx = rng.permutation(n)[:j]
        sel = [np.asarray(l)[idx] for l in leaves]
        out = [np.broadcast_to(s, (num_workers,) + s.shape).copy() for s in sel]
        return jax.tree_util.tree_unflatten(treedef, [jnp.asarray(o) for o in out])

    if mode == "iid":
        order = rng.permutation(n)
    elif mode == "sorted":
        # Sort by the last leaf (labels) for maximal outer variation.
        order = np.argsort(np.asarray(leaves[-1]), kind="stable")
    else:
        raise ValueError(f"unknown partition mode {mode!r}")

    j = samples_per_worker or (n // num_workers)
    if num_workers * j > n:
        raise ValueError(f"need {num_workers * j} samples, have {n}")
    order = order[: num_workers * j].reshape(num_workers, j)
    out = [jnp.asarray(np.asarray(l)[order]) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, out)
