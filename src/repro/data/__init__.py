from repro.data.federated import partition
from repro.data.synthetic import (
    Dataset,
    covtype_like,
    ijcnn1_like,
    logreg_dataset,
    logreg_full_loss_and_opt,
    logreg_loss,
    mnist_like,
    token_stream,
)
