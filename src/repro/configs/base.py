"""Config dataclasses: model architecture, input shapes, training, robustness.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
four benchmark input shapes are :data:`SHAPES` in ``shapes.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One sublayer descriptor within a repeating layer period."""

    kind: str = "attn"       # attn | mamba
    moe: bool = False        # MoE FFN instead of dense FFN
    cross: bool = False      # add cross-attention (enc-dec decoder blocks)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0        # 0 -> d_model // num_heads
    activation: str = "swiglu"
    qkv_bias: bool = False
    rope_theta: Optional[float] = 1e4  # None -> no RoPE (whisper/jamba)
    sliding_window: Optional[int] = None
    norm: str = "rmsnorm"    # rmsnorm | layernorm
    tie_embeddings: bool = True
    scale_embeddings: bool = False   # gemma-style sqrt(D) embedding scale
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- layer pattern (one period; empty -> uniform) ---
    pattern: Tuple[BlockSpec, ...] = ()
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0
    # --- VLM ---
    num_prefix_tokens: int = 0
    frontend: Optional[str] = None   # audio | vision (stubbed per brief)
    # --- numerics / long context ---
    param_dtype: str = "bfloat16"
    long_context_window: int = 8192  # SWA window used for long_500k on full-attn archs
    source: str = ""                 # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def resolve_pattern(self) -> Tuple[Tuple[BlockSpec, ...], int]:
        """Return (pattern, num_periods)."""
        pat = self.pattern or (BlockSpec(kind="attn", moe=self.num_experts > 0),)
        if self.num_layers % len(pat):
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(pat)}")
        return pat, self.num_layers // len(pat)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 periods, d_model<=256, <=4 experts."""
        pat, _ = self.resolve_pattern()
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        hd = 64
        changes = dict(
            num_layers=len(pat) * min(2, self.num_layers // len(pat) or 1),
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64),
            num_prefix_tokens=min(self.num_prefix_tokens, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            param_dtype="float32",
        )
        if self.num_experts:
            changes.update(
                num_experts=min(self.num_experts, 4),
                num_shared_experts=min(self.num_shared_experts, 1),
                top_k=min(self.top_k, 2),
                moe_d_ff=min(self.moe_d_ff, 256),
            )
        if self.ssm_state:
            changes.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32,
                           ssm_chunk=16)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgd"   # paper-faithful update is plain SGD (eq. 11)
    lr: float = 1e-3
    remat: bool = True
    loss_chunk: int = 512
