"""paligemma-3b [vlm] — SigLIP vision tower STUB + gemma decoder [arXiv:2407.07726].

18L d_model=2048, 8H (GQA kv=1 = MQA), d_ff=16384, vocab=257216, head_dim=256.
256 image-patch embeddings form a bidirectional prefix (prefix-LM masking);
the SigLIP encoder + projector are stubbed per the brief — input_specs()
supplies (B, 256, d_model) patch embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="geglu",
    rope_theta=1e4,
    scale_embeddings=True,
    tie_embeddings=True,
    num_prefix_tokens=256,
    frontend="vision",
    source="arXiv:2407.07726",
)
