"""whisper-tiny [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].

4L(enc)+4L(dec) d_model=384, 6H (kv=6), d_ff=1536, vocab=51865, LayerNorm,
GELU, learned positions (no RoPE), encoder over 1500 stubbed mel-frame
embeddings (the mel+conv frontend is stubbed per the brief).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    rope_theta=None,
    encoder_layers=4,
    encoder_seq=1500,
    frontend="audio",
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
