"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432, 96H (GQA kv=8), d_ff=73728, vocab=256000, head_dim=192.
Untied embeddings (separate input/output embedding matrices).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=1e4,
    tie_embeddings=False,
    source="arXiv:2402.16819",
)
