"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attn [arXiv:2401.04088].

56L d_model=6144, 48H (GQA kv=8), expert d_ff=16384, vocab=32768.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32768,
    rope_theta=1e6,
    sliding_window=4096,
    num_experts=8,
    top_k=2,
    moe_d_ff=16384,
    pattern=(BlockSpec(kind="attn", moe=True),),
    tie_embeddings=False,
    source="arXiv:2401.04088",
)
