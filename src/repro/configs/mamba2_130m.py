"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSM heads.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,          # unused (attention-free); kept for API uniformity
    num_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    pattern=(BlockSpec(kind="mamba"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
