"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE [arXiv:2403.19887].

32L d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536, MoE 16e top-2.
Period of 8 layers: attention at index 4, the rest Mamba; MoE replaces the
dense FFN on every other layer (odd indices).  Jamba attention uses no
positional embeddings (rope_theta=None).  SSM state 16 (Jamba uses Mamba-1
sized states); d_inner=8192, head_dim 64 -> 128 SSM heads.
"""
from repro.configs.base import BlockSpec, ModelConfig

_M = lambda moe: BlockSpec(kind="mamba", moe=moe)
_A = lambda moe: BlockSpec(kind="attn", moe=moe)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    rope_theta=None,
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    pattern=(_M(False), _M(True), _M(False), _M(True),
             _A(False), _M(True), _M(False), _M(True)),
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=False,
    source="arXiv:2403.19887",
)
