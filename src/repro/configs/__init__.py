"""Architecture/config registry: ``get_config(name)`` / ``ARCH_NAMES``."""
from repro.configs.base import BlockSpec, ModelConfig, ShapeConfig, TrainConfig
from repro.configs.shapes import SHAPES

from repro.configs import (
    byrd_logreg,
    command_r_plus_104b,
    jamba_v01_52b,
    mamba2_130m,
    mistral_large_123b,
    mixtral_8x22b,
    nemotron4_340b,
    paligemma_3b,
    qwen2_7b,
    qwen2_moe_a2p7b,
    whisper_tiny,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        mamba2_130m, qwen2_moe_a2p7b, qwen2_7b, nemotron4_340b, whisper_tiny,
        mixtral_8x22b, jamba_v01_52b, mistral_large_123b, command_r_plus_104b,
        paligemma_3b,
    )
}

ARCH_NAMES = tuple(_REGISTRY)
LOGREG_CONFIG = byrd_logreg.CONFIG


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
