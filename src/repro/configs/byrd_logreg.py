"""The paper's own workload: l2-regularized logistic regression (Sec. V-A).

Not one of the assigned LLM architectures — this config drives the exact
reproduction benchmarks (Figs. 3-6, Table I) at the paper's scale:
W-B = 50 honest workers + B = 20 Byzantine, IJCNN1/COVTYPE-like data.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LogRegConfig:
    dataset: str = "ijcnn1"   # ijcnn1 | covtype
    num_honest: int = 50
    num_byzantine: int = 20
    rho: float = 0.01
    steps: int = 3000
    lr_sgd: float = 0.02
    lr_bsgd: float = 0.01
    lr_saga: float = 0.02
    minibatch: int = 50
    geomed_eps: float = 1e-5


CONFIG = LogRegConfig()
