"""Production meshes.

Kept as FUNCTIONS so importing this module never touches jax device state.

* single pod : (16, 16)   axes ("data", "model")  -- 256 chips (v5e pod)
* multi-pod  : (P, 16, 16) axes ("pod", "data", "model") -- P x 256 chips

Workers of the Byzantine-robust federation are the indices along the
WORKER AXES ``("pod", "data")`` (the axes :func:`worker_axes` reports): 16
workers single-pod, P*16 multi-pod; each worker owns ``model``-parallel
chips and its own finite local dataset + SAGA table.  The global worker id
is the row-major linear index over the worker axes (pod-major) -- the order
every collective in ``core/robust_step.py`` collapses those axes to
(``repro.compat.all_gather`` / ``all_to_all`` / ``axis_index``).

All mesh construction funnels through ``repro.compat.make_mesh`` so the same
code runs on jax 0.4.x (no axis_types) and >= 0.6 (explicit AxisType.Auto).
"""
from __future__ import annotations

from typing import Optional

from repro import compat


def make_production_mesh(*, multi_pod: bool = False,
                         num_pods: Optional[int] = None,
                         data_per_pod: int = 16, model: int = 16):
    """Build the production mesh.

    ``num_pods``: explicit pod count; >= 2 adds the leading "pod" axis,
    1 builds the flat single-pod mesh.  Defaults to the legacy boolean
    ``multi_pod`` (False -> 1 pod, True -> 2 pods).
    """
    if num_pods is None:
        num_pods = 2 if multi_pod else 1
    if num_pods < 1:
        raise ValueError(f"num_pods must be >= 1, got {num_pods}")
    if num_pods > 1:
        return compat.make_mesh((num_pods, data_per_pod, model),
                                ("pod", "data", "model"))
    return compat.make_mesh((data_per_pod, model), ("data", "model"))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests/examples)."""
    return compat.make_mesh(shape, axes)


def worker_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_workers(mesh) -> int:
    n = 1
    for a in worker_axes(mesh):
        n *= axis_sizes(mesh)[a]
    return n
