"""Production meshes.

Kept as FUNCTIONS so importing this module never touches jax device state.

* single pod : (16, 16)   axes ("data", "model")  -- 256 chips (v5e pod)
* multi-pod  : (2, 16, 16) axes ("pod", "data", "model") -- 512 chips

Workers of the Byzantine-robust federation are the indices along the
("pod",) "data" axes: 16 workers single-pod, 32 multi-pod; each worker owns
16 model-parallel chips and its own finite local dataset + SAGA table.

All mesh construction funnels through ``repro.compat.make_mesh`` so the same
code runs on jax 0.4.x (no axis_types) and >= 0.6 (explicit AxisType.Auto).
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    if multi_pod:
        return compat.make_mesh((2, 16, 16), ("pod", "data", "model"))
    return compat.make_mesh((16, 16), ("data", "model"))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests/examples)."""
    return compat.make_mesh(shape, axes)


def worker_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_workers(mesh) -> int:
    n = 1
    for a in worker_axes(mesh):
        n *= axis_sizes(mesh)[a]
    return n
