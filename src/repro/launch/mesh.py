"""Production meshes.

Kept as FUNCTIONS so importing this module never touches jax device state.

* single pod : (16, 16)   axes ("data", "model")  -- 256 chips (v5e pod)
* multi-pod  : (2, 16, 16) axes ("pod", "data", "model") -- 512 chips

Workers of the Byzantine-robust federation are the indices along the
("pod",) "data" axes: 16 workers single-pod, 32 multi-pod; each worker owns
16 model-parallel chips and its own finite local dataset + SAGA table.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} -- set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests/examples)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n],
                         axis_types=(AxisType.Auto,) * len(shape))


def worker_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_workers(mesh) -> int:
    n = 1
    for a in worker_axes(mesh):
        n *= axis_sizes(mesh)[a]
    return n
