"""Runnable distributed training driver.

CPU-scale entry point for the same code path the dry-run lowers: builds a
host mesh over however many local devices exist, initializes real params,
and runs Byzantine-robust data-parallel training on synthetic LM data.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \\
        --steps 20 --mesh 4x2 --aggregator geomed --attack sign_flip --byzantine 1

(The flag must be set by the caller; unlike dryrun.py this driver is meant
to also run on real multi-chip platforms where forcing a device count would
be wrong.)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro import telemetry
from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import packing
from repro.core.robust_step import RobustConfig
from repro.data.synthetic import token_stream
from repro.core import guards as guards_lib
from repro.launch import health as health_lib
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shard_lib
from repro.launch import steps as steps_lib
from repro.models.api import build_model


def make_batch(key, cfg, num_workers: int, per_worker: int, seq: int):
    toks = jax.random.randint(key, (num_workers, per_worker, seq + 1),
                              0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    if cfg.family == "vlm":
        batch["image_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1),
            (num_workers, per_worker, cfg.num_prefix_tokens, cfg.d_model),
            jnp.float32).astype(cfg.dtype)
    if cfg.family == "audio":
        batch["audio_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (num_workers, per_worker, cfg.encoder_seq, cfg.d_model),
            jnp.float32).astype(cfg.dtype)
    return batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized variant (CPU friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (data x model) or "
                    "2x2x2 (pod x data x model, multi-pod worker axes); "
                    "default: all devices on the data axis")
    ap.add_argument("--aggregator", default="geomed")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--byzantine", type=int, default=0)
    ap.add_argument("--comm", default="gather", choices=["gather", "sharded"])
    ap.add_argument("--topology", default="star",
                    help="communication graph (repro.topology): star keeps "
                    "the master path; ring/torus2d/complete/erdos_renyi "
                    "train decentralized (per-node params)")
    ap.add_argument("--topology-seed", type=int, default=0,
                    help="seed for erdos_renyi draws")
    ap.add_argument("--topology-p", type=float, default=0.5,
                    help="edge probability for erdos_renyi")
    ap.add_argument("--gossip", default="gradient",
                    choices=["gradient", "params"],
                    help="decentralized message channel: gossip gradients "
                    "(aggregate then step) or parameters (local step then "
                    "robust model aggregation, arXiv:2308.05292)")
    ap.add_argument("--schedule", default="static",
                    choices=["static", "cyclic", "erdos_renyi"],
                    help="time-varying graph schedule: static keeps "
                    "--topology fixed; cyclic rotates a comma-separated "
                    "--topology list; erdos_renyi resamples a seeded "
                    "G(N, p) per round")
    ap.add_argument("--schedule-period", type=int, default=4,
                    help="rounds per erdos_renyi schedule period")
    ap.add_argument("--per-leaf", action="store_true",
                    help="disable the flat-packed hot path (DESIGN.md "
                    "Sec. 8) and run the pre-refactor per-leaf pipeline")
    ap.add_argument("--message-dtype", default="float32",
                    choices=list(packing.WIRE_FORMAT_NAMES),
                    help="wire format of the packed worker messages "
                    "(repro.core.packing.WIRE_FORMATS): bfloat16 halves "
                    "communication volume, int8 quarters it with per-block "
                    "symmetric scales, sign1 sends 1-bit signs with "
                    "per-client error feedback (robust rules still "
                    "accumulate in f32)")
    from repro.core.variance import VR_NAMES
    ap.add_argument("--vr", default="sgd", choices=list(VR_NAMES),
                    help="variance reduction (repro.core.variance): sgd "
                    "(none), minibatch, saga (per-sample table, O(J*D)/"
                    "client), lsvrg (loopless-SVRG snapshots, O(D)/client)")
    ap.add_argument("--saga-samples", type=int, default=4)
    ap.add_argument("--lsvrg-p", type=float, default=0.1,
                    help="per-step Bernoulli snapshot-refresh probability "
                    "for --vr lsvrg")
    ap.add_argument("--num-clients", type=int, default=0,
                    help="client-scale virtualization: total logical "
                    "clients; a seeded cohort the size of the worker count "
                    "participates per round (0 = full participation)")
    ap.add_argument("--participation-seed", type=int, default=0,
                    help="seed for the shuffled-epoch cohort sampler")
    ap.add_argument("--max-staleness", type=int, default=64,
                    help="staleness cutoff: rows at or beyond this many "
                    "rounds stale get aggregation weight exactly 0")
    ap.add_argument("--staleness-decay", type=float, default=1.0,
                    help="per-round staleness weight decay (1.0 keeps "
                    "weights 0/1: pure dropout masking)")
    ap.add_argument("--straggler-k", type=int, default=4,
                    help="how stale the straggler attack reports itself")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint in --checkpoint-dir "
                    "(full train state: params + opt + VR state + step) and "
                    "continue from there")
    ap.add_argument("--guards", action="store_true",
                    help="self-healing training (DESIGN.md Sec. 13): "
                    "in-graph per-row fault containment (non-finite / "
                    "magnitude-outlier messages get aggregation weight "
                    "exactly 0) plus the round-health verdict that holds "
                    "the train state on rejected rounds")
    ap.add_argument("--guard-multiplier", type=float, default=10.0,
                    help="magnitude gate: quarantine rows whose norm "
                    "exceeds this multiple of the median honest norm")
    ap.add_argument("--reject-ema", type=float, default=0.9,
                    help="decay of the aggregate-norm EMA behind the "
                    "round-health verdict")
    ap.add_argument("--reject-zmax", type=float, default=6.0,
                    help="reject a round when the aggregate norm's z-score "
                    "vs the EMA exceeds this (<=0: non-finite-only gate)")
    ap.add_argument("--rollback-patience", type=int, default=5,
                    help="consecutive bad rounds (rejected / non-finite "
                    "loss / loss blow-up) before rolling back to the last "
                    "good checkpoint")
    ap.add_argument("--loss-blowup", type=float, default=1e3,
                    help="treat a round as bad when the loss exceeds this "
                    "multiple of the best loss seen")
    ap.add_argument("--max-rollbacks", type=int, default=3,
                    help="stop restoring checkpoints after this many "
                    "rollbacks (the run continues degraded instead of "
                    "ping-ponging forever)")
    ap.add_argument("--degradation-ladder", default="",
                    help="escalation per rollback: semicolon-separated "
                    "RobustConfig override groups, e.g. "
                    "'trim=0.3;aggregator=trimmed_mean,trim=0.4' "
                    "(repro.launch.health)")
    ap.add_argument("--diagnostics", action="store_true",
                    help="compute in-graph aggregation diagnostics "
                    "(per-worker distance / implicit weight / krum scores, "
                    "DESIGN.md Sec. 11) inside the compiled step and log "
                    "them alongside the loss")
    ap.add_argument("--log-dir", default="",
                    help="run-telemetry directory (repro.telemetry): writes "
                    "<dir>/metrics.jsonl + <dir>/meta.json; empty keeps the "
                    "console-only progress line")
    ap.add_argument("--log-every", type=int, default=1,
                    help="keep every N-th step in metrics.jsonl")
    ap.add_argument("--profile-steps", type=int, default=0,
                    help="capture a profiler trace of this many post-warmup "
                    "steps into <log-dir>/profile (needs --log-dir)")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume needs --checkpoint-dir")
    if args.profile_steps and not args.log_dir:
        raise SystemExit("--profile-steps needs --log-dir")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ndev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (ndev, 1)
    if len(shape) not in (2, 3):
        raise SystemExit(f"--mesh must have 2 or 3 axes, got {args.mesh!r}")
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    mesh = mesh_lib.make_host_mesh(shape, axes)
    w = mesh_lib.num_workers(mesh)

    model = build_model(cfg, remat=False, q_chunk=min(args.seq, 512),
                        kv_chunk=min(args.seq, 512), loss_chunk=128)
    robust = RobustConfig(
        aggregator=args.aggregator, vr=args.vr, attack=args.attack,
        num_byzantine=args.byzantine, comm=args.comm, weiszfeld_iters=16,
        topology=args.topology, topology_seed=args.topology_seed,
        topology_p=args.topology_p, gossip=args.gossip,
        schedule=args.schedule, schedule_period=args.schedule_period,
        packed=not args.per_leaf, message_dtype=args.message_dtype,
        lsvrg_p=args.lsvrg_p, num_clients=args.num_clients,
        participation_seed=args.participation_seed,
        max_staleness=args.max_staleness,
        staleness_decay=args.staleness_decay,
        straggler_k=args.straggler_k,
        diagnostics=args.diagnostics,
        guards=args.guards, guard_multiplier=args.guard_multiplier,
        reject_ema=args.reject_ema, reject_zmax=args.reject_zmax)
    train = TrainConfig(optimizer=args.optimizer, lr=args.lr)
    from repro.core.robust_step import resolve_schedule
    sched = resolve_schedule(robust, w)
    decentralized = sched is not None
    reducer = robust.reducer()
    from repro.core import participation as participation_lib
    plan = participation_lib.resolve_participation(robust, w)
    if plan is not None:
        print(plan.describe())
    saga_samples = args.saga_samples if reducer.uses_sample_idx else 0
    def build_step(rcfg):
        """Step builder keyed on the (possibly ladder-escalated) robust
        config; the state STRUCTURE must not change across rebuilds
        (launch/health.py forbids structure-changing ladder fields)."""
        if decentralized:
            fn, _, _ = steps_lib.make_decentralized_train_step(
                model, rcfg, train, mesh, sched,
                saga_num_samples=saga_samples)
        else:
            fn, _, _ = steps_lib.make_train_step(
                model, rcfg, train, mesh, saga_num_samples=saga_samples)
        return steps_lib.compile_train_step(fn)

    if decentralized:
        # Schedule-level report: per-round spectral gaps + the joint gap.
        print(f"schedule: {sched.describe()}")

    key = jax.random.PRNGKey(0)
    with compat.use_mesh(mesh):
        params0 = model.init(key)
        params = params0
        if decentralized:
            # Every node starts from the same init; copies drift apart only
            # as far as the robust gossip lets them (consensus_dist metric).
            params = jax.tree_util.tree_map(
                lambda p: jnp.broadcast_to(p[None], (w,) + p.shape) + 0,
                params0)
        from repro.optim import get_optimizer
        opt = get_optimizer(args.optimizer, args.lr)
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if reducer.wants_state(saga_samples):
            # Cold-start VR state (zero SAGA table / zero lsvrg anchor):
            # warms up over the first steps instead of paying a J-pass
            # init sweep at LLM scale.  Under client-scale virtualization the
            # tables are resident per CLIENT, not per slot.
            rows = plan.num_clients if plan is not None else w
            state["vr"] = reducer.init_zeros(params0, rows, saga_samples)
        if plan is not None:
            state["staleness"] = participation_lib.init_staleness(
                plan.num_clients)
        if robust.guards:
            state["health"] = guards_lib.init_health()
        wspec = robust.message_spec(params0, batch_ndim=0)
        if robust.wire_format().error_feedback:
            # Per-client error-feedback residual for 1-bit wire formats.
            # Resident per CLIENT (like the VR tables): sampled cohorts
            # gather/scatter their rows alongside the SAGA/LSVRG state.
            rows = plan.num_clients if plan is not None else w
            state["ef"] = jnp.zeros((rows, wspec.padded_dim), jnp.float32)
        ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
        start = 0
        if args.resume:
            step0, state = ckpt.restore_latest(state)
            if step0 is not None:
                start = step0
                print(f"resumed full train state from step {step0}")
        # State donation lives in the step compiler (launch/steps.py):
        # params, opt moments and the VR state are all in arg 0.
        jstep = build_step(robust)
        log_dir = args.log_dir or None
        t0 = time.time()

        def console(step_i, row):
            # Progress line, fired from RunLogger.flush so the loop itself
            # never syncs on a metric value per step.
            extra = (f" consensus={row['consensus_dist']:.5f}"
                     if decentralized else "")
            wall = row.get("time_wall_s", time.time() - t0)
            print(f"step {step_i:4d} loss={row['loss']:.4f} "
                  f"agg_norm={row['agg_norm']:.4f}{extra} "
                  f"({wall/(step_i-start+1):.2f}s/step)")

        # Run-health monitor (DESIGN.md Sec. 13): consumes every flushed
        # metric row; guards runs flush in small batches so verdicts reach
        # the host within a few steps of being issued in-graph.
        monitor = health_lib.RunHealth(
            patience=args.rollback_patience, blowup=args.loss_blowup,
            ladder=args.degradation_ladder) if args.guards else None
        last_row: dict = {}

        def on_row(row):
            last_row.update(row)
            if monitor is not None:
                monitor.observe(row)

        logger = telemetry.RunLogger(
            log_dir, log_every=args.log_every,
            flush_every=4 if args.guards else 32, on_row=on_row,
            console=console, console_every=max(args.steps // 10, 1))
        if log_dir is not None:
            # AOT-lower the step once so meta.json records the compiled
            # executable's cost analysis + parsed collective traffic.  The
            # throwaway Compiled never executes, so argument donation in the
            # hot-loop jit is untouched (second compile is the price).
            batch0 = make_batch(jax.random.fold_in(key, 1000 + start), cfg,
                                w, args.per_worker_batch, args.seq)
            compiled = jstep.lower(state, batch0,
                                   jax.random.fold_in(key, start)).compile()
            ca = compat.cost_analysis(compiled)
            logger.write_meta(
                config=vars(args), jax_version=jax.__version__,
                backend=jax.default_backend(), device_count=ndev,
                mesh_shape=dict(zip(mesh.axis_names,
                                    (int(s) for s in mesh.devices.shape))),
                num_workers=w, start_step=start,
                cost_analysis={k: float(v) for k, v in sorted(ca.items())
                               if isinstance(v, (int, float))},
                collective_bytes=hlo_analysis.collective_bytes(
                    compiled.as_text()),
                wire={"message_dtype": args.message_dtype,
                      "bits_per_coord": wspec.wire_format.bits_per_coord,
                      "coords": wspec.padded_dim,
                      "bytes_per_message": wspec.wire_bytes(),
                      "bytes_per_round": wspec.wire_bytes() * w})
            del compiled, batch0

        timer = telemetry.PhaseTimer()
        prof = None
        profile_until = 0
        i = start
        while i < args.steps:
            if args.profile_steps and i == start + 1:
                # Skip the compile step, then trace N steady-state steps.
                prof = compat.profiler_trace(os.path.join(log_dir, "profile"))
                prof.__enter__()
                profile_until = i + args.profile_steps
            with timer.phase("data"):
                bkey = jax.random.fold_in(key, 1000 + i)
                batch = make_batch(bkey, cfg, w, args.per_worker_batch,
                                   args.seq)
            with timer.phase("step"):
                state, metrics = jstep(state, batch,
                                       jax.random.fold_in(key, i))
            with timer.phase("host"):
                host = timer.snapshot()
                host["time_wall_s"] = round(time.time() - t0, 3)
                logger.log_step(i, metrics, host=host)
                if ckpt and args.checkpoint_every and (i + 1) % args.checkpoint_every == 0:
                    ckpt.save_train_state(i + 1, state)
                    if monitor is None or monitor.healthy:
                        # Healthy as of the last flush -> rollback anchor.
                        ckpt.mark_good(i + 1)
            if prof is not None and i + 1 >= profile_until:
                jax.block_until_ready(jax.tree_util.tree_leaves(state))
                prof.__exit__(None, None, None)
                prof = None
            i += 1
            if (monitor is not None and monitor.rollback_pending
                    and ckpt is not None
                    and monitor.rollbacks < args.max_rollbacks):
                # Auto-rollback (DESIGN.md Sec. 13): drain the logger so the
                # monitor has seen every issued verdict, restore the last
                # good checkpoint, climb one ladder rung, and re-descend
                # with the SAME seeded key schedule -- deterministic, so
                # the continuation is bit-exact with a fresh resumed run
                # (tests/test_rollback.py).
                logger.flush()
                gstep, state = ckpt.restore_last_good(state)
                monitor.on_rollback()
                if gstep is None:
                    print("run unhealthy but no restorable checkpoint; "
                          "continuing without rollback")
                else:
                    escalated = monitor.escalate(robust)
                    if escalated != robust:
                        robust = escalated
                        jstep = build_step(robust)
                        print(f"rollback #{monitor.rollbacks}: restored "
                              f"step {gstep}, escalated to "
                              f"aggregator={robust.aggregator} "
                              f"trim={robust.trim} "
                              f"guard_multiplier={robust.guard_multiplier}")
                    else:
                        print(f"rollback #{monitor.rollbacks}: restored "
                              f"step {gstep} (ladder exhausted or empty)")
                    i = gstep
            elif monitor is not None and monitor.rollback_pending:
                # No checkpointing or rollback budget spent: reset the
                # counter so the warning does not fire every step.
                monitor.dismiss()
                print("run unhealthy; no rollback available "
                      f"(checkpointing={'on' if ckpt else 'off'}, "
                      f"rollbacks={monitor.rollbacks}/{args.max_rollbacks})")
        if prof is not None:
            jax.block_until_ready(jax.tree_util.tree_leaves(state))
            prof.__exit__(None, None, None)
        logger.close()
        if log_dir is not None and args.guards:
            # Fold the resilience outcome into meta.json so offline tooling
            # (and the CI chaos job) can assert on it without parsing the
            # whole metrics stream.
            meta_path = os.path.join(log_dir, "meta.json")
            try:
                with open(meta_path) as f:
                    meta = json.load(f)
            except (OSError, json.JSONDecodeError):
                meta = {}
            meta["resilience"] = {
                "rejected_rounds": last_row.get("rejected_rounds", 0.0),
                "final_loss": last_row.get("loss"),
                **monitor.summary(),
            }
            with open(meta_path, "w") as f:
                json.dump(meta, f, indent=2, sort_keys=True, default=str)
                f.write("\n")
    print(f"done ({args.steps - start} steps, {time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
