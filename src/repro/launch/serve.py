"""Runnable serving driver: prefill a batch of prompts, then decode tokens
step by step with the KV/SSM cache (greedy sampling).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \\
        --prompt-len 32 --decode-tokens 16 --batch 2

Pass ``--mesh DxM`` (e.g. 4x2) to serve on a device mesh: the batch is
sharded over the 'data' axis and the whole loop runs under the ambient mesh
(version-portable via repro.compat), exercising the same runtime the
distributed trainer uses.
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_config
from repro.launch import mesh as mesh_lib
from repro.models.api import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (data x model); "
                    "default: single-device, no mesh")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        compat.require_distributed(min_devices=2, what="mesh serving")
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = mesh_lib.make_host_mesh(shape, ("data", "model"))
        print(f"mesh: {mesh_lib.axis_sizes(mesh)}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=False, q_chunk=64, kv_chunk=64)
    with (compat.use_mesh(mesh) if mesh is not None
          else contextlib.nullcontext()):
        _serve(args, cfg, model, mesh)


def _serve(args, cfg, model, mesh) -> None:
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    b, s = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        batch["image_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.encoder_seq, cfg.d_model))
    if mesh is not None:
        data = mesh_lib.axis_sizes(mesh)["data"]
        if b % data == 0:
            # Shard the serving batch over the 'data' axis (leading batch dim).
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            sh = NamedSharding(mesh, P("data"))
            batch = {k: jax.device_put(v, sh) for k, v in batch.items()}
        else:
            print(f"WARNING: batch={b} not divisible by data axis ({data}); "
                  "serving with a REPLICATED batch, not data-sharded")

    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch)
    print(f"prefill {s} tokens: {time.time()-t0:.2f}s (logits {logits.shape})")

    # Grow the cache to prompt+decode capacity by padding the seq dim.
    cap = s + args.decode_tokens + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)

    def grow(leaf):
        # attention caches have shape (periods, B, S, KV, hd); mamba leaves don't grow
        if leaf.ndim == 5 and leaf.shape[2] in (s, s + cfg.num_prefix_tokens):
            pad = cap - leaf.shape[2]
            return jnp.pad(leaf, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return leaf

    # Only grow self-attention caches (cross caches stay encoder_seq-sized).
    def grow_tree(c):
        out = {}
        for posk, sub in c.items():
            out[posk] = {k: (grow(v) if k in ("k", "v") else v) for k, v in sub.items()}
        return out

    cache = grow_tree(cache)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    pos0 = s + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(args.decode_tokens - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    toks = jnp.concatenate(out_tokens, axis=1)
    dt = time.time() - t0
    print(f"decoded {toks.shape[1]} tokens/seq in {dt:.2f}s "
          f"({args.batch * toks.shape[1] / max(dt, 1e-9):.1f} tok/s)")
    print("sampled token ids (first seq):", toks[0][:16].tolist())


if __name__ == "__main__":
    main()
