"""Step builders for the distributed runtime.

``make_train_step`` composes the Byzantine-robust data-parallel training
step of DESIGN.md Sec. 2:

  1. per-worker gradients -- ``vmap(grad)`` over the leading worker axis of
     the batch (sharded over the pod/data mesh axes);
  2. optional variance-reduction correction via the
     :mod:`repro.core.variance` registry (SAGA tables / lsvrg snapshots
     sharded like the gradients);
  3. Byzantine attack injection (mask-replace the first B workers);
  4. robust aggregation (every registry aggregator runs on both paths):
       * ``comm="gather"``  -- paper-faithful replicated master (XLA
         all-gathers the worker axes; the rule runs redundantly);
       * ``comm="sharded"`` -- beyond-paper coordinate resharding (shard_map
         all_to_all; psum'd norms / partial Gram / per-block segments --
         DESIGN.md Sec. 2);
  5. optimizer update (paper update is plain SGD, eq. (11)).

With ``robust.packed`` (default) steps 3-4 run on ONE packed (W, D)
message buffer (DESIGN.md Sec. 8) -- a single sharding constraint, a
single attack pass, and the flat aggregation engine -- instead of walking
the gradient pytree leaf-by-leaf; ``packed=False`` keeps the pre-refactor
per-leaf pipeline (the ``benchmarks/bench_step.py`` baseline).  Compile
the returned step with :func:`compile_train_step` to DONATE the train
state (params + opt moments + variance-reduction state): the input
buffers are reused for the outputs instead of holding two state
generations live.

Worker axes may be a single ``data`` axis or multi-pod ``(pod, data)``
(``launch/mesh.py``); the step builder is agnostic -- it forwards
``mesh_lib.worker_axes(mesh)`` everywhere.

``make_prefill_step`` / ``make_serve_step`` build the inference paths,
including the sequence-sharded long-context decode.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import telemetry
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import attacks as attack_lib
from repro.core import guards as guards_lib
from repro.core import participation as participation_lib
from repro.core.robust_step import RobustConfig, sharded_aggregate
from repro.core import aggregators as agg_lib
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shard_lib
from repro.models.api import Model
from repro.optim import optimizers as optim_lib

Pytree = Any


def _opt_specs_like(optimizer_name: str, pspecs: Pytree) -> Pytree:
    """Optimizer-state PartitionSpecs mirroring the parameter specs.  Shared
    by the master and decentralized step builders so a new optimizer is
    reflected in both (the decentralized caller passes node-stacked specs)."""
    if optimizer_name == "sgd":
        return ()
    if optimizer_name == "momentum":
        return pspecs
    return optim_lib.AdamState(mu=pspecs, nu=pspecs)


def _opt_structs_like(optimizer_name: str, ps: Pytree) -> Pytree:
    """Optimizer-state ShapeDtypeStructs for parameter structs ``ps`` (Adam
    moments are always f32); same sharing contract as `_opt_specs_like`."""
    if optimizer_name == "sgd":
        return ()
    if optimizer_name == "momentum":
        return ps
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return optim_lib.AdamState(mu=jax.tree_util.tree_map(f32, ps),
                               nu=jax.tree_util.tree_map(f32, ps))


# The auto-jit gather master packs only the rules that need FULL-VECTOR
# message geometry (and therefore replicate the (W, p) matrix anyway);
# coordinate-separable and per-leaf rules stay leaf-sharded (see the
# dispatch comment inside make_train_step).
PACKED_GATHER_RULES = frozenset(
    {"geomed", "geomed_groups", "krum", "centered_clip"})


def make_train_step(model: Model, robust: RobustConfig, train: TrainConfig,
                    mesh, *, saga_num_samples: int = 0):
    """Returns (train_step, state_specs, make_state_structs).

    ``train_step(state, batch, key) -> (state, metrics)`` where ``state`` is
    a dict {params, opt, vr?, step, staleness?}.  Batch leaves carry a
    leading worker axis of size num_workers(mesh).

    With ``robust.num_clients > 0`` (client-scale virtualization, DESIGN.md
    Sec. 10) the variance-reduction state is resident PER CLIENT -- leading
    (num_clients,) axis, still sharded over the worker mesh axes (so
    num_clients should be a multiple of the worker count) -- and each round
    the seeded cohort of W clients mans the mesh's worker slots: their VR
    rows are gathered/scattered in the auto-jit region around the
    shard-mapped aggregation, and the cohort's staleness counters produce
    the replicated (W,) per-slot weights the flat rules consume.  The batch
    stays per-SLOT (the data pipeline feeds whatever the round's cohort
    should see; this builder virtualizes optimizer-relevant state, not the
    input pipeline).
    """
    cfg = model.cfg
    if robust.comm not in ("gather", "sharded"):
        raise ValueError(f"RobustConfig.comm must be 'gather' or 'sharded', "
                         f"got {robust.comm!r}")
    if robust.comm == "sharded":
        compat.require_distributed(what="comm='sharded' aggregation")
    wa = mesh_lib.worker_axes(mesh)
    w = mesh_lib.num_workers(mesh)
    plan = participation_lib.resolve_participation(robust, w)
    num_clients = plan.num_clients if plan is not None else w
    weighted = participation_lib.uses_staleness(robust, plan)
    optimizer = optim_lib.get_optimizer(train.optimizer, train.lr)
    attack_cfg = robust.attack_config()
    reducer = robust.reducer()
    use_vr = reducer.wants_state(saga_num_samples)
    wire_fmt = robust.wire_format()
    use_ef = wire_fmt.error_feedback
    if wire_fmt.quantized and not robust.packed:
        raise ValueError(
            f"message_dtype={robust.message_dtype!r} is a quantized wire "
            "format and needs the packed path (robust.packed=True)")

    def row_weights_for(state):
        """Replicated (W,) staleness weights of the mesh's message slots
        (Byzantine slots are the FIRST B -- mask-replace convention), plus
        this round's cohort, or (None, None, None) on the bit-exact
        unweighted path."""
        cohort = None if plan is None else plan.cohort_at(state["step"])
        if not weighted:
            return None, None, cohort
        if plan is None:
            honest_stal = jnp.zeros((w,), jnp.int32)
        else:
            honest_stal = jnp.take(state["staleness"], cohort, axis=0)
        slot_stal = participation_lib.slot_staleness(
            honest_stal, robust.attack,
            robust.num_byzantine if robust.attack != "none" else 0,
            straggler_k=robust.straggler_k,
            max_staleness=robust.max_staleness, byz_first=True)
        rw = participation_lib.staleness_weights(
            slot_stal, decay=robust.staleness_decay,
            max_staleness=robust.max_staleness)
        return rw, slot_stal, cohort

    def train_step(state, batch, key):
        params = state["params"]
        rw, slot_stal, cohort = row_weights_for(state)

        def worker_loss(p, wb):
            return model.loss(p, wb)

        losses, grads = jax.vmap(jax.value_and_grad(worker_loss),
                                 in_axes=(None, 0))(params, batch)
        # Keep the worker axis sharded over the worker mesh axes.
        waxes = wa if len(wa) > 1 else wa[0]
        grads = jax.tree_util.tree_map(
            lambda g: jax.lax.with_sharding_constraint(
                g, jax.sharding.NamedSharding(mesh, P(waxes))), grads)

        if use_vr:
            # Table reducers (saga) draw this step's sample index; batch
            # reducers (lsvrg) correct the batch gradient directly, with
            # the snapshot oracle re-running the grad vmap at the
            # snapshot params and no full-gradient oracle (the anchor
            # refreshes from the current batch gradient -- the practical
            # large-scale variant, DESIGN.md Sec. 9).
            idx = None
            if reducer.uses_sample_idx:
                idx = reducer.draw_indices(jax.random.fold_in(key, 1), w,
                                           saga_num_samples)
            vr_rows = (participation_lib.gather_rows(state["vr"], cohort)
                       if plan is not None else state["vr"])
            msgs, vr_rows, vr_metrics = reducer.correct(
                vr_rows, grads, idx, jax.random.fold_in(key, 3),
                params=jax.tree_util.tree_map(
                    lambda p: jnp.broadcast_to(p[None], (w,) + p.shape),
                    params),
                grads_at=lambda snap: jax.vmap(
                    jax.grad(worker_loss))(snap, batch))
            vr_state = (participation_lib.scatter_rows(state["vr"], cohort,
                                                       vr_rows)
                        if plan is not None else vr_rows)
        else:
            msgs, vr_state, vr_metrics = grads, state.get("vr"), {}

        # Sender-side wire step (DESIGN.md Sec. 12): quantized formats pack
        # the messages once, fold in / bank the per-slot error-feedback
        # residual (sign1), and continue with the DEQUANTIZED wire -- what
        # the attacks observe and the variance metric measures, mirroring
        # the sim path.  The EF state is updated for ALL w slots HERE,
        # before the comm-mode branch, so gather and sharded runs carry
        # bit-identical residual tables.
        ef_state = state.get("ef")
        if wire_fmt.quantized:
            wspec = robust.message_spec(msgs, batch_ndim=1)
            wbuf = jax.lax.with_sharding_constraint(
                wspec.pack(msgs),
                jax.sharding.NamedSharding(mesh, P(wa if len(wa) > 1
                                                   else wa[0])))
            ef_rows = ef_state
            if use_ef and plan is not None:
                ef_rows = participation_lib.gather_rows(state["ef"], cohort)
            wbuf, ef_rows = wspec.transmit(wbuf, ef_rows)
            if use_ef:
                ef_state = (participation_lib.scatter_rows(
                    state["ef"], cohort, ef_rows)
                    if plan is not None else ef_rows)
            msgs = wspec.unpack(wbuf)

        # Honest-message variance BEFORE attack injection (mask-replace hits
        # the FIRST B slots, so the honest workers are the slots >= B).
        b = robust.num_byzantine if robust.attack != "none" else 0
        hmask = (jnp.arange(w) >= b).astype(jnp.float32)
        var = telemetry.consensus_dist(msgs, hmask, max(w - b, 1))

        diag = None
        quarantined = None
        if robust.comm == "gather" and (weighted or robust.diagnostics or
                                        robust.guards or (
                robust.packed and (wire_fmt.quantized or
                                   robust.aggregator in PACKED_GATHER_RULES))):
            # Flat-packed hot path (DESIGN.md Sec. 8): one (W, D) buffer
            # carries the messages through attack + aggregation.  The
            # FULL-VECTOR rules route here by default -- they replicate the
            # message matrix anyway (the Weiszfeld/Gram needs global
            # norms), so packing collapses their per-leaf launches for
            # free.  The VR state stays per-leaf so its tables/snapshots
            # keep their model-axis sharding (DESIGN.md Sec. 4).  When
            # staleness weights OR diagnostics are active EVERY gather rule
            # routes here: both are flat-engine features (the per-leaf
            # baseline predates them).
            spec = robust.message_spec(msgs, batch_ndim=1)
            buf = jax.lax.with_sharding_constraint(
                spec.pack(msgs), jax.sharding.NamedSharding(mesh, P(waxes)))
            buf = attack_lib.apply_attack_stacked(
                attack_cfg, buf, jax.random.fold_in(key, 2), spec=spec)
            if wire_fmt.quantized:
                # Byzantine payloads are wire-constrained too (the honest
                # rows are already a fixed point of the round-trip); the
                # sharded branch gets the same treatment inside
                # sharded_aggregate's encode.
                buf = spec.wire_roundtrip(buf)
            flat_fn = robust.flat_aggregator_fn(spec)
            if robust.guards:
                # Containment on the DEQUANTIZED wire (dequantize-then-
                # guard, DESIGN.md Sec. 13); quarantined rows fold into the
                # flat rule as zero row_weights.
                gmask = guards_lib.guard_mask(
                    buf, multiplier=robust.guard_multiplier, base_weights=rw)
                out = guards_lib.guarded_flat_call(flat_fn, buf, gmask,
                                                   row_weights=rw)
                quarantined = jnp.sum(1.0 - gmask)
            else:
                out = flat_fn(buf) if rw is None else flat_fn(
                    buf, row_weights=rw)
            if robust.diagnostics:
                agg_vec, diag = out
            else:
                agg_vec = out
            agg = spec.unpack(agg_vec, batch_ndim=0)
        else:
            # Everything else keeps per-leaf messages: comm="sharded" is
            # ALREADY coordinate-packed internally (it flattens each
            # device's leaf shards before the all_to_all, DESIGN.md
            # Sec. 2), and the coordinate-separable rules (mean/median/
            # trimmed_mean; geomed_blockwise is per-leaf by definition)
            # act shard-locally under the auto-sharded jit -- packing them
            # into one replicated buffer would DESTROY their model-axis
            # sharding for zero algorithmic gain.
            msgs = attack_lib.apply_attack_stacked(
                attack_cfg, msgs, jax.random.fold_in(key, 2))
            if robust.comm == "sharded":
                out = _sharded_agg(msgs, robust, mesh, pspecs,
                                   row_weights=rw,
                                   diagnostics=robust.diagnostics)
                agg, diag = out if robust.diagnostics else (out, None)
            else:
                agg = _gather_agg(msgs, robust)

        updates, opt_state = optimizer.update(agg, state["opt"], params,
                                              state["step"])
        params = optim_lib.apply_updates(params, updates)
        agg_norm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(agg)))
        health = state.get("health")
        health_metrics = {}
        if robust.guards:
            # Round-health verdict (DESIGN.md Sec. 13): a rejected round
            # holds params/opt/VR/EF via select (donation-safe, no host
            # sync); step/staleness/health always advance.
            accept, health = guards_lib.round_verdict(
                agg_norm, health, decay=robust.reject_ema,
                zmax=robust.reject_zmax, warmup=robust.reject_warmup)
            params, opt_state, vr_state, ef_state = guards_lib.select_tree(
                accept, (params, opt_state, vr_state, ef_state),
                (state["params"], state["opt"], state.get("vr"),
                 state.get("ef")))
            health_metrics = telemetry.health_metrics(health, accept)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        if use_vr:
            new_state["vr"] = vr_state
        if use_ef:
            new_state["ef"] = ef_state
        if robust.guards:
            new_state["health"] = health
        if plan is not None:
            new_state["staleness"] = participation_lib.tick_staleness(
                state["staleness"], cohort)
        metrics = {
            "loss": jnp.mean(losses),
            "honest_variance": var,
            "agg_norm": agg_norm,
            **vr_metrics,
            **telemetry.staleness_metrics(slot_stal),
            **health_metrics,
        }
        if quarantined is not None:
            metrics["quarantined_rows"] = quarantined
        if diag is not None:
            metrics.update(telemetry.diagnostics_metrics(diag))
        return new_state, metrics

    # ---- specs / structs -------------------------------------------------
    szs = mesh_lib.axis_sizes(mesh)
    pspecs = model.param_specs(szs)
    wa_spec = wa if len(wa) > 1 else wa[0]

    def state_specs():
        sp = {"params": pspecs, "opt": _opt_specs_like(train.optimizer, pspecs),
              "step": P()}
        if use_vr:
            sp["vr"] = reducer.state_specs(pspecs, wa_spec)
        if use_ef:
            # (num_clients, D) residual rows sharded over the worker axes,
            # like the per-client VR tables (DESIGN.md Sec. 12).
            sp["ef"] = P(wa_spec)
        if robust.guards:
            sp["health"] = P()   # (HEALTH_WIDTH,) f32, replicated
        if plan is not None:
            sp["staleness"] = P()   # (num_clients,) int32, replicated
        return sp

    def state_structs():
        ps = model.param_structs()
        st = {"params": ps, "opt": _opt_structs_like(train.optimizer, ps),
              "step": jax.ShapeDtypeStruct((), jnp.int32)}
        if robust.guards:
            st["health"] = jax.ShapeDtypeStruct(
                (guards_lib.HEALTH_WIDTH,), jnp.float32)
        if use_vr:
            # Per-client resident rows under partial participation.
            st["vr"] = reducer.state_structs(ps, num_clients,
                                             saga_num_samples)
        if use_ef:
            st["ef"] = jax.ShapeDtypeStruct(
                (num_clients,
                 robust.message_spec(ps, batch_ndim=0).padded_dim),
                jnp.float32)
        if plan is not None:
            st["staleness"] = jax.ShapeDtypeStruct((num_clients,), jnp.int32)
        return st

    return train_step, state_specs(), state_structs


def make_decentralized_train_step(model: Model, robust: RobustConfig,
                                  train: TrainConfig, mesh, topology, *,
                                  saga_num_samples: int = 0):
    """Server-free variant of :func:`make_train_step` (DESIGN.md Secs. 6-7):
    every worker-axis index is a graph NODE owning its own parameter /
    optimizer copy (state leaves grow a leading node axis sharded over the
    worker axes), gradients are computed at each node's own parameters, and
    the aggregation step is the per-node masked neighborhood rule of
    :func:`repro.topology.decentralized_aggregate` -- per-edge Byzantine
    attacks included, so ``apply_attack_stacked`` is NOT used here.  Both
    ``comm="gather"`` and ``comm="sharded"`` run on 1-axis and (pod, data)
    worker meshes.

    ``topology`` may be a graph name, a :class:`repro.topology.Topology`,
    or a :class:`repro.topology.GraphSchedule`; with ``robust.schedule``
    != "static" the schedule is built around it and the state's step
    counter selects each round's stacked mask/mixing constants inside the
    compiled step (no per-round retrace).  ``robust.gossip`` picks the
    message channel: ``"gradient"`` aggregates neighbor gradients then
    applies the optimizer; ``"params"`` applies the optimizer locally first
    and robust-aggregates the neighbors' half-stepped models.

    Returns ``(train_step, state_specs, make_state_structs)`` like
    :func:`make_train_step`; metrics add ``consensus_dist`` (mean squared
    drift of the honest nodes' parameters from their average).
    """
    from repro.core.robust_step import resolve_schedule
    from repro.topology import (GOSSIP_MODES, decentralized_aggregate,
                                validate_schedule)

    cfg = model.cfg
    if robust.comm not in ("gather", "sharded"):
        raise ValueError(f"RobustConfig.comm must be 'gather' or 'sharded', "
                         f"got {robust.comm!r}")
    if robust.gossip not in GOSSIP_MODES:
        raise ValueError(f"RobustConfig.gossip must be one of {GOSSIP_MODES}, "
                         f"got {robust.gossip!r}")
    compat.require_distributed(what="decentralized topology training")
    wa = mesh_lib.worker_axes(mesh)
    w = mesh_lib.num_workers(mesh)
    sched = resolve_schedule(robust, w, topology)
    if sched is None:
        raise ValueError(
            "topology 'star' with a static schedule is the master "
            "federation -- use launch/steps.make_train_step (the bit-exact "
            "paper path)")
    validate_schedule(robust, sched, w)  # fail at build time, not first jit
    optimizer = optim_lib.get_optimizer(train.optimizer, train.lr)
    reducer = robust.reducer()
    use_vr = reducer.wants_state(saga_num_samples)
    wire_fmt = robust.wire_format()
    use_ef = wire_fmt.error_feedback
    if wire_fmt.quantized and not robust.packed:
        raise ValueError(
            f"message_dtype={robust.message_dtype!r} is a quantized wire "
            "format and needs the packed path (robust.packed=True)")
    b = robust.num_byzantine if robust.attack != "none" else 0
    honest = (jnp.arange(w) >= b).astype(jnp.float32)  # first B nodes attack
    wh = max(w - b, 1)
    plan = participation_lib.resolve_participation(robust, w)
    num_clients = plan.num_clients if plan is not None else w
    weighted = participation_lib.uses_staleness(robust, plan)

    def row_weights_for(state):
        """Replicated (W,) per-sender staleness weights + the round's cohort
        (first-B-Byzantine node convention), or Nones on the unweighted
        bit-exact path."""
        cohort = None if plan is None else plan.cohort_at(state["step"])
        if not weighted:
            return None, None, cohort
        if plan is None:
            honest_stal = jnp.zeros((w,), jnp.int32)
        else:
            honest_stal = jnp.take(state["staleness"], cohort, axis=0)
        slot_stal = participation_lib.slot_staleness(
            honest_stal, robust.attack, b,
            straggler_k=robust.straggler_k,
            max_staleness=robust.max_staleness, byz_first=True)
        rw = participation_lib.staleness_weights(
            slot_stal, decay=robust.staleness_decay,
            max_staleness=robust.max_staleness)
        return rw, slot_stal, cohort

    szs = mesh_lib.axis_sizes(mesh)
    pspecs = model.param_specs(szs)
    wa_spec = wa if len(wa) > 1 else wa[0]
    node_specs = jax.tree_util.tree_map(
        lambda s: P(wa_spec, *tuple(s)), pspecs,
        is_leaf=lambda x: isinstance(x, P))

    def train_step(state, batch, key):
        params = state["params"]  # leaves (W, ...): one copy per node
        rw, slot_stal, cohort = row_weights_for(state)

        losses, grads = jax.vmap(jax.value_and_grad(model.loss))(params, batch)
        grads = jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, jax.sharding.NamedSharding(mesh, s)), grads, node_specs)

        if use_vr:
            # Same oracle binding as make_train_step, but the params/
            # snapshot gradients are per-NODE (each node corrects against
            # its own iterate); under partial participation the round's
            # cohort rows are gathered from the per-client resident state.
            idx = None
            if reducer.uses_sample_idx:
                idx = reducer.draw_indices(jax.random.fold_in(key, 1), w,
                                           saga_num_samples)
            vr_rows = (participation_lib.gather_rows(state["vr"], cohort)
                       if plan is not None else state["vr"])
            msgs, vr_rows, vr_metrics = reducer.correct(
                vr_rows, grads, idx, jax.random.fold_in(key, 3),
                params=params,
                grads_at=lambda snap: jax.vmap(
                    jax.grad(model.loss))(snap, batch))
            vr_state = (participation_lib.scatter_rows(state["vr"], cohort,
                                                       vr_rows)
                        if plan is not None else vr_rows)
        else:
            msgs, vr_state, vr_metrics = grads, state.get("vr"), {}

        # With diagnostics the shard_map emits a second output: the
        # replicated per-sender AggDiagnostics summary (all-P() specs).
        out_specs = node_specs
        if robust.diagnostics:
            out_specs = (node_specs,
                         telemetry.AggDiagnostics(
                             *(P() for _ in telemetry.AggDiagnostics._fields)))

        def node_agg(local_msgs, t, k, weights=None):
            local = jax.tree_util.tree_map(lambda z: z[0], local_msgs)
            out = decentralized_aggregate(
                local, robust, sched, comm=robust.comm, worker_axes=wa,
                model_axes=("model",), num_workers=w, key=k,
                round_index=t, row_weights=weights,
                diagnostics=robust.diagnostics)
            if robust.diagnostics:
                out, d = out
                return jax.tree_util.tree_map(lambda a: a[None], out), d
            return jax.tree_util.tree_map(lambda a: a[None], out)

        if rw is None:
            def gossip_agg(wire_msgs):
                return compat.shard_map(
                    node_agg, mesh=mesh, in_specs=(node_specs, P(), P()),
                    out_specs=out_specs, check_vma=False,
                )(wire_msgs, state["step"], jax.random.fold_in(key, 2))
        else:
            # Staleness weighting: the replicated (W,) sender weights ride
            # into the shard_map as a P() input and multiply the mask's
            # sender columns inside decentralized_aggregate.
            def gossip_agg(wire_msgs):
                return compat.shard_map(
                    node_agg, mesh=mesh, in_specs=(node_specs, P(), P(), P()),
                    out_specs=out_specs, check_vma=False,
                )(wire_msgs, state["step"], jax.random.fold_in(key, 2), rw)

        # Sender-side wire step for the gossiped channel (DESIGN.md
        # Sec. 12): same packed transmit as the master step, applied to
        # whichever tree goes on the wire -- gradients in gradient gossip,
        # the half-stepped models in params gossip.  Updated in the
        # auto-jit region before the shard_map, so both comm modes carry
        # bit-identical residual tables; decentralized_aggregate then
        # ships/dequantizes the (idempotently re-encoded) wire.
        ef_state = state.get("ef")

        def wire_transmit(tree):
            nonlocal ef_state
            if not wire_fmt.quantized:
                return tree
            wspec = robust.message_spec(tree, batch_ndim=1)
            wbuf = wspec.pack(tree)
            ef_rows = state.get("ef")
            if use_ef and plan is not None:
                ef_rows = participation_lib.gather_rows(state["ef"], cohort)
            wbuf, ef_rows = wspec.transmit(wbuf, ef_rows)
            if use_ef:
                ef_state = (participation_lib.scatter_rows(
                    state["ef"], cohort, ef_rows)
                    if plan is not None else ef_rows)
            return wspec.unpack(wbuf)

        if robust.gossip != "params":
            msgs = wire_transmit(msgs)

        # Honest-message variance BEFORE the gossip (first B nodes attack).
        var = telemetry.consensus_dist(msgs, honest, wh)

        diag = None
        if robust.gossip == "params":
            # Local optimizer step with each node's own corrected gradient,
            # then robust PARAMETER gossip: the wire carries half-stepped
            # models and the aggregate IS the new iterate.  agg_norm keeps
            # gradient-scale meaning across modes by reporting the per-step
            # MOVEMENT (aggregate minus previous iterate), not the iterate.
            updates, opt_state = optimizer.update(msgs, state["opt"], params,
                                                  state["step"])
            half = optim_lib.apply_updates(params, updates)
            agg = gossip_agg(wire_transmit(half))
            if robust.diagnostics:
                agg, diag = agg
            agg_move = jax.tree_util.tree_map(
                lambda a, p: a.astype(jnp.float32) - p.astype(jnp.float32),
                agg, params)
            params = agg
        else:
            agg = gossip_agg(msgs)
            if robust.diagnostics:
                agg, diag = agg
            agg_move = agg
            updates, opt_state = optimizer.update(agg, state["opt"], params,
                                                  state["step"])
            params = optim_lib.apply_updates(params, updates)
        agg_norm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(agg_move)) / w)
        health = state.get("health")
        health_metrics = {}
        if robust.guards:
            # Verdict on the per-step MOVEMENT norm (mode-independent
            # scale); a rejected round holds every node's params/opt/VR/EF.
            accept, health = guards_lib.round_verdict(
                agg_norm, health, decay=robust.reject_ema,
                zmax=robust.reject_zmax, warmup=robust.reject_warmup)
            params, opt_state, vr_state, ef_state = guards_lib.select_tree(
                accept, (params, opt_state, vr_state, ef_state),
                (state["params"], state["opt"], state.get("vr"),
                 state.get("ef")))
            health_metrics = telemetry.health_metrics(health, accept)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        if use_vr:
            new_state["vr"] = vr_state
        if use_ef:
            new_state["ef"] = ef_state
        if robust.guards:
            new_state["health"] = health
        if plan is not None:
            new_state["staleness"] = participation_lib.tick_staleness(
                state["staleness"], cohort)

        metrics = {
            "loss": jnp.sum(honest * losses) / wh,
            "honest_variance": var,
            # Consensus drift of the honest nodes' parameter copies.
            "consensus_dist": telemetry.consensus_dist(params, honest, wh),
            "agg_norm": agg_norm,
            **vr_metrics,
            **telemetry.staleness_metrics(slot_stal),
            **health_metrics,
        }
        if diag is not None:
            metrics.update(telemetry.diagnostics_metrics(diag))
        return new_state, metrics

    # ---- specs / structs: every leaf gains the leading node axis ---------
    def state_specs():
        sp = {"params": node_specs,
              "opt": _opt_specs_like(train.optimizer, node_specs),
              "step": P()}
        if use_vr:
            sp["vr"] = reducer.state_specs(pspecs, wa_spec)
        if use_ef:
            sp["ef"] = P(wa_spec)
        if robust.guards:
            sp["health"] = P()   # (HEALTH_WIDTH,) f32, replicated
        if plan is not None:
            sp["staleness"] = P()   # (num_clients,) int32, replicated
        return sp

    def state_structs():
        ps = model.param_structs()
        node = lambda s: jax.ShapeDtypeStruct((w,) + s.shape, s.dtype)
        nps = jax.tree_util.tree_map(node, ps)
        st = {"params": nps, "opt": _opt_structs_like(train.optimizer, nps),
              "step": jax.ShapeDtypeStruct((), jnp.int32)}
        if robust.guards:
            st["health"] = jax.ShapeDtypeStruct(
                (guards_lib.HEALTH_WIDTH,), jnp.float32)
        if use_vr:
            st["vr"] = reducer.state_structs(ps, num_clients,
                                             saga_num_samples)
        if use_ef:
            st["ef"] = jax.ShapeDtypeStruct(
                (num_clients,
                 robust.message_spec(ps, batch_ndim=0).padded_dim),
                jnp.float32)
        if plan is not None:
            st["staleness"] = jax.ShapeDtypeStruct((num_clients,), jnp.int32)
        return st

    return train_step, state_specs(), state_structs


def _gather_agg(msgs: Pytree, robust: RobustConfig) -> Pytree:
    """Paper-faithful master, per-leaf baseline (robust.packed=False):
    plain stacked aggregation; under jit the Weiszfeld forces an
    all-gather of the worker axis on every device."""
    name = robust.aggregator
    agg = agg_lib.get_aggregator(
        name, perleaf=True,
        max_iters=robust.weiszfeld_iters, tol=robust.weiszfeld_tol,
        num_groups=robust.num_groups, trim=robust.trim,
        num_byzantine=robust.num_byzantine, clip_radius=robust.clip_radius)
    return agg(msgs)


def _sharded_agg(msgs: Pytree, robust: RobustConfig, mesh,
                 param_specs: Pytree, *,
                 row_weights: Optional[jnp.ndarray] = None,
                 diagnostics: bool = False) -> Pytree:
    """Beyond-paper: all_to_all coordinate resharding + slice-local rules
    inside a FULLY-manual shard_map (worker axes and model axis): every leaf
    arrives as its local shard, the flatten/all_to_all stay local, and global
    geometry is restored by small psums over (worker + model) axes --
    W-float norms per Weiszfeld/clip iteration, one (W, W) partial Gram for
    krum, a (W, num_leaves) per-block matrix for geomed_blockwise.  Bytes
    moved per device: O(2 * p_shard) instead of the gather master's
    O(W * p_shard).  ``row_weights``: optional (W,) staleness weights,
    passed in REPLICATED (``P()``) so every device's slice rule sees the
    same per-row mass (DESIGN.md Sec. 10).

    With ``diagnostics`` the shard_map also returns the replicated
    :class:`repro.telemetry.AggDiagnostics` struct (every field rides out
    as a ``P()`` output -- the in-graph psums already made it identical on
    all devices)."""
    wa = mesh_lib.worker_axes(mesh)
    w = mesh_lib.num_workers(mesh)
    waxes = wa if len(wa) > 1 else wa[0]

    in_specs = jax.tree_util.tree_map(
        lambda s: P(waxes, *tuple(s)), param_specs,
        is_leaf=lambda x: isinstance(x, P))
    out_specs = param_specs
    if diagnostics:
        out_specs = (param_specs,
                     telemetry.AggDiagnostics(
                         *(P() for _ in telemetry.AggDiagnostics._fields)))

    if row_weights is None:
        def agg_fn(local_msgs):
            local = jax.tree_util.tree_map(lambda z: z[0], local_msgs)
            return sharded_aggregate(local, robust, worker_axes=wa,
                                     model_axes=("model",), num_workers=w,
                                     diagnostics=diagnostics)

        return compat.shard_map(agg_fn, mesh=mesh, in_specs=(in_specs,),
                                out_specs=out_specs, check_vma=False)(msgs)

    def agg_fn_w(local_msgs, rw):
        local = jax.tree_util.tree_map(lambda z: z[0], local_msgs)
        return sharded_aggregate(local, robust, worker_axes=wa,
                                 model_axes=("model",), num_workers=w,
                                 row_weights=rw, diagnostics=diagnostics)

    return compat.shard_map(agg_fn_w, mesh=mesh, in_specs=(in_specs, P()),
                            out_specs=out_specs,
                            check_vma=False)(msgs, row_weights)


def compile_train_step(step_fn, *, donate_state: bool = True):
    """jit a train step with the TRAIN STATE DONATED (arg 0).

    The state -- params, optimizer moments, the variance-reduction state
    (for SAGA the largest buffer in the federation: W x J x p), and
    per-node copies on the decentralized path -- is consumed and
    re-emitted every step, so
    donating it lets XLA reuse the input buffers for the outputs instead
    of holding both generations live (halves peak state memory; in-place
    updates on backends that support donation).  Works for both state
    conventions: the dict state of the distributed steps
    (``step(state, batch, key)``) and the :class:`FederatedState` of the
    simulation steps (``step(state)``).

    CONTRACT: after calling the compiled step, the caller must treat the
    passed-in state as dead (its buffers may be deleted) and continue from
    the returned state -- the standard training-loop pattern.  Never feed
    the same state object twice (``tests/test_donation.py`` pins the
    re-use-after-donation behaviour), and batches/keys are NOT donated
    (callers may reuse them across steps).
    """
    return jax.jit(step_fn, donate_argnums=(0,) if donate_state else ())


# ---------------------------------------------------------------------------
# Inference steps
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, mesh):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model: Model, shape: ShapeConfig, mesh, *,
                    window: Optional[int] = None):
    """One-token decode step.  For long_500k (batch=1) the KV cache is
    sequence-sharded over 'data' and attention LSE-combines across shards."""
    cfg = model.cfg
    seq_sharded = shape.global_batch == 1 and any(
        bs.kind == "attn" for bs in cfg.resolve_pattern()[0])
    if seq_sharded:
        compat.require_distributed(what="sequence-sharded decode")

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(
            params, cache, tokens, pos, window=window,
            seq_shard_axis="data" if seq_sharded else None)

    return serve_step
