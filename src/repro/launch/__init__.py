from repro.launch.mesh import (
    axis_sizes,
    make_host_mesh,
    make_production_mesh,
    num_workers,
    worker_axes,
)
