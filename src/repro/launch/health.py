"""Host-side run-health monitor + rollback state machine (DESIGN.md Sec. 13).

The in-graph layers (repro.core.guards) contain per-row faults and reject
individual rounds without ever syncing the host; this module is the third
containment layer: a tiny host-side state machine that watches the metric
rows the :class:`repro.telemetry.RunLogger` flushes (``on_row`` callback,
so it inherits the logger's batched device_get -- no extra per-step sync)
and decides when the RUN is unhealthy enough to abandon the trajectory:

- ``patience`` consecutive bad rounds (in-graph verdict rejected the round,
  or the loss went non-finite, or the loss blew past ``blowup`` times the
  best loss seen) arm ``rollback_pending``;
- the train loop then restores the last known-good checkpoint
  (:meth:`repro.checkpoint.CheckpointManager.restore_last_good`) and
  re-descends with the same seeded key schedule -- deterministic, so a
  rolled-back run continues bit-exactly like a fresh run resumed from that
  checkpoint (tests/test_rollback.py);
- every rollback climbs one rung of the ``degradation ladder``: a
  user-configured list of RobustConfig overrides (e.g. raise the trim
  fraction, switch the aggregator, tighten the guard gate) applied via
  ``dataclasses.replace``, so repeated failures escalate the defense
  instead of replaying the same losing round forever.

Ladder syntax (CLI ``--degradation-ladder``): semicolon-separated rungs,
each a comma-separated ``key=value`` group over RobustConfig fields::

    trim=0.3;aggregator=trimmed_mean,trim=0.4;aggregator=geomed

Values are coerced to the dataclass field's type.  Only aggregation-rule
knobs belong on a ladder (aggregator / trim / guard_multiplier /
reject_zmax / clip_radius / weiszfeld_iters ...): fields that change the
TRAIN-STATE STRUCTURE (vr, message_dtype, num_clients, guards itself)
would invalidate the checkpoint being restored, and ``escalate`` refuses
them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# Structure-changing RobustConfig fields a ladder rung may not touch: the
# restored checkpoint was saved under the CURRENT state structure.
_LADDER_FORBIDDEN = frozenset(
    {"vr", "message_dtype", "num_clients", "guards", "comm", "packed",
     "topology", "gossip", "schedule"})


def parse_ladder(spec: str) -> list[dict[str, str]]:
    """Parse the semicolon/comma ladder syntax into a list of override
    dicts (values still strings; :func:`apply_rung` coerces)."""
    rungs = []
    for group in (spec or "").split(";"):
        group = group.strip()
        if not group:
            continue
        rung = {}
        for kv in group.split(","):
            if "=" not in kv:
                raise ValueError(
                    f"degradation ladder rung {group!r}: expected "
                    f"key=value, got {kv!r}")
            k, v = kv.split("=", 1)
            rung[k.strip()] = v.strip()
        rungs.append(rung)
    return rungs


def _coerce(value: str, like):
    """Coerce ``value`` to the type of the current field value ``like``."""
    if isinstance(like, bool):
        return value.lower() in ("1", "true", "yes", "on")
    if isinstance(like, int):
        return int(value)
    if isinstance(like, float):
        return float(value)
    return value


def apply_rung(robust, rung: dict[str, str]):
    """One ladder rung -> a new RobustConfig via ``dataclasses.replace``,
    with string values coerced to each field's current type."""
    fields = {f.name for f in dataclasses.fields(robust)}
    overrides = {}
    for k, v in rung.items():
        if k not in fields:
            raise ValueError(f"degradation ladder: RobustConfig has no "
                             f"field {k!r}")
        if k in _LADDER_FORBIDDEN:
            raise ValueError(
                f"degradation ladder: field {k!r} changes the train-state "
                f"structure and cannot be escalated mid-run")
        overrides[k] = _coerce(v, getattr(robust, k))
    return dataclasses.replace(robust, **overrides)


class RunHealth:
    """Consecutive-bad-round counter + rollback/escalation bookkeeping.

    Feed it metric rows via :meth:`observe` (wire it as the RunLogger's
    ``on_row`` callback); poll ``rollback_pending`` in the train loop and
    call :meth:`on_rollback` after restoring the checkpoint.
    """

    def __init__(self, *, patience: int = 5, blowup: float = 1e3,
                 ladder: str = ""):
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        self.patience = patience
        self.blowup = blowup
        self.ladder = parse_ladder(ladder)
        self.rollbacks = 0
        self.rollback_pending = False
        self._consecutive_bad = 0
        self._best_loss: Optional[float] = None

    # -- observation ------------------------------------------------------

    def observe(self, row: dict) -> None:
        """One flushed metric row.  Marks the round bad when the in-graph
        verdict rejected it, the loss is non-finite, or the loss exceeds
        ``blowup`` x the best loss seen so far."""
        bad = False
        accepted = row.get("round_accepted")
        if accepted is not None and float(accepted) < 0.5:
            bad = True
        loss = row.get("loss")
        if loss is not None:
            loss = float(loss)
            if not math.isfinite(loss):
                bad = True
            elif self._best_loss is None:
                self._best_loss = loss
            elif loss > self.blowup * max(abs(self._best_loss), 1e-12):
                bad = True
            else:
                self._best_loss = min(self._best_loss, loss)
        self._consecutive_bad = self._consecutive_bad + 1 if bad else 0
        if self._consecutive_bad >= self.patience:
            self.rollback_pending = True

    @property
    def healthy(self) -> bool:
        """No bad round observed since the last good one (as of the last
        RunLogger flush) -- the gate for marking checkpoints good."""
        return self._consecutive_bad == 0 and not self.rollback_pending

    # -- recovery ---------------------------------------------------------

    def on_rollback(self) -> None:
        """The loop restored a checkpoint: reset the counter (a fresh
        ``patience`` window must elapse before the next rollback) and
        count the escalation."""
        self.rollbacks += 1
        self.rollback_pending = False
        self._consecutive_bad = 0
        self._best_loss = None

    def dismiss(self) -> None:
        """No rollback is available (no checkpoint dir / budget spent):
        clear the pending flag and restart the patience window WITHOUT
        counting a rollback or consuming a ladder rung."""
        self.rollback_pending = False
        self._consecutive_bad = 0

    def escalate(self, robust):
        """RobustConfig for the post-rollback re-descent: rung
        ``rollbacks - 1`` of the ladder (call AFTER :meth:`on_rollback`),
        or ``robust`` unchanged when the ladder is exhausted/empty."""
        idx = self.rollbacks - 1
        if idx < 0 or idx >= len(self.ladder):
            return robust
        return apply_rung(robust, self.ladder[idx])

    def summary(self) -> dict:
        return {"rollbacks": self.rollbacks,
                "ladder_rungs_used": min(self.rollbacks, len(self.ladder))}
