import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).  Everything else follows.
"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory_analysis / cost_analysis / collective bytes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Artifacts: one JSON per (arch, shape, mesh) under experiments/dryrun/,
consumed by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core.robust_step import RobustConfig
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shard_lib
from repro.launch import steps as steps_lib
from repro.models import api as model_api
from repro.models.api import build_model, input_specs

# Hardware constants (TPU v5e-class target).
PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

# Variance-reduction defaults at scale (DESIGN.md Secs. 4, 9): SAGA table
# size J per arch; 0 => Byrd-SGD.  Only consumed by TABLE reducers
# (reducer.uses_sample_idx); state sizing itself routes through
# ``VarianceReducer.memory_elems`` so lsvrg and future reducers report
# correct dryrun memory with no special-casing here.
SAGA_SAMPLES = {
    "mamba2-130m": 8,
    "whisper-tiny": 8,
    "paligemma-3b": 4,
    "qwen2-moe-a2.7b": 2,
}


def vr_num_samples(arch: str, robust: RobustConfig) -> int:
    """The J the reducer's table needs (0 for non-table reducers)."""
    return SAGA_SAMPLES.get(arch, 0) if robust.reducer().uses_sample_idx else 0

# long_500k applicability (DESIGN.md Sec. 5): whisper enc-dec is skipped.
LONG_SKIP = {"whisper-tiny": "enc-dec with 448-token decoder context; 500k decode not meaningful"}
# Dense/MoE/VLM full-attention archs run long_500k under a sliding window.
NATIVE_LONG = {"mamba2-130m", "jamba-v0.1-52b", "mixtral-8x22b"}


def robust_config(arch: str, overrides: dict | None = None) -> RobustConfig:
    base = dict(aggregator="geomed", vr="saga" if SAGA_SAMPLES.get(arch) else "sgd",
                attack="sign_flip", num_byzantine=2, comm="gather",
                weiszfeld_iters=8, weiszfeld_tol=1e-6)
    base.update(overrides or {})
    base.pop("serve_fsdp", None)   # dry-run-only flag, not a RobustConfig field
    return RobustConfig(**base)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              robust_overrides: dict | None = None,
              train_overrides: dict | None = None,
              hlo_path: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    w = mesh_lib.num_workers(mesh)
    szs = mesh_lib.axis_sizes(mesh)
    chips = 1
    for s in mesh.devices.shape:
        chips *= s

    if shape.kind == "decode" and shape.seq_len > 100_000:
        if arch in LONG_SKIP:
            return {"arch": arch, "shape": shape_name, "skipped": LONG_SKIP[arch]}

    robust = robust_config(arch, robust_overrides)
    train = TrainConfig(**(train_overrides or {}))
    model = build_model(cfg, remat=train.remat, loss_chunk=256,
                        q_chunk=512, kv_chunk=1024)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "chips": chips,
        "robust": dataclasses.asdict(robust),
        "step_kind": shape.kind,
        "remat": train.remat,
    }
    t0 = time.time()

    with compat.use_mesh(mesh):
        if shape.kind == "train":
            step, sspecs, sstructs = steps_lib.make_train_step(
                model, robust, train, mesh,
                saga_num_samples=vr_num_samples(arch, robust))
            bspecs = shard_lib.batch_specs(cfg, shape, mesh)
            bstructs = input_specs(cfg, shape, num_workers=w)
            in_sh = (shard_lib.named(mesh, sspecs),
                     shard_lib.named(mesh, bspecs),
                     shard_lib.replicated(mesh))
            # Prefix sharding for the metrics subtree: reducers may add
            # their own scalar metrics (e.g. lsvrg's vr_snapshot_rate).
            out_sh = (shard_lib.named(mesh, sspecs),
                      shard_lib.replicated(mesh))
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = fn.lower(sstructs(), bstructs,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        elif shape.kind == "prefill":
            step = steps_lib.make_prefill_step(model, mesh)
            bspecs = shard_lib.batch_specs(cfg, shape, mesh)
            bstructs = input_specs(cfg, shape)
            pspecs = model.param_specs(szs)
            fn = jax.jit(step, in_shardings=(shard_lib.named(mesh, pspecs),
                                             shard_lib.named(mesh, bspecs)))
            lowered = fn.lower(model.param_structs(), bstructs)
        else:  # decode
            window = None
            if shape.seq_len > 100_000 and arch not in NATIVE_LONG and cfg.sliding_window is None:
                window = cfg.long_context_window
                record["window"] = window
            step = steps_lib.make_serve_step(model, shape, mesh, window=window)
            pspecs = model.param_specs(szs)
            if (robust_overrides or {}).get("serve_fsdp"):
                pspecs = shard_lib.fsdp_param_specs(pspecs, mesh,
                                                    model.param_structs())
                record["serve_fsdp"] = True
            cspecs = shard_lib.cache_specs_for(cfg, shape, mesh)
            bspecs = shard_lib.batch_specs(cfg, shape, mesh)
            cache_structs = model.cache_structs(shape.global_batch, shape.seq_len)
            bstructs = input_specs(cfg, shape)
            fn = jax.jit(step, in_shardings=(
                shard_lib.named(mesh, pspecs),
                shard_lib.named(mesh, cspecs),
                shard_lib.named(mesh, bspecs["tokens"]),
                shard_lib.replicated(mesh)))
            lowered = fn.lower(model.param_structs(), cache_structs,
                               bstructs["tokens"],
                               jax.ShapeDtypeStruct((), jnp.int32))

        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            record["memory"] = {
                "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
                "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
                "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
                "alias_gb": getattr(mem, "alias_size_in_bytes", 0) / 1e9,
            }
            record["memory"]["total_per_device_gb"] = (
                record["memory"]["argument_gb"] + record["memory"]["temp_gb"]
                + record["memory"]["output_gb"] - record["memory"]["alias_gb"])
        try:
            ca = compat.cost_analysis(compiled)
            record["flops_per_device"] = float(ca.get("flops", 0.0))
            record["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
        except Exception as e:  # pragma: no cover
            record["cost_analysis_error"] = str(e)
        txt = compiled.as_text()
        record["collectives"] = hlo_analysis.collective_bytes(txt)
        record["hlo_chars"] = len(txt)
        if hlo_path:
            import gzip
            with gzip.open(hlo_path, "wt") as hf:
                hf.write(txt)

    attach_roofline(record)
    return record


def attach_roofline(record: dict) -> None:
    """Compute roofline terms from the ANALYTIC cost model (XLA CPU
    cost_analysis undercounts while-loop bodies -- see launch/analytic.py);
    the HLO-derived numbers stay in the record as a structural cross-check
    (`hlo_*` fields)."""
    from repro.launch import analytic

    cfg = get_config(record["arch"])
    shape = SHAPES[record["shape"]]
    robust = RobustConfig(**{k: v for k, v in record.get("robust", {}).items()})
    chips = record.get("chips", 256)
    an = analytic.analytic_costs(
        cfg, shape, chips=chips, model_shards=16,
        num_workers=chips // 16,
        robust=robust if shape.kind == "train" else None,
        saga_num_samples=vr_num_samples(record["arch"], robust),
        remat=record.get("remat", True))
    record["analytic"] = an
    record["hlo_flops_per_device"] = record.get("flops_per_device")
    record["hlo_bytes_per_device"] = record.get("bytes_per_device")
    record["params_total"] = an["params_total"]
    record["params_active"] = an["params_active"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    record["model_flops_total"] = mult * an["params_active"] * tokens
    record["useful_flops_ratio"] = (
        record["model_flops_total"] / (an["flops_per_device"] * chips)
        if an["flops_per_device"] else None)
    record["roofline"] = {
        "compute_s": an["flops_per_device"] / PEAK_FLOPS,
        "memory_s": an["hbm_bytes_per_device"] / HBM_BW,
        "collective_s": an["collective_bytes_per_device"] / LINK_BW,
    }
    dom = max(record["roofline"], key=record["roofline"].get)
    record["roofline"]["dominant"] = dom


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D tokens (train); decode uses
    2*N_active per token forward-only."""
    import math
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.param_structs()
    n_total = sum(math.prod(p.shape) for p in
                  jax.tree_util.tree_leaves(params))
    # Active params for MoE: replace expert count by top_k (+ shared).
    n_active = n_total
    if cfg.num_experts:
        pat, periods = cfg.resolve_pattern()
        moe_blocks = sum(1 for b in pat if b.moe) * periods
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        n_active = n_total - moe_blocks * (cfg.num_experts - cfg.top_k) * per_expert
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * tokens, n_total, n_active


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--robust", default=None,
                    help="JSON overrides for RobustConfig, e.g. '{\"comm\":\"sharded\"}'")
    ap.add_argument("--train", default=None,
                    help="JSON overrides for TrainConfig, e.g. '{\"remat\": false}'")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true",
                    help="archive gzipped post-SPMD HLO next to each JSON")
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = json.loads(args.robust) if args.robust else None

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.tag:
                    name += f"__{args.tag}"
                path = os.path.join(args.out, name + ".json")
                try:
                    rec = lower_one(
                        arch, shape, multi_pod=mp, robust_overrides=overrides,
                        train_overrides=json.loads(args.train) if args.train else None,
                        hlo_path=(os.path.join(args.out, name + ".hlo.gz")
                                  if args.save_hlo else None))
                    if "skipped" in rec:
                        print(f"SKIP {name}: {rec['skipped']}")
                    else:
                        r = rec["roofline"]
                        print(f"OK   {name}: mem/dev={rec.get('memory',{}).get('total_per_device_gb',-1):.2f}GB "
                              f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
                              f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
                              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:
                    failures += 1
                    print(f"FAIL {name}: {type(e).__name__}: {e}")
                    traceback.print_exc(limit=6)
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
