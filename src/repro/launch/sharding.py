"""Sharding-tree builders: PartitionSpec trees -> NamedSharding trees, plus
the batch/cache/state specs for each step kind."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import mesh as mesh_lib

Pytree = Any


def fsdp_param_specs(spec_tree: Pytree, mesh, shapes: Pytree) -> Pytree:
    """Upgrade 'model'-sharded param dims to ('data', 'model') where the dim
    divides the combined axis size — FSDP-style inference sharding.

    Valid for SERVING only: robust training needs per-worker gradients, so
    params stay replicated over the worker axes there; at decode time there
    is no such constraint and weights can shard over every axis (XLA inserts
    the per-layer all-gathers)."""
    szs = mesh_lib.axis_sizes(mesh)
    wa = mesh_lib.worker_axes(mesh)
    combo = tuple(wa) + ("model",)
    total = 1
    for a in combo:
        total *= szs.get(a, 1)

    def fix(spec, shape):
        dims = tuple(spec) + (None,) * (len(shape.shape) - len(tuple(spec)))
        out = []
        for names, dim in zip(dims, shape.shape):
            if names == "model" and dim % total == 0:
                out.append(combo)
            else:
                out.append(names)
        return P(*out)

    return jax.tree_util.tree_map(
        fix, spec_tree, shapes, is_leaf=lambda x: isinstance(x, P))


def named(mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh):
    return NamedSharding(mesh, P())


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Pytree:
    """PartitionSpec tree matching models.api.input_specs output."""
    wa = mesh_lib.worker_axes(mesh)
    waxes = wa if len(wa) > 1 else wa[0]
    if shape.kind == "train":
        # leaves (W, per-worker-batch, ...): worker axis sharded over pod+data.
        specs = {"tokens": P(waxes, None, None), "labels": P(waxes, None, None)}
        if cfg.family == "vlm":
            specs["image_emb"] = P(waxes, None, None, None)
        if cfg.family == "audio":
            specs["audio_emb"] = P(waxes, None, None, None)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": P(waxes, None)}
        if cfg.family == "vlm":
            specs["image_emb"] = P(waxes, None, None)
        if cfg.family == "audio":
            specs["audio_emb"] = P(waxes, None, None)
        return specs
    if shape.kind == "decode":
        bspec = waxes if shape.global_batch > 1 else None
        return {"tokens": P(bspec, None), "pos": P()}
    raise ValueError(shape.kind)


def cache_batch_axis(shape: ShapeConfig, mesh) -> tuple:
    """(batch_sharding, seq_sharding) for KV caches.

    decode_32k: batch large -> shard batch over worker axes, seq replicated.
    long_500k: batch=1 -> shard the *sequence* over the data axis
    (sequence-parallel KV cache; attention LSE-combines across shards).
    """
    wa = mesh_lib.worker_axes(mesh)
    waxes = wa if len(wa) > 1 else wa[0]
    if shape.global_batch > 1:
        return (waxes, None)
    return (None, "data")


def cache_specs_for(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Pytree:
    """Spec tree matching models init_decode_cache structure: leaves are
    stacked over periods (leading dim), then (B, S, KV, hd) for attention,
    mamba state for SSM blocks."""
    if cfg.family == "audio":
        # The enc-dec decoder's pattern carries cross-attention caches.
        import dataclasses

        from repro.configs.base import BlockSpec
        cfg = dataclasses.replace(cfg, pattern=(BlockSpec(kind="attn", cross=True),))
    pat, _ = cfg.resolve_pattern()
    b_ax, s_ax = cache_batch_axis(shape, mesh)
    szs = mesh_lib.axis_sizes(mesh)

    def div(dim, ax):
        if ax is None:
            return None
        total = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            total *= szs.get(a, 1)
        return ax if dim % total == 0 else None

    hd = cfg.resolved_head_dim
    kv_shard = div(cfg.num_kv_heads * 0 + cfg.num_kv_heads, "model")
    # kv head count rarely divides 16; shard head_dim instead when possible.
    cache = {}
    for i, spec in enumerate(pat):
        c = {}
        if spec.kind == "attn":
            kvspec = P(b_ax, s_ax, div(cfg.num_kv_heads, "model"),
                       None if div(cfg.num_kv_heads, "model") else div(hd, "model"))
            c["k"] = kvspec
            c["v"] = kvspec
        else:
            c["h"] = P(b_ax, div(cfg.ssm_heads, "model"), None, None)
            c["conv_x"] = P(b_ax, None, div(cfg.d_inner, "model"))
            c["conv_B"] = P(b_ax, None, None)
            c["conv_C"] = P(b_ax, None, None)
        if spec.cross:
            cs = P(b_ax, None, div(cfg.num_kv_heads, "model"), None)
            c["cross_k"] = cs
            c["cross_v"] = cs
        cache[f"pos{i}"] = {k: P(None, *tuple(v)) for k, v in c.items()}
    return cache
