"""Parse collective traffic out of compiled (SPMD-partitioned) HLO text.

``cost_analysis()`` does not expose collective bytes, so we scan the HLO for
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` ops.  The post-optimization HLO print omits operand
shapes, so byte accounting works from the RESULT shape plus the collective's
replica-group size S with a ring model (bytes received per device):

    all-gather          result * (S-1)/S
    all-reduce          2 * result * (S-1)/S     (reduce-scatter + all-gather)
    reduce-scatter      result * (S-1)            (operand = S * result)
    all-to-all          result * (S-1)/S
    collective-permute  result

The HLO module is the per-device program, so parsed bytes are already
per-device; the roofline collective term is bytes / link_bw.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"=\s*(?:\(|\S+\s+)?\s*([\w-]+)\(")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        # replica_groups=[G,S]: G groups of size S.
        return max(int(m.group(2)), 1)
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # unknown: conservative smallest nontrivial group


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Bytes moved per device, by collective kind + 'total'."""
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        eq = ls.index("=")
        m = re.search(r"\s([\w-]+)\(", ls[eq:])
        if not m:
            continue
        op = m.group(1)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        if op.endswith("-done"):   # payload counted at the matching -start
            continue
        result_seg = ls[eq + 1 : eq + m.start(1)]
        result_b = _line_shapes(result_seg)
        s = _group_size(ls)
        if op.startswith(("all-gather-start", "all-reduce-start")):
            # tuple result (operand, result): halve to get the result part.
            result_b //= 2
        if kind == "all-gather":
            moved = result_b * (s - 1) / s
        elif kind == "all-reduce":
            moved = 2 * result_b * (s - 1) / s
        elif kind == "reduce-scatter":
            moved = result_b * (s - 1)
        elif kind == "all-to-all":
            moved = result_b * (s - 1) / s
        else:  # collective-permute
            moved = result_b
        out[kind] += moved
        out["total"] += moved
        out[f"count:{kind}"] += 1
    return dict(out)


def _line_shapes(segment: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(segment))


def collective_summary(hlo_text: str) -> str:
    b = collective_bytes(hlo_text)
    parts = [f"{k}={b.get(k, 0) / 1e9:.3f}GB(n={int(b.get('count:' + k, 0))})"
             for k in _COLLECTIVES if k in b]
    return f"total={b.get('total', 0) / 1e9:.3f}GB " + " ".join(parts)
