"""Analytic roofline model: per-device FLOPs, HBM bytes, and collective
bytes per step from first principles (config + shape + mesh + robust mode).

Why analytic: XLA's CPU ``cost_analysis()`` counts while-loop bodies ONCE
(verified: an 8-step ``lax.scan`` of matmuls reports ~1/8 the FLOPs of the
unrolled loop), so compiled-artifact counters systematically undercount
scanned-layer models.  Production roofline practice is analytic anyway; the
compiled artifact remains the proof of lowering/fit and a structural
cross-check (collective kinds, buffer sizes).

Conventions:
* bf16 params/activations (2 bytes); f32 Weiszfeld accumulation.
* train FLOPs = (3 + remat) x forward FLOPs (fwd + 2x bwd + remat refwd).
* Causal attention scores/AV contribute with the average visible context
  (S/2, or the sliding window when smaller).
* TP collectives: ring model, 2 bytes/elt, one all-reduce of the block
  output per attention and per FFN block per direction (Megatron-style),
  size (S_loc x D).
* Aggregation:
  - gather  : every device receives (W-1) x p_shard messages, then sweeps
              the (W, p_shard) matrix twice per Weiszfeld iteration in HBM.
  - sharded : all_to_all (p_shard bytes) + final all-gather (p_shard),
              Weiszfeld sweeps (W, p_shard / W) per iteration.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.robust_step import RobustConfig

BF16 = 2


@dataclasses.dataclass
class Costs:
    flops_per_device: float = 0.0
    hbm_bytes_per_device: float = 0.0
    collective_bytes_per_device: float = 0.0

    def add(self, f=0.0, b=0.0, c=0.0):
        self.flops_per_device += f
        self.hbm_bytes_per_device += b
        self.collective_bytes_per_device += c


def _params_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts from the structural definition."""
    from repro.models.api import build_model
    leaves = jax.tree_util.tree_leaves(build_model(cfg).param_structs())
    n_total = sum(math.prod(p.shape) for p in leaves)
    n_active = n_total
    if cfg.num_experts:
        pat, periods = cfg.resolve_pattern()
        moe_blocks = sum(1 for b in pat if b.moe) * periods
        n_active -= moe_blocks * (cfg.num_experts - cfg.top_k) * 3 * cfg.d_model * cfg.moe_d_ff
    return n_total, n_active


def _layer_token_flops(cfg: ModelConfig, s_ctx: float, decode: bool) -> float:
    """Forward FLOPs per token for ONE period of the layer pattern, divided
    by the pattern length (i.e. the per-layer average).  ``s_ctx``: average
    attended context length."""
    pat, _ = cfg.resolve_pattern()
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    total = 0.0
    for b in pat:
        if b.kind == "attn":
            total += 2 * d * hd * (h + 2 * kv)          # qkv proj
            total += 2 * h * hd * d                     # o proj
            total += 2 * 2 * s_ctx * h * hd             # scores + AV
        else:
            di, n, hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            p = cfg.ssm_head_dim
            q = cfg.ssm_chunk
            total += 2 * d * (2 * di + 2 * n + cfg.ssm_heads)   # projections
            total += 2 * 4 * (di + 2 * n)                        # convs
            if decode:
                total += 2 * 2 * n * hs * p                      # state update + readout
            else:
                total += 2 * (q * n + q * hs * p)                # intra-chunk dual form
                total += 2 * 2 * n * hs * p                      # states + inter
            total += 2 * di * d                                  # out proj
        if b.cross:
            total += 2 * d * hd * (h + 2 * kv) + 2 * h * hd * d
            total += 2 * 2 * cfg.encoder_seq * h * hd
        if b.moe:
            fe = cfg.moe_d_ff
            total += 2 * d * cfg.num_experts                     # router
            total += cfg.top_k * 2 * 3 * d * fe                  # routed experts
            total += 2 * 2 * cfg.top_k * cfg.capacity_factor * d # dispatch+combine
            if cfg.num_shared_experts:
                total += 2 * 3 * d * cfg.num_shared_experts * fe
        elif cfg.d_ff:
            n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
            total += 2 * n_mats * d * cfg.d_ff
    return total / len(pat)


def analytic_costs(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
                   model_shards: int, num_workers: int,
                   robust: RobustConfig | None = None,
                   saga_num_samples: int = 0, remat: bool = True) -> dict:
    n_total, n_active = _params_count(cfg)
    p_shard_bytes = n_total * BF16 / model_shards     # per-device param bytes
    d = cfg.d_model
    L = cfg.num_layers
    c = Costs()

    decode = shape.kind == "decode"
    window = cfg.sliding_window
    if decode and shape.seq_len > 100_000 and window is None and cfg.family in ("dense", "moe", "vlm"):
        window = cfg.long_context_window
    if decode:
        s_ctx = min(window or shape.seq_len, shape.seq_len)
        tokens = shape.global_batch           # one new token per sequence
    else:
        s_eff = shape.seq_len / 2             # causal average
        s_ctx = min(window or s_eff, s_eff)
        tokens = shape.global_batch * shape.seq_len
    tokens_per_dev_group = tokens / (chips / model_shards)  # tokens per TP group

    # ---- model compute -----------------------------------------------------
    fwd_tok = L * _layer_token_flops(cfg, s_ctx, decode) + 2 * d * cfg.vocab_size
    if cfg.family == "audio" and not decode:
        enc_tok_equiv = cfg.encoder_layers * (
            2 * d * cfg.resolved_head_dim * (cfg.num_heads + 2 * cfg.num_kv_heads)
            + 2 * cfg.num_heads * cfg.resolved_head_dim * d
            + 2 * 2 * cfg.encoder_seq / 2 * cfg.num_heads * cfg.resolved_head_dim
            + 2 * 2 * d * cfg.d_ff)
        fwd_tok += enc_tok_equiv * (cfg.encoder_seq / max(shape.seq_len, 1))
    mult = (3 + (1 if remat else 0)) if shape.kind == "train" else 1
    c.add(f=mult * fwd_tok * tokens / chips)

    # ---- model HBM traffic ---------------------------------------------------
    param_passes = 5 if shape.kind == "train" else 1   # fwd+bwd+refwd+opt r/w
    c.add(b=param_passes * p_shard_bytes)
    act_unit = 16 * d * BF16                           # per token per layer
    act_passes = (4 if remat else 3) if shape.kind == "train" else 1
    c.add(b=act_passes * act_unit * L * tokens_per_dev_group)
    if decode:
        # KV / SSM state read per decoded token; the cache is sharded over
        # the model axis (heads/head_dim) or, for batch=1 long-context, over
        # the data axis -- either way a 1/model_shards-scale slice per chip.
        pat, periods = cfg.resolve_pattern()
        attn_blocks = sum(1 for b in pat if b.kind == "attn") * periods
        mamba_blocks = sum(1 for b in pat if b.kind == "mamba") * periods
        kv_bytes = (attn_blocks * 2 * cfg.num_kv_heads * cfg.resolved_head_dim
                    * min(window or shape.seq_len, shape.seq_len) * BF16)
        ssm_bytes = mamba_blocks * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4
        seqs_per_group = max(shape.global_batch / (chips / model_shards), 1)
        c.add(b=seqs_per_group * (kv_bytes + ssm_bytes) / model_shards)

    # ---- TP collectives ------------------------------------------------------
    pat, periods = cfg.resolve_pattern()
    blocks_per_layer = sum((2 if b.kind == "attn" else 1) + (1 if b.moe or cfg.d_ff else 0)
                           for b in pat) / len(pat)
    dirs = 2 if shape.kind == "train" else 1
    ar = lambda size: 2 * size * (model_shards - 1) / model_shards
    c.add(c=dirs * L * blocks_per_layer / 2 * ar(tokens_per_dev_group * d * BF16))

    # ---- robust aggregation (train only) ------------------------------------
    vr_state_bytes = 0.0
    if shape.kind == "train" and robust is not None:
        w = num_workers
        iters = robust.weiszfeld_iters
        p_loc = p_shard_bytes                      # message shard per device
        if robust.aggregator in ("geomed", "geomed_groups", "geomed_blockwise",
                                 "median", "trimmed_mean", "krum"):
            rows = robust.num_groups if robust.aggregator == "geomed_groups" else w
            if robust.comm == "sharded":
                c.add(c=2 * p_loc)                              # all_to_all + allgather
                c.add(b=2 * iters * rows * (p_loc / w))         # weiszfeld sweeps on slice
                c.add(f=4 * iters * rows * (n_total / model_shards / w))
            else:
                c.add(c=(rows - 1) * p_loc)                     # gather W messages
                c.add(b=2 * iters * rows * p_loc)               # sweeps over (W, p_loc)
                c.add(f=4 * iters * rows * (n_total / model_shards))
        elif robust.aggregator == "mean":
            c.add(c=ar(p_loc))
        # Variance-reduction terms come from the reducer itself (the one
        # place that knows each method's state layout): per-step HBM
        # passes over the message shard, and the resident state bytes.
        reducer = robust.reducer()
        if reducer.wants_state(saga_num_samples):
            c.add(b=reducer.state_hbm_passes * p_loc)
            # Resident VR rows: per CLIENT under client-scale virtualization
            # (num_clients > 0), per worker slot otherwise.
            vr_rows = robust.num_clients or w
            vr_state_bytes = (BF16 * reducer.memory_elems(
                vr_rows, saga_num_samples, n_total) / chips)
    out = {
        "flops_per_device": c.flops_per_device,
        "hbm_bytes_per_device": c.hbm_bytes_per_device,
        "collective_bytes_per_device": c.collective_bytes_per_device,
        "params_total": n_total,
        "params_active": n_active,
    }
    if shape.kind == "train" and robust is not None:
        out["vr_state_bytes_per_device"] = vr_state_bytes
    return out
