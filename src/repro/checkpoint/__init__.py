from repro.checkpoint.checkpoint import CheckpointManager, load, save
