"""Dependency-free pytree checkpointing (npz + JSON treedef).

Saves any pytree of arrays (params, optimizer state, variance-reduction
state -- SAGA tables, lsvrg snapshots/anchors, whatever the configured
:class:`repro.core.variance.VarianceReducer` carries -- and step counters)
to a single ``.npz`` with a JSON sidecar describing the tree structure,
and restores it bit-exactly.  Supports atomic writes and a rolling
``keep`` window for periodic training checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_SEP = "/"


def _flatten_with_paths(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":
            # ml_dtypes (bfloat16 etc.) are void dtypes for np.savez; widen
            # to float32 (exact for bf16/f16) and restore on load via `like`.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save(path: str, tree: Pytree) -> None:
    """Atomically save a pytree to ``path`` (a .npz file)."""
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __treedef__=np.frombuffer(str(treedef).encode(), np.uint8),
                 **flat)
        # np.savez appends .npz to names without it.
        src = tmp if tmp.endswith(".npz") else tmp + ".npz"
        os.replace(src, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of ``like`` (shapes/dtypes must match what
    was saved; ``like`` may be a pytree of arrays or ShapeDtypeStructs)."""
    with np.load(path) as data:
        flat_like = _flatten_with_paths_struct(like)
        out = {}
        for key, proto in flat_like.items():
            if key not in data:
                raise KeyError(f"checkpoint {path} missing leaf {key!r}")
            out[key] = jnp.asarray(data[key]).astype(proto.dtype)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten_with_paths_struct(like).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])


def _flatten_with_paths_struct(tree: Pytree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    """Rolling checkpoint directory: ``step_000123.npz``, keep last N."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.npz")

    def save(self, step: int, tree: Pytree) -> str:
        p = self._path(step)
        save(p, tree)
        self._gc()
        return p

    def latest_step(self) -> Optional[int]:
        steps = sorted(self.all_steps())
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, like: Pytree) -> Pytree:
        return load(self._path(step), like)

    # -- full-train-state convenience -----------------------------------
    #
    # The train state is WHOLE-state by contract: params + optimizer state
    # + the generic variance-reduction state (SAGA table/avg, lsvrg
    # snapshot/anchor, ...) + step counter (+ PRNG key for the simulation
    # path), exactly the dict/NamedTuple the step builders hand back.
    # Saving anything less makes resumed runs silently diverge (a fresh
    # Adam moment, a cold SAGA table or a stale lsvrg snapshot changes the
    # trajectory); tests/test_system.py pins resume bit-exactness for both
    # paths.

    def save_train_state(self, step: int, state: Pytree) -> str:
        """Checkpoint the COMPLETE train state at ``step``.  ``state`` must
        be the full structure returned by the step functions -- every leaf
        (bf16 included) round-trips bit-exactly."""
        return self.save(step, state)

    def restore_latest(self, like: Pytree) -> tuple[Optional[int], Pytree]:
        """Restore the newest checkpoint into the structure of ``like``
        (arrays or ShapeDtypeStructs).  Returns ``(step, state)``, or
        ``(None, like)`` when the directory holds no checkpoint yet --
        callers can start fresh without special-casing."""
        step = self.latest_step()
        if step is None:
            return None, like
        return step, self.restore(step, like)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            os.unlink(self._path(s))
