"""Dependency-free pytree checkpointing (npz + JSON treedef).

Saves any pytree of arrays (params, optimizer state, variance-reduction
state -- SAGA tables, lsvrg snapshots/anchors, whatever the configured
:class:`repro.core.variance.VarianceReducer` carries -- and step counters)
to a single ``.npz`` with a JSON sidecar describing the tree structure,
and restores it bit-exactly.  Supports atomic writes and a rolling
``keep`` window for periodic training checkpoints.

Integrity + recovery (DESIGN.md Sec. 13): the manager keeps a
``manifest.json`` next to the checkpoints with a sha256 content checksum
per file and an optional ``last_good`` step marker.  ``restore_latest``
verifies the checksum before deserializing and walks newest->oldest past
corrupted files (truncated npz, bit rot) with a warning instead of
crashing the resume; ``mark_good`` / ``restore_last_good`` give the
host-side rollback state machine (``launch/health.py``) a verified
anchor that the rolling GC never deletes.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_SEP = "/"


def _flatten_with_paths(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":
            # ml_dtypes (bfloat16 etc.) are void dtypes for np.savez; widen
            # to float32 (exact for bf16/f16) and restore on load via `like`.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save(path: str, tree: Pytree) -> None:
    """Atomically save a pytree to ``path`` (a .npz file)."""
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __treedef__=np.frombuffer(str(treedef).encode(), np.uint8),
                 **flat)
        # np.savez appends .npz to names without it.
        src = tmp if tmp.endswith(".npz") else tmp + ".npz"
        os.replace(src, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of ``like`` (shapes/dtypes must match what
    was saved; ``like`` may be a pytree of arrays or ShapeDtypeStructs)."""
    with np.load(path) as data:
        flat_like = _flatten_with_paths_struct(like)
        out = {}
        for key, proto in flat_like.items():
            if key not in data:
                raise KeyError(f"checkpoint {path} missing leaf {key!r}")
            out[key] = jnp.asarray(data[key]).astype(proto.dtype)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten_with_paths_struct(like).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])


def _flatten_with_paths_struct(tree: Pytree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class CheckpointManager:
    """Rolling checkpoint directory: ``step_000123.npz``, keep last N (plus
    the ``last_good`` anchor, which the GC never deletes)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.npz")

    # -- manifest (checksums + last-good marker) -------------------------

    @property
    def _manifest_path(self) -> str:
        return os.path.join(self.directory, "manifest.json")

    def _manifest(self) -> dict:
        try:
            with open(self._manifest_path) as f:
                m = json.load(f)
        except (OSError, json.JSONDecodeError):
            m = {}
        m.setdefault("checksums", {})
        m.setdefault("last_good", None)
        return m

    def _write_manifest(self, m: dict) -> None:
        # Atomic like the checkpoints themselves: a crash mid-write must
        # not destroy the previous (valid) manifest.
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(m, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self._manifest_path)

    def save(self, step: int, tree: Pytree) -> str:
        p = self._path(step)
        save(p, tree)
        m = self._manifest()
        m["checksums"][os.path.basename(p)] = _sha256_file(p)
        self._write_manifest(m)
        self._gc()
        return p

    def verify(self, step: int) -> bool:
        """True when the checkpoint file exists and matches its manifest
        checksum (legacy files with no recorded checksum pass)."""
        p = self._path(step)
        if not os.path.exists(p):
            return False
        expect = self._manifest()["checksums"].get(os.path.basename(p))
        return expect is None or _sha256_file(p) == expect

    def latest_step(self) -> Optional[int]:
        steps = sorted(self.all_steps())
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, like: Pytree) -> Pytree:
        return load(self._path(step), like)

    # -- full-train-state convenience -----------------------------------
    #
    # The train state is WHOLE-state by contract: params + optimizer state
    # + the generic variance-reduction state (SAGA table/avg, lsvrg
    # snapshot/anchor, ...) + step counter (+ PRNG key for the simulation
    # path), exactly the dict/NamedTuple the step builders hand back.
    # Saving anything less makes resumed runs silently diverge (a fresh
    # Adam moment, a cold SAGA table or a stale lsvrg snapshot changes the
    # trajectory); tests/test_system.py pins resume bit-exactness for both
    # paths.

    def save_train_state(self, step: int, state: Pytree) -> str:
        """Checkpoint the COMPLETE train state at ``step``.  ``state`` must
        be the full structure returned by the step functions -- every leaf
        (bf16 included) round-trips bit-exactly."""
        return self.save(step, state)

    def restore_latest(self, like: Pytree) -> tuple[Optional[int], Pytree]:
        """Restore the newest VALID checkpoint into the structure of
        ``like`` (arrays or ShapeDtypeStructs).  Each candidate's content
        checksum is verified against the manifest before deserializing; a
        corrupted or unreadable file (truncated npz, bit rot) is skipped
        with a warning and the next-older checkpoint is tried.  Returns
        ``(step, state)``, or ``(None, like)`` when no restorable
        checkpoint exists -- callers can start fresh without
        special-casing."""
        for step in reversed(self.all_steps()):
            if not self.verify(step):
                warnings.warn(
                    f"checkpoint {self._path(step)} fails its manifest "
                    f"checksum; skipping to the previous checkpoint")
                continue
            try:
                return step, self.restore(step, like)
            except Exception as e:  # truncated/corrupt npz, missing leaves
                warnings.warn(
                    f"checkpoint {self._path(step)} is unreadable "
                    f"({type(e).__name__}: {e}); skipping to the previous "
                    f"checkpoint")
        return None, like

    # -- last-good anchor (host-side rollback, launch/health.py) ---------

    def mark_good(self, step: int) -> None:
        """Record ``step`` as the last KNOWN-GOOD checkpoint (the run was
        healthy when it was taken).  The GC never deletes it."""
        if not os.path.exists(self._path(step)):
            raise FileNotFoundError(f"cannot mark step {step} good: "
                                    f"{self._path(step)} does not exist")
        m = self._manifest()
        m["last_good"] = int(step)
        self._write_manifest(m)

    def last_good_step(self) -> Optional[int]:
        step = self._manifest()["last_good"]
        if step is None or not os.path.exists(self._path(step)):
            return None
        return int(step)

    def restore_last_good(self, like: Pytree) -> tuple[Optional[int], Pytree]:
        """Restore the last checkpoint marked good (verified), or fall back
        to :meth:`restore_latest`'s newest-valid walk when no good marker
        exists."""
        step = self.last_good_step()
        if step is not None and self.verify(step):
            try:
                return step, self.restore(step, like)
            except Exception as e:
                warnings.warn(
                    f"last-good checkpoint {self._path(step)} is unreadable "
                    f"({type(e).__name__}: {e}); falling back to the "
                    f"newest valid checkpoint")
        return self.restore_latest(like)

    def _gc(self) -> None:
        steps = self.all_steps()
        good = self._manifest()["last_good"]
        doomed = [s for s in (steps[: -self.keep] if self.keep else [])
                  if s != good]
        for s in doomed:
            os.unlink(self._path(s))
        if doomed:
            m = self._manifest()
            live = {f"step_{s:08d}.npz" for s in self.all_steps()}
            m["checksums"] = {k: v for k, v in m["checksums"].items()
                              if k in live}
            self._write_manifest(m)
