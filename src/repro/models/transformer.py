"""Decoder-only transformer assembler (dense / MoE / SSM / hybrid / VLM).

Layers are organized as ``num_periods`` repetitions of a *pattern* of block
specs (``configs.base.BlockSpec``); parameters of each pattern position are
stacked over periods and the forward pass is a single ``lax.scan`` over
periods (HLO size and compile time are depth-independent -- essential for
the 96-layer dry-runs).  A uniform model is the special case of a length-1
pattern.

Three entry points per model (built by :func:`build`):

* ``loss(params, batch)``         -- training loss (chunked xent).
* ``prefill(params, batch)``      -- forward over the prompt, returns
                                     (last_logits, cache).
* ``decode_step(params, cache, tokens, pos)`` -- one-token serve step.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models.common import chunked_xent, layernorm, rmsnorm

Pytree = Any


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _norm_params(make, path: str, cfg: ModelConfig):
    p = {"scale": make(f"{path}.scale", (cfg.d_model,), P(None), "ones")}
    if cfg.norm == "layernorm":
        p["bias"] = make(f"{path}.bias", (cfg.d_model,), P(None), "zeros")
    return p


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def _block_params(make, path: str, cfg: ModelConfig, spec: BlockSpec):
    p = {"pre_norm": _norm_params(make, f"{path}.pre_norm", cfg)}
    if spec.kind == "attn":
        p["attn"] = attn_lib.attn_params(
            make, f"{path}.attn", d_model=cfg.d_model, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias)
    else:
        p["mamba"] = mamba_lib.mamba_params(
            make, f"{path}.mamba", d_model=cfg.d_model, d_inner=cfg.d_inner,
            ssm_state=cfg.ssm_state, num_heads=cfg.ssm_heads)
    if spec.cross:
        p["cross_norm"] = _norm_params(make, f"{path}.cross_norm", cfg)
        p["cross"] = attn_lib.attn_params(
            make, f"{path}.cross", d_model=cfg.d_model, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias, cross=True)
    p["mlp_norm"] = _norm_params(make, f"{path}.mlp_norm", cfg)
    if spec.moe:
        p["moe"] = moe_lib.moe_params(
            make, f"{path}.moe", d_model=cfg.d_model, moe_d_ff=cfg.moe_d_ff,
            num_experts=cfg.num_experts,
            num_shared_experts=cfg.num_shared_experts,
            activation=cfg.activation)
    elif cfg.d_ff:
        p["mlp"] = mlp_lib.mlp_params(
            make, f"{path}.mlp", d_model=cfg.d_model, d_ff=cfg.d_ff,
            activation=cfg.activation)
    return p


def decoder_params(make, cfg: ModelConfig, *, prefix: str = "dec"):
    """Pattern-position params stacked over periods via an outer vmap-like
    leading dim: we emit per-period paths and stack with the maker's shape
    (periods is folded into the shape directly)."""
    pat, periods = cfg.resolve_pattern()

    def stacked_make(path, shape, spec=P(), init=None):
        return make(path, (periods,) + tuple(shape), P(None, *tuple(spec)), init)

    blocks = {
        f"pos{i}": _block_params(stacked_make, f"{prefix}.pos{i}", cfg, bs)
        for i, bs in enumerate(pat)
    }
    p = {
        "embed": make(f"{prefix}.embed", (cfg.vocab_size, cfg.d_model),
                      P("model", None), ("normal", 0.02)),
        "blocks": blocks,
        "final_norm": _norm_params(make, f"{prefix}.final_norm", cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = make(f"{prefix}.unembed", (cfg.d_model, cfg.vocab_size),
                            P(None, "model"), ("normal", 0.02))
    return p


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _run_block(cfg: ModelConfig, spec: BlockSpec, bp, x, *,
               window, prefix_len, enc_out, q_chunk, kv_chunk):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, bp["pre_norm"], x)
    if spec.kind == "attn":
        h = attn_lib.attention(
            bp["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            causal=True, window=window, prefix_len=prefix_len,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
    else:
        h = mamba_lib.mamba_block(
            bp["mamba"], h, num_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
            ssm_state=cfg.ssm_state, chunk=cfg.ssm_chunk)
    x = x + h
    if spec.cross:
        h = apply_norm(cfg, bp["cross_norm"], x)
        h = attn_lib.attention(
            bp["cross"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=None, causal=False,
            cross_kv=enc_out, q_chunk=q_chunk, kv_chunk=kv_chunk)
        x = x + h
    h = apply_norm(cfg, bp["mlp_norm"], x)
    if spec.moe:
        h, aux = moe_lib.moe(
            bp["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
            activation=cfg.activation, capacity_factor=cfg.capacity_factor,
            num_shared_experts=cfg.num_shared_experts)
    elif cfg.d_ff:
        h = mlp_lib.mlp(bp["mlp"], h, activation=cfg.activation)
    else:
        h = jnp.zeros_like(x)
    return x + h, aux


def forward_hidden(params, cfg: ModelConfig, tokens, *,
                   prefix_emb: Optional[jnp.ndarray] = None,
                   enc_out: Optional[jnp.ndarray] = None,
                   window: Optional[int] = None,
                   remat: bool = True,
                   q_chunk: int = 1024, kv_chunk: int = 1024) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Embed + scan blocks.  Returns (hidden (B, S_total, D), moe_aux).

    ``prefix_emb``: (B, Pfx, D) bidirectional prefix (VLM image tokens),
    prepended to the token embeddings; ``enc_out``: encoder output for
    cross-attention decoders.
    """
    pat, periods = cfg.resolve_pattern()
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    prefix_len = 0
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
        prefix_len = prefix_emb.shape[1]
    window = window if window is not None else cfg.sliding_window

    def period_body(x, period_params):
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(pat):
            x, a = _run_block(cfg, spec, period_params[f"pos{i}"], x,
                              window=window, prefix_len=prefix_len,
                              enc_out=enc_out, q_chunk=q_chunk, kv_chunk=kv_chunk)
            aux = aux + a
        return x, aux

    body = jax.checkpoint(period_body) if remat else period_body
    x, auxs = jax.lax.scan(lambda c, pp: body(c, pp), x, params["blocks"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x, jnp.sum(auxs)


def _unembed(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]
    return params["unembed"].T


def make_loss(cfg: ModelConfig, *, remat: bool = True, loss_chunk: int = 512,
              window: Optional[int] = None, moe_aux_weight: float = 0.01,
              q_chunk: int = 1024, kv_chunk: int = 1024):
    def loss(params, batch):
        prefix_emb = batch.get("prefix_emb")
        enc_out = batch.get("enc_out")
        h, aux = forward_hidden(
            params, cfg, batch["tokens"], prefix_emb=prefix_emb,
            enc_out=enc_out, window=window, remat=remat,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
        if prefix_emb is not None:
            h = h[:, prefix_emb.shape[1]:]
        nll = chunked_xent(h, _unembed(params, cfg), batch["labels"],
                           chunk=loss_chunk, mask=batch.get("loss_mask"))
        return nll + moe_aux_weight * aux

    return loss


# ---------------------------------------------------------------------------
# Prefill: forward over the prompt, also populating the decode cache.
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens, *,
            prefix_emb: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            window: Optional[int] = None,
            q_chunk: int = 1024, kv_chunk: int = 1024):
    """Returns (last_token_logits (B, V), cache)."""
    pat, periods = cfg.resolve_pattern()
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    prefix_len = 0
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
        prefix_len = prefix_emb.shape[1]
    window = window if window is not None else cfg.sliding_window

    def period_body(x, period_params):
        caches = {}
        for i, spec in enumerate(pat):
            bp = period_params[f"pos{i}"]
            c = {}
            h = apply_norm(cfg, bp["pre_norm"], x)
            if spec.kind == "attn":
                h, (k, v) = attn_lib.attention(
                    bp["attn"], h, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                    rope_theta=cfg.rope_theta, causal=True, window=window,
                    prefix_len=prefix_len, q_chunk=q_chunk, kv_chunk=kv_chunk,
                    return_kv=True)
                c["k"], c["v"] = k, v
            else:
                h, st = mamba_lib.mamba_block(
                    bp["mamba"], h, num_heads=cfg.ssm_heads,
                    head_dim=cfg.ssm_head_dim, ssm_state=cfg.ssm_state,
                    chunk=cfg.ssm_chunk, return_state=True)
                c.update(st)
            x = x + h
            if spec.cross:
                h = apply_norm(cfg, bp["cross_norm"], x)
                hq = h @ bp["cross"]["wq"]
                ck = enc_out @ bp["cross"]["wk"]
                cv = enc_out @ bp["cross"]["wv"]
                if "bq" in bp["cross"]:
                    hq = hq + bp["cross"]["bq"]
                    ck = ck + bp["cross"]["bk"]
                    cv = cv + bp["cross"]["bv"]
                b, s, _ = h.shape
                se = enc_out.shape[1]
                c["cross_k"] = ck.reshape(b, se, cfg.num_kv_heads, cfg.resolved_head_dim)
                c["cross_v"] = cv.reshape(b, se, cfg.num_kv_heads, cfg.resolved_head_dim)
                h = attn_lib.attention(
                    bp["cross"], h, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                    rope_theta=None, causal=False, cross_kv=enc_out,
                    q_chunk=q_chunk, kv_chunk=kv_chunk)
                x = x + h
            h = apply_norm(cfg, bp["mlp_norm"], x)
            if spec.moe:
                h, _ = moe_lib.moe(
                    bp["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
                    activation=cfg.activation, capacity_factor=cfg.capacity_factor,
                    num_shared_experts=cfg.num_shared_experts)
            elif cfg.d_ff:
                h = mlp_lib.mlp(bp["mlp"], h, activation=cfg.activation)
            else:
                h = jnp.zeros_like(x)
            x = x + h
            caches[f"pos{i}"] = c
        return x, caches

    x, cache = jax.lax.scan(period_body, x, params["blocks"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = x[:, -1].astype(jnp.float32) @ _unembed(params, cfg).astype(jnp.float32).T
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (serve) path
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, make=None):
    """Cache pytree (optionally built through a maker for dry-run structs).

    Layout mirrors the block pattern: per pattern position, leaves stacked
    over periods."""
    pat, periods = cfg.resolve_pattern()
    mk = make or (lambda path, shape, spec=P(), init=None: jnp.zeros(shape, dtype))

    def stk(path, shape, spec=P(), init=None):
        return mk(path, (periods,) + tuple(shape), P(None, *tuple(spec)), init)

    cache = {}
    for i, spec in enumerate(pat):
        c = {}
        if spec.kind == "attn":
            c["k"] = stk(f"cache.pos{i}.k", (batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim),
                         P(("pod", "data"), None, "model", None))
            c["v"] = stk(f"cache.pos{i}.v", (batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim),
                         P(("pod", "data"), None, "model", None))
        else:
            c["h"] = stk(f"cache.pos{i}.h", (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                         P(("pod", "data"), "model", None, None))
            c["conv_x"] = stk(f"cache.pos{i}.conv_x", (batch, 3, cfg.d_inner),
                              P(("pod", "data"), None, "model"))
            c["conv_B"] = stk(f"cache.pos{i}.conv_B", (batch, 3, cfg.ssm_state),
                              P(("pod", "data"), None, None))
            c["conv_C"] = stk(f"cache.pos{i}.conv_C", (batch, 3, cfg.ssm_state),
                              P(("pod", "data"), None, None))
        if spec.cross:
            c["cross_k"] = stk(f"cache.pos{i}.cross_k",
                               (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.resolved_head_dim),
                               P(("pod", "data"), None, "model", None))
            c["cross_v"] = stk(f"cache.pos{i}.cross_v",
                               (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.resolved_head_dim),
                               P(("pod", "data"), None, "model", None))
        cache[f"pos{i}"] = c
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *,
                window: Optional[int] = None,
                seq_shard_axis: Optional[str] = None):
    """One-token serve step.  tokens: (B, 1); pos: scalar int32 (tokens
    already in cache).  Returns (logits (B, V), new_cache)."""
    pat, periods = cfg.resolve_pattern()
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    window = window if window is not None else cfg.sliding_window

    def period_body(x, scanned):
        period_params, pcache = scanned
        new_cache = {}
        for i, spec in enumerate(pat):
            bp = period_params[f"pos{i}"]
            c = pcache[f"pos{i}"]
            nc = dict(c)
            h = apply_norm(cfg, bp["pre_norm"], x)
            if spec.kind == "attn":
                h, kv = attn_lib.decode_attention(
                    bp["attn"], h, {"k": c["k"], "v": c["v"]}, pos,
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                    window=window, seq_shard_axis=seq_shard_axis)
                nc.update(kv)
            else:
                h, mc = mamba_lib.mamba_decode_step(
                    bp["mamba"], h, {k: c[k] for k in ("h", "conv_x", "conv_B", "conv_C")},
                    num_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
                    ssm_state=cfg.ssm_state)
                nc.update(mc)
            x = x + h
            if spec.cross:
                h = apply_norm(cfg, bp["cross_norm"], x)
                h, _ = attn_lib.decode_attention(
                    bp["cross"], h, {"k": c["cross_k"], "v": c["cross_v"]},
                    jnp.asarray(cfg.encoder_seq - 1, jnp.int32),
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, rope_theta=None, cross=True)
                nc["cross_k"], nc["cross_v"] = c["cross_k"], c["cross_v"]
                x = x + h
            h = apply_norm(cfg, bp["mlp_norm"], x)
            if spec.moe:
                # Decode routes a single token per sequence: use a no-drop
                # capacity (cap = group*top_k) so serving never drops tokens
                # (training capacity pressure doesn't apply to batch-1 groups).
                h, _ = moe_lib.moe(
                    bp["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
                    activation=cfg.activation,
                    capacity_factor=float(cfg.num_experts),
                    num_shared_experts=cfg.num_shared_experts,
                    group_size=max(x.shape[0], 8))
            elif cfg.d_ff:
                h = mlp_lib.mlp(bp["mlp"], h, activation=cfg.activation)
            else:
                h = jnp.zeros_like(x)
            x = x + h
            new_cache[f"pos{i}"] = nc
        return x, new_cache

    x, new_cache = jax.lax.scan(period_body, x, (params["blocks"], cache))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = (x[:, 0].astype(jnp.float32) @ _unembed(params, cfg).astype(jnp.float32).T)
    return logits, new_cache
