"""Grouped-query attention: training (flash-style chunked), sliding-window,
cross-attention, KV-cache decode, and sequence-sharded long-context decode.

Everything is pure JAX (``lax.scan`` online-softmax); the (S, S) score matrix
is never materialized, so 32k-token training/prefill fits activation memory.
The long-context decode path (``sharded_decode_attn``) LSE-combines partial
attention across a mesh axis that shards the KV cache sequence dim -- the
TPU-native answer to 500k-token decode (DESIGN.md Sec. 2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.common import apply_rope

NEG_INF = -1e30


def attn_params(make, prefix: str, *, d_model: int, num_heads: int,
                num_kv_heads: int, head_dim: int, qkv_bias: bool,
                cross: bool = False):
    """Parameter subtree for one attention block (weights stored flattened
    as (D, H*hd) so tensor-parallel sharding works even when H itself does
    not divide the model axis)."""
    p = {
        "wq": make(f"{prefix}.wq", (d_model, num_heads * head_dim), P(None, "model")),
        "wk": make(f"{prefix}.wk", (d_model, num_kv_heads * head_dim), P(None, "model")),
        "wv": make(f"{prefix}.wv", (d_model, num_kv_heads * head_dim), P(None, "model")),
        "wo": make(f"{prefix}.wo", (num_heads * head_dim, d_model), P("model", None)),
    }
    if qkv_bias:
        p["bq"] = make(f"{prefix}.bq", (num_heads * head_dim,), P("model"), "zeros")
        p["bk"] = make(f"{prefix}.bk", (num_kv_heads * head_dim,), P("model"), "zeros")
        p["bv"] = make(f"{prefix}.bv", (num_kv_heads * head_dim,), P("model"), "zeros")
    return p


def _project_qkv(params, x, kv_x, num_heads, num_kv_heads, head_dim):
    b, s, _ = x.shape
    sk = kv_x.shape[1]
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, sk, num_kv_heads, head_dim)
    v = v.reshape(b, sk, num_kv_heads, head_dim)
    return q, k, v


def _flash(q, k, v, *, causal: bool, prefix_len: int, q_chunk: int, kv_chunk: int,
           q_offset: int = 0):
    """Online-softmax attention.  q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).

    ``causal``: causal mask with an optional bidirectional prefix of length
    ``prefix_len`` (PaliGemma-style prefix-LM).  ``q_offset``: absolute
    position of q[0] (for windows/caches).  GQA handled by head repetition
    in-register (no memory blowup: repeat happens on the (chunk, chunk)
    score tile).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq = -(-sq // q_chunk)
    nk = -(-sk // kv_chunk)
    pad_q = nq * q_chunk - sq
    pad_k = nk * kv_chunk - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # (nq, B, qc, H, hd) etc.
    qs = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(b, nk, kv_chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kv_chunk, kv, hd).transpose(1, 0, 2, 3, 4)

    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    def q_body(_, qc_i):
        qc, qi = qc_i
        qpos = q_offset + qi * q_chunk + q_pos_base  # (qc,)

        def kv_body(carry, kc_i):
            m, l, o = carry
            kc, vc, ki = kc_i
            kpos = ki * kv_chunk + k_pos_base  # (kc,)
            # scores: (B, qc, KV, rep, kc)
            qg = qc.reshape(b, q_chunk, kv, rep, hd)
            s_ = jnp.einsum("bqgrd,bkgd->bqgrk", qg.astype(jnp.float32),
                            kc.astype(jnp.float32)) * scale
            if causal:
                allowed = (kpos[None, :] <= qpos[:, None]) | (kpos[None, :] < prefix_len)
                s_ = jnp.where(allowed[None, :, None, None, :], s_, NEG_INF)
            if pad_k:
                valid_k = kpos < sk
                s_ = jnp.where(valid_k[None, None, None, None, :], s_, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqgrk,bkgd->bqgrd", p, vc.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, q_chunk, kv, rep), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kv, rep), jnp.float32)
        o0 = jnp.zeros((b, q_chunk, kv, rep, hd), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_body, (m0, l0, o0),
                                    (ks, vs, jnp.arange(nk)))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return None, out.reshape(b, q_chunk, h, hd)

    _, outs = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq].astype(q.dtype)


def _sliding(q, k, v, *, window: int, q_chunk: int):
    """Sliding-window causal attention with true sub-quadratic compute: each
    query chunk attends a dynamic slice of K/V of static length
    window + q_chunk."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    q_chunk = min(q_chunk, sq)
    nq = -(-sq // q_chunk)
    pad_q = nq * q_chunk - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    span = window + q_chunk
    # Left-pad K/V by `window` so every chunk's slice is in range.
    kp = jnp.pad(k, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    qs = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def q_body(_, qc_i):
        qc, qi = qc_i
        start = qi * q_chunk  # in padded-K coords this is where the span starts
        kc = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        qpos = start + jnp.arange(q_chunk)  # absolute position (unpadded coords)
        kpos = start - window + jnp.arange(span)
        rep = h // kv
        qg = qc.reshape(b, q_chunk, kv, rep, hd)
        s_ = jnp.einsum("bqgrd,bkgd->bqgrk", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) * (hd ** -0.5)
        allowed = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window) & (kpos[None, :] >= 0)
        s_ = jnp.where(allowed[None, :, None, None, :], s_, NEG_INF)
        p = jax.nn.softmax(s_, axis=-1)
        out = jnp.einsum("bqgrk,bkgd->bqgrd", p, vc.astype(jnp.float32))
        return None, out.reshape(b, q_chunk, h, hd)

    _, outs = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq].astype(q.dtype)


def attention(params, x, *, num_heads: int, num_kv_heads: int, head_dim: int,
              rope_theta: Optional[float] = 1e4, causal: bool = True,
              window: Optional[int] = None, prefix_len: int = 0,
              cross_kv: Optional[jnp.ndarray] = None,
              positions: Optional[jnp.ndarray] = None,
              q_chunk: int = 1024, kv_chunk: int = 1024,
              return_kv: bool = False):
    """Full attention sublayer for training/prefill.  x: (B, S, D).

    ``return_kv``: also return the (roped) K/V so prefill can populate the
    decode cache."""
    b, s, _ = x.shape
    kv_x = cross_kv if cross_kv is not None else x
    q, k, v = _project_qkv(params, x, kv_x, num_heads, num_kv_heads, head_dim)
    if rope_theta is not None and cross_kv is None:
        pos = positions if positions is not None else jnp.arange(s, dtype=jnp.int32)[None]
        q = apply_rope(q, jnp.broadcast_to(pos, (b, s)), rope_theta)
        k = apply_rope(k, jnp.broadcast_to(pos, (b, s)), rope_theta)
    if window is not None and causal and cross_kv is None:
        out = _sliding(q, k, v, window=window, q_chunk=q_chunk)
    else:
        out = _flash(q, k, v, causal=causal and cross_kv is None,
                     prefix_len=prefix_len, q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, s, num_heads * head_dim) @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# Decode (serve) path
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
    }


def decode_attention(params, x, cache, pos, *, num_heads: int,
                     num_kv_heads: int, head_dim: int,
                     rope_theta: Optional[float] = 1e4,
                     window: Optional[int] = None,
                     seq_shard_axis: Optional[str] = None,
                     cross: bool = False) -> tuple[jnp.ndarray, dict]:
    """One-token decode.  x: (B, 1, D); cache k/v: (B, S, KV, hd); ``pos``:
    scalar current position (number of tokens already cached).

    ``seq_shard_axis``: if set, k/v are sequence-sharded over that mesh axis
    and partial attention is LSE-combined with psums (long_500k path); the
    caller must run this inside shard_map.
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, x, num_heads, num_kv_heads, head_dim)
    if rope_theta is not None:
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = apply_rope(q, posv, rope_theta)
        k_new = apply_rope(k_new, posv, rope_theta)

    if cross:
        # Fixed (precomputed) encoder K/V: attend over everything, no write.
        out = _cache_attn(q, cache["k"], cache["v"], pos, None)
        out = out.reshape(b, 1, num_heads * head_dim) @ params["wo"]
        return out, cache

    if seq_shard_axis is None:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
        out = _cache_attn(q, k, v, pos, window)
        new_cache = {"k": k, "v": v}
    else:
        # Sequence-sharded cache: run the LSE-combined attention inside a
        # shard_map that is manual over the seq axis only ('model' and batch
        # sharding stay under the automatic partitioner).  The mesh comes from
        # the ambient compat.use_mesh context.  Each shard learns its own
        # index from a P(ax)-sharded iota instead of lax.axis_index, which
        # old-jax partial-manual shard_map cannot lower (PartitionId op).
        ax = seq_shard_axis
        mesh = compat.active_mesh()
        if mesh is None:
            raise RuntimeError(
                "sequence-sharded decode needs an ambient mesh -- wrap the "
                "call in `with repro.compat.use_mesh(mesh):`")
        n_shards = dict(zip(mesh.axis_names, mesh.axis_sizes
                            if hasattr(mesh, "axis_sizes")
                            else tuple(mesh.shape.values())))[ax]
        shard_ids = jnp.arange(n_shards, dtype=jnp.int32)
        kv_spec = P(None, ax, None, None)
        fn = functools.partial(_sharded_cache_attn, axis=ax, window=window)
        out, new_cache = compat.shard_map(
            fn,
            in_specs=(P(), P(), P(), {"k": kv_spec, "v": kv_spec}, P(), P(ax)),
            out_specs=(P(), {"k": kv_spec, "v": kv_spec}),
            axis_names={ax}, check_vma=False,
        )(q, k_new, v_new, {"k": cache["k"], "v": cache["v"]}, pos, shard_ids)
    out = out.reshape(b, 1, num_heads * head_dim) @ params["wo"]
    return out, new_cache


def _cache_attn(q, k, v, pos, window):
    b, _, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    sk = k.shape[1]
    qg = q.reshape(b, 1, kv, rep, hd)
    s_ = jnp.einsum("bqgrd,bkgd->bqgrk", qg.astype(jnp.float32), k.astype(jnp.float32)) * (hd ** -0.5)
    kpos = jnp.arange(sk)
    valid = kpos <= pos
    if window is not None:
        valid &= kpos > pos - window
    s_ = jnp.where(valid[None, None, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bqgrk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def _sharded_cache_attn(q, k_new, v_new, cache, pos, shard_id, *, axis: str,
                        window):
    """KV cache sharded over ``axis`` along the sequence dim; partial
    softmax per shard combined with max/sum psums (2 scalars per head).
    ``shard_id``: (1,) int32 -- this shard's index along ``axis``."""
    b, _, h, hd = q.shape
    kv = k_new.shape[2]
    rep = h // kv
    k_loc, v_loc = cache["k"], cache["v"]
    s_loc = k_loc.shape[1]
    my = shard_id[0]
    # The new token's kv is written into the shard that owns position `pos`.
    owner = pos // s_loc
    local_off = pos - owner * s_loc
    is_owner = (my == owner)
    k_upd = jax.lax.dynamic_update_slice_in_dim(k_loc, k_new.astype(k_loc.dtype), local_off, axis=1)
    v_upd = jax.lax.dynamic_update_slice_in_dim(v_loc, v_new.astype(v_loc.dtype), local_off, axis=1)
    k_loc = jnp.where(is_owner, k_upd, k_loc)
    v_loc = jnp.where(is_owner, v_upd, v_loc)

    qg = q.reshape(b, 1, kv, rep, hd)
    s_ = jnp.einsum("bqgrd,bkgd->bqgrk", qg.astype(jnp.float32),
                    k_loc.astype(jnp.float32)) * (hd ** -0.5)
    kpos = my * s_loc + jnp.arange(s_loc)
    valid = kpos <= pos
    if window is not None:
        valid &= kpos > pos - window
    s_ = jnp.where(valid[None, None, None, None, :], s_, NEG_INF)
    m_loc = jnp.max(s_, axis=-1)                         # (B,1,KV,rep)
    m_glob = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(s_ - m_glob[..., None])
    l_loc = jnp.sum(p, axis=-1)
    o_loc = jnp.einsum("bqgrk,bkgd->bqgrd", p, v_loc.astype(jnp.float32))
    l_glob = jax.lax.psum(l_loc, axis)
    o_glob = jax.lax.psum(o_loc, axis)
    out = (o_glob / jnp.maximum(l_glob[..., None], 1e-30)).reshape(b, 1, h, hd)
    return out.astype(q.dtype), {"k": k_loc, "v": v_loc}
