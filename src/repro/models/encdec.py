"""Encoder-decoder (Whisper-style) backbone.

Per the brief, the audio frontend (mel spectrogram + conv feature extractor)
is a STUB: ``input_specs`` supplies precomputed frame embeddings
(B, encoder_seq, D).  This module implements the transformer backbone: a
bidirectional encoder over the frames and a causal decoder with
cross-attention (built from the same block machinery as the decoder-only
models, pattern = [attn(cross=True)]).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import transformer as tfm

Pytree = Any


def _decoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, pattern=(BlockSpec(kind="attn", cross=True),))


def encdec_params(make, cfg: ModelConfig):
    enc = {
        "pos_embed": make("enc.pos_embed", (cfg.encoder_seq, cfg.d_model),
                          P(None, None), ("normal", 0.02)),
        "blocks": {
            "pos0": tfm._block_params(
                _stacked_make(make, cfg.encoder_layers), "enc.pos0", cfg,
                BlockSpec(kind="attn")),
        },
        "final_norm": tfm._norm_params(make, "enc.final_norm", cfg),
    }
    dec = tfm.decoder_params(make, _decoder_cfg(cfg), prefix="dec")
    return {"encoder": enc, "decoder": dec}


def _stacked_make(make, periods: int):
    def stacked(path, shape, spec=P(), init=None):
        return make(path, (periods,) + tuple(shape), P(None, *tuple(spec)), init)
    return stacked


def encode(params, cfg: ModelConfig, audio_emb: jnp.ndarray, *,
           remat: bool = True, q_chunk: int = 1024, kv_chunk: int = 1024) -> jnp.ndarray:
    """audio_emb: (B, encoder_seq, D) stub frontend output -> encoder states."""
    x = audio_emb.astype(cfg.dtype) + params["encoder"]["pos_embed"].astype(cfg.dtype)

    def body(x, bp):
        h = tfm.apply_norm(cfg, bp["pre_norm"], x)
        h = attn_lib.attention(
            bp["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=None, causal=False,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
        x = x + h
        h = tfm.apply_norm(cfg, bp["mlp_norm"], x)
        h = mlp_lib.mlp(bp["mlp"], h, activation=cfg.activation)
        return x + h, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"]["blocks"]["pos0"])
    return tfm.apply_norm(cfg, params["encoder"]["final_norm"], x)


def make_loss(cfg: ModelConfig, *, remat: bool = True, loss_chunk: int = 512,
              q_chunk: int = 1024, kv_chunk: int = 1024):
    dcfg = _decoder_cfg(cfg)
    dec_loss = tfm.make_loss(dcfg, remat=remat, loss_chunk=loss_chunk,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)

    def loss(params, batch):
        enc_out = encode(params, cfg, batch["audio_emb"], remat=remat,
                         q_chunk=q_chunk, kv_chunk=kv_chunk)
        b = dict(batch)
        b["enc_out"] = enc_out
        return dec_loss(params["decoder"], b)

    return loss


def prefill(params, cfg: ModelConfig, batch, *, q_chunk=1024, kv_chunk=1024):
    enc_out = encode(params, cfg, batch["audio_emb"], remat=False,
                     q_chunk=q_chunk, kv_chunk=kv_chunk)
    return tfm.prefill(params["decoder"], _decoder_cfg(cfg), batch["tokens"],
                       enc_out=enc_out, q_chunk=q_chunk, kv_chunk=kv_chunk)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    return tfm.decode_step(params["decoder"], _decoder_cfg(cfg), cache, tokens, pos)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, make=None):
    return tfm.init_decode_cache(_decoder_cfg(cfg), batch, max_len, dtype, make)
