"""Mixture-of-Experts layer: top-k routing, optional shared experts,
capacity-based dispatch/combine einsums (TPU-native, collective pattern is
an all-to-all-equivalent pair of batched matmuls under SPMD).

Matches the assigned configs:
* Mixtral-8x22B: 8 routed experts, top-2, no shared experts.
* Qwen1.5-MoE-A2.7B: 60 routed top-4 + 4 shared experts (shared experts are
  a dense SwiGLU whose d_ff is ``num_shared * moe_d_ff``).
* Jamba: 16 routed, top-2.

Dispatch is group-chunked (``lax.scan`` over token groups) so the one-hot
dispatch tensor (g, E, C) stays bounded regardless of sequence length.
Expert weights are stacked (E, D, F) with F sharded over the model axis
(tensor-parallel experts — works for any expert count, incl. 60).
A Switch-style load-balance aux loss is returned for training.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import mlp as mlp_lib


def moe_params(make, prefix: str, *, d_model: int, moe_d_ff: int,
               num_experts: int, num_shared_experts: int, activation: str):
    p = {
        "router": make(f"{prefix}.router", (d_model, num_experts), P(None, None)),
        "w_in": make(f"{prefix}.w_in", (num_experts, d_model, moe_d_ff), P(None, None, "model")),
        "w_gate": make(f"{prefix}.w_gate", (num_experts, d_model, moe_d_ff), P(None, None, "model")),
        "w_out": make(f"{prefix}.w_out", (num_experts, moe_d_ff, d_model), P(None, "model", None)),
    }
    if num_shared_experts:
        p["shared"] = mlp_lib.mlp_params(
            make, f"{prefix}.shared", d_model=d_model,
            d_ff=num_shared_experts * moe_d_ff, activation=activation)
    return p


def _expert_ffn(params, xe, activation: str):
    """xe: (E, C, D) -> (E, C, D); expert-batched gated FFN."""
    act = mlp_lib.ACTIVATIONS[mlp_lib.GATED.get(activation, activation)]
    gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_in"])
    if activation in mlp_lib.GATED:
        h = act(gate) * up
    else:
        h = act(up)
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"])


def moe(params, x, *, num_experts: int, top_k: int, activation: str,
        capacity_factor: float = 1.25, group_size: int = 2048,
        num_shared_experts: int = 0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D). Returns (output, aux_load_balance_loss)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    group = min(group_size, t)
    pad = (-t) % group
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_groups = xf.shape[0] // group
    xg = xf.reshape(n_groups, group, d)
    cap = int(group * top_k / num_experts * capacity_factor)
    cap = max(cap, top_k)

    def group_body(_, xt):
        # Routing.
        logits = (xt @ params["router"]).astype(jnp.float32)     # (g, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (g, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        # Position of each (token, k) inside its expert's buffer.
        onehot = jax.nn.one_hot(gate_idx, num_experts, dtype=jnp.float32)  # (g,k,E)
        pos = jnp.cumsum(onehot.reshape(-1, num_experts), axis=0).reshape(
            group, top_k, num_experts) - 1.0
        pos = jnp.sum(pos * onehot, axis=-1)                     # (g, k)
        keep = pos < cap
        gate_vals = gate_vals * keep
        # Dispatch/combine tensors (g, E, C).
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        disp = jnp.einsum("gke,gkc->gec", onehot * keep[..., None], pos_oh)
        comb = jnp.einsum("gke,gkc,gk->gec", onehot, pos_oh, gate_vals)
        xe = jnp.einsum("gec,gd->ecd", disp, xt.astype(jnp.float32))  # (E,C,D)
        ye = _expert_ffn(params, xe.astype(xt.dtype), activation)
        yt = jnp.einsum("gec,ecd->gd", comb, ye.astype(jnp.float32)).astype(xt.dtype)
        # Switch aux loss terms: fraction routed + mean router prob per expert.
        frac = jnp.mean(onehot[:, 0, :], axis=0)     # top-1 assignment share
        pmean = jnp.mean(probs, axis=0)
        aux = num_experts * jnp.sum(frac * pmean)
        return None, (yt, aux)

    _, (yg, auxg) = jax.lax.scan(group_body, None, xg)
    y = yg.reshape(-1, d)[:t].reshape(b, s, d)
    if num_shared_experts:
        y = y + mlp_lib.mlp(params["shared"], x, activation=activation)
    return y, jnp.mean(auxg)
