"""Unified model API: ``build_model(cfg)`` -> :class:`Model`.

One object per architecture exposing init / loss / prefill / decode_step,
plus the three *maker* interpretations of its parameter and cache trees
(arrays, PartitionSpecs, ShapeDtypeStructs) so smoke tests, the real
trainer, and the zero-allocation multi-pod dry-run all consume the same
definition.  ``input_specs`` produces the batch stand-ins for each of the
four assigned input shapes (with stubbed frontend embeddings for the
audio/VLM archs, per the brief).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.models.common import init_maker, spec_maker, struct_maker

Pytree = Any


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Pytree]
    param_specs: Callable[[dict], Pytree]
    param_structs: Callable[[], Pytree]
    loss: Callable[[Pytree, dict], jnp.ndarray]
    prefill: Callable[[Pytree, dict], tuple]
    decode_step: Callable[..., tuple]
    init_cache: Callable[..., Pytree]
    cache_specs: Callable[..., Pytree]
    cache_structs: Callable[..., Pytree]


def _params_fn(cfg: ModelConfig):
    if cfg.family == "audio":
        return lambda make: encdec_lib.encdec_params(make, cfg)
    return lambda make: tfm.decoder_params(make, cfg)


def build_model(cfg: ModelConfig, *, remat: bool = True, loss_chunk: int = 512,
                q_chunk: int = 1024, kv_chunk: int = 1024) -> Model:
    params_of = _params_fn(cfg)

    def init(key):
        return params_of(init_maker(key, cfg.dtype))

    def param_specs(axis_sizes):
        return params_of(spec_maker(axis_sizes))

    def param_structs():
        return params_of(struct_maker(cfg.dtype))

    if cfg.family == "audio":
        base_loss = encdec_lib.make_loss(cfg, remat=remat, loss_chunk=loss_chunk,
                                         q_chunk=q_chunk, kv_chunk=kv_chunk)

        def loss(params, batch):
            return base_loss(params, batch)

        def prefill_fn(params, batch):
            return encdec_lib.prefill(params, cfg, batch, q_chunk=q_chunk,
                                      kv_chunk=kv_chunk)

        def decode_fn(params, cache, tokens, pos, *, window=None,
                      seq_shard_axis=None):
            return encdec_lib.decode_step(params, cfg, cache, tokens, pos)

        def init_cache(batch, max_len, dtype=jnp.bfloat16):
            return encdec_lib.init_decode_cache(cfg, batch, max_len, dtype)

        def cache_specs(axis_sizes, batch, max_len):
            return encdec_lib.init_decode_cache(
                cfg, batch, max_len, make=spec_maker(axis_sizes))

        def cache_structs(batch, max_len, dtype=jnp.bfloat16):
            return encdec_lib.init_decode_cache(
                cfg, batch, max_len, make=struct_maker(dtype))

    else:
        base_loss = tfm.make_loss(cfg, remat=remat, loss_chunk=loss_chunk,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk)

        def loss(params, batch):
            b = batch
            if cfg.family == "vlm" and "image_emb" in batch:
                b = dict(batch)
                b["prefix_emb"] = b.pop("image_emb")
            return base_loss(params, b)

        def prefill_fn(params, batch):
            prefix = batch.get("image_emb")
            return tfm.prefill(params, cfg, batch["tokens"], prefix_emb=prefix,
                               q_chunk=q_chunk, kv_chunk=kv_chunk)

        def decode_fn(params, cache, tokens, pos, *, window=None,
                      seq_shard_axis=None):
            return tfm.decode_step(params, cfg, cache, tokens, pos,
                                   window=window, seq_shard_axis=seq_shard_axis)

        def init_cache(batch, max_len, dtype=jnp.bfloat16):
            return tfm.init_decode_cache(cfg, batch, max_len, dtype)

        def cache_specs(axis_sizes, batch, max_len):
            return _fix_cache_specs(
                tfm.init_decode_cache(cfg, batch, max_len, make=spec_maker(axis_sizes)))

        def cache_structs(batch, max_len, dtype=jnp.bfloat16):
            return tfm.init_decode_cache(cfg, batch, max_len, make=struct_maker(dtype))

    return Model(cfg, init, param_specs, param_structs, loss, prefill_fn,
                 decode_fn, init_cache, cache_specs, cache_structs)


def _fix_cache_specs(tree):
    return tree


# ---------------------------------------------------------------------------
# Input shape stand-ins
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                num_workers: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape).

    For train shapes the batch is pre-split by worker: leaves carry a
    leading ``num_workers`` axis (the robust-aggregation worker axis).
    Frontend embeddings (audio frames / image patches) are stubbed, per the
    brief.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    emb = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.bfloat16)

    if shape.kind == "train":
        wb = b // num_workers
        lead = (num_workers, wb) if num_workers > 1 else (b,)
        text_s = s - cfg.num_prefix_tokens if cfg.family == "vlm" else s
        batch = {"tokens": i32(lead + (text_s,)), "labels": i32(lead + (text_s,))}
        if cfg.family == "vlm":
            batch["image_emb"] = emb(lead + (cfg.num_prefix_tokens, cfg.d_model))
        if cfg.family == "audio":
            batch["audio_emb"] = emb(lead + (cfg.encoder_seq, cfg.d_model))
        return batch

    if shape.kind == "prefill":
        text_s = s - cfg.num_prefix_tokens if cfg.family == "vlm" else s
        batch = {"tokens": i32((b, text_s))}
        if cfg.family == "vlm":
            batch["image_emb"] = emb((b, cfg.num_prefix_tokens, cfg.d_model))
        if cfg.family == "audio":
            batch["audio_emb"] = emb((b, cfg.encoder_seq, cfg.d_model))
        return batch

    if shape.kind == "decode":
        return {"tokens": i32((b, 1)),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    raise ValueError(shape.kind)
