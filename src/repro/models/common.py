"""Shared model-building machinery.

The central trick is the *maker* protocol: every model defines its parameter
tree once, as a function ``params(make)`` where ``make(path, shape, spec,
init)`` is interpreted three ways:

* :func:`init_maker`    -- draw initialized ``jnp`` arrays (per-path PRNG);
* :func:`spec_maker`    -- produce the matching ``PartitionSpec`` tree,
                           dropping shardings whose dim isn't divisible by
                           the mesh axis (e.g. 6 whisper heads on a 16-way
                           model axis fall back to replication);
* :func:`struct_maker`  -- produce ``jax.ShapeDtypeStruct`` stand-ins so the
                           multi-pod dry-run can lower 340B-parameter models
                           without allocating a single byte.

Also here: RMSNorm/LayerNorm, RoPE, activations, and the chunked
cross-entropy that never materializes the full (B, S, V) logits tensor.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any
Maker = Callable[..., Any]


# ---------------------------------------------------------------------------
# Maker protocol
# ---------------------------------------------------------------------------

def _path_seed(path: str) -> int:
    return int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")


def init_maker(key: jax.Array, dtype=jnp.float32) -> Maker:
    """make() -> initialized array.  Init kinds: ("normal", std) | "ones" |
    "zeros" | ("uniform", bound)."""

    def make(path: str, shape: Sequence[int], spec: P = P(), init=None):
        k = jax.random.fold_in(key, _path_seed(path))
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if isinstance(init, tuple) and init[0] == "uniform":
            return jax.random.uniform(k, shape, dtype, -init[1], init[1])
        if isinstance(init, tuple) and init[0] == "normal":
            std = init[1]
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = fan_in ** -0.5
        return (std * jax.random.normal(k, shape, jnp.float32)).astype(dtype)

    return make


def spec_maker(axis_sizes: dict[str, int]) -> Maker:
    """make() -> PartitionSpec, replacing non-divisible shardings by None."""

    def make(path: str, shape: Sequence[int], spec: P = P(), init=None):
        del init
        fixed = []
        for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if names is None:
                fixed.append(None)
                continue
            names_t = names if isinstance(names, tuple) else (names,)
            total = 1
            for n in names_t:
                total *= axis_sizes.get(n, 1)
            fixed.append(names if dim % total == 0 else None)
        return P(*fixed)

    return make


def struct_maker(dtype=jnp.bfloat16) -> Maker:
    def make(path: str, shape: Sequence[int], spec: P = P(), init=None):
        del spec, init
        return jax.ShapeDtypeStruct(tuple(shape), dtype)

    return make


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
    "tanh": jnp.tanh,
}


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------

def chunked_xent(
    h: jnp.ndarray,          # (B, S, D) final hidden states
    emb: jnp.ndarray,        # (V, D) tied output embedding (or unembed.T)
    labels: jnp.ndarray,     # (B, S) int32
    *,
    chunk: int = 512,
    mask: Optional[jnp.ndarray] = None,  # (B, S) 1=count
) -> jnp.ndarray:
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    n_chunks = h.shape[1] // chunk
    hc = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        hh, ll, mm = xs
        logits = (hh.astype(jnp.float32) @ emb.astype(jnp.float32).T)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ll[..., None].astype(jnp.int32), axis=-1)[..., 0]
        nll = (lse - tgt) * mm
        return (tot + jnp.sum(nll), cnt + jnp.sum(mm)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
